//! `cargo bench --bench fig10_rate_distortion` — regenerates Fig 10
//! (rate-distortion, vecSZ avg-padding vs SZ-1.4) and the §V-I padding
//! study table.
fn main() {
    let quick = std::env::var("VECSZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    vecsz::figures::run("fig10", "results", quick).expect("fig10");
    println!();
    vecsz::figures::run("padding", "results", quick).expect("padding");
}

//! `cargo bench --bench fig5_blocksize` — regenerates Fig 5 (bandwidth vs
//! block size x vector length) and Fig 1/4 roofline placements.
fn main() {
    let quick = std::env::var("VECSZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    vecsz::figures::run("fig1", "results", quick).expect("fig1");
    println!();
    vecsz::figures::run("fig4", "results", quick).expect("fig4");
    println!();
    vecsz::figures::run("fig5", "results", quick).expect("fig5");
}

//! `cargo bench --bench fig3_bandwidth` — regenerates Fig 3 (P&Q bandwidth
//! of SZ-1.4 vs pSZ vs vecSZ per dataset, both modeled CPU configs).
//! Honours VECSZ_BENCH_QUICK=1.
fn main() {
    let quick = std::env::var("VECSZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    vecsz::figures::run("fig3", "results", quick).expect("fig3");
}

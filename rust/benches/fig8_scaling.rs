//! `cargo bench --bench fig8_scaling` — regenerates Figs 8/9 (thread
//! scaling, measured on this host + modeled for the paper's testbeds) and
//! the Fig 6/7 autotuning heatmaps.
fn main() {
    let quick = std::env::var("VECSZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    vecsz::figures::run("fig8", "results", quick).expect("fig8");
    println!();
    vecsz::figures::run("fig9", "results", quick).expect("fig9");
    println!();
    vecsz::figures::run("fig6_7", "results", quick).expect("fig6_7");
    println!();
    vecsz::figures::run("stability", "results", quick).expect("stability");
}

//! `cargo bench --bench stream_access` — streaming-container access paths:
//! chunked compression (with and without per-chunk autotuning, so the
//! tuner's overhead is a tracked number), full chunk-parallel decode, and
//! random access through the VSZ3 index footer (single chunk and row
//! range vs. decoding everything), plus the PR 8 `Dataset` handle:
//! cold region reads (open + fill) vs. warm overlapping-window reads
//! served from the decoded-chunk LRU cache. Emits the machine-readable
//! perf trajectory `BENCH_stream.json`; honour `VECSZ_BENCH_QUICK=1`
//! in CI.

// The legacy random-access rows deliberately keep exercising the
// deprecated StreamDecompressor wrappers so their cost stays tracked.
#![allow(deprecated)]

use vecsz::autotune::TuneSettings;
use vecsz::bench::{bench, BenchOpts, BenchStats};
use vecsz::blocks::Dims;
use vecsz::compressor::{BackendChoice, Config, EbMode};
use vecsz::data::Field;
use vecsz::stream::{
    compress_chunked, compress_chunked_with, decompress_chunked, Dataset, DatasetOptions,
    Region, StreamDecompressor, StreamOptions,
};
use vecsz::util::prng::Pcg32;

const ROWS: usize = 1024;
const COLS: usize = 512;
const SPAN: usize = 64; // 16 chunks of 64x512 = 32768 elems each

fn json_row(op: &str, threads: usize, s: &BenchStats) -> String {
    format!(
        "{{\"op\":\"{op}\",\"threads\":{threads},\"mb_per_s\":{:.1},\
         \"mean_s\":{:.6},\"min_s\":{:.6},\"samples\":{}}}",
        s.mean_mb_s(),
        s.mean_s,
        s.min_s,
        s.samples
    )
}

fn main() {
    let opts = BenchOpts::from_env();
    let dims = Dims::d2(ROWS, COLS);
    let mut rng = Pcg32::seeded(7);
    let mut x = 0.0f32;
    let data: Vec<f32> = (0..dims.len())
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    let field = Field::new("bench", dims, data);
    let raw_bytes = field.data.len() * 4;
    let mut rows: Vec<String> = Vec::new();

    // ---- compression: plain vs per-chunk-autotuned (tuner overhead) ----
    for threads in [1usize, 4] {
        let cfg = Config { eb: EbMode::Abs(1e-3), threads, ..Config::default() };
        let s = bench(&format!("stream compress {threads}T"), raw_bytes, opts, || {
            std::hint::black_box(compress_chunked(&field, &cfg, SPAN).unwrap());
        });
        println!("{}", s.row());
        rows.push(json_row("compress", threads, &s));
    }
    // same path through the explicit-intrinsics fused P&Q backend
    for threads in [1usize, 4] {
        let cfg = Config {
            eb: EbMode::Abs(1e-3),
            threads,
            backend: BackendChoice::Simd { width: 16 },
            ..Config::default()
        };
        let s = bench(&format!("stream compress simd16 {threads}T"), raw_bytes, opts, || {
            std::hint::black_box(compress_chunked(&field, &cfg, SPAN).unwrap());
        });
        println!("{}", s.row());
        rows.push(json_row("compress-simd16", threads, &s));
    }
    {
        let cfg = Config { eb: EbMode::Abs(1e-3), threads: 4, ..Config::default() };
        let topts = StreamOptions {
            chunk_autotune: Some(TuneSettings { sample_pct: 5.0, iterations: 1, seed: 3 }),
            ..StreamOptions::default()
        };
        let s = bench("stream compress 4T + per-chunk autotune", raw_bytes, opts, || {
            std::hint::black_box(compress_chunked_with(&field, &cfg, SPAN, topts).unwrap());
        });
        println!("{}", s.row());
        rows.push(json_row("compress-autotune", 4, &s));
    }

    // ---- the container the decode benches read ----
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, stats) = compress_chunked(&field, &cfg, SPAN).unwrap();
    println!(
        "    (container: {} chunks, {:.2}x, {} bytes)",
        stats.n_chunks,
        stats.ratio(),
        container.len()
    );

    // ---- full decode (the baseline random access competes against) ----
    for threads in [1usize, 4] {
        let s = bench(&format!("full decode {threads}T"), raw_bytes, opts, || {
            std::hint::black_box(decompress_chunked(&container, threads).unwrap());
        });
        println!("{}", s.row());
        rows.push(json_row("decode-full", threads, &s));
    }

    // ---- random access: open + index + one chunk (cold every time) ----
    let chunk_bytes = SPAN * COLS * 4;
    let s = bench("random access: one chunk (open+index+decode)", chunk_bytes, opts, || {
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&container)).unwrap();
        std::hint::black_box(dec.decode_chunk(stats.n_chunks / 2).unwrap());
    });
    println!("{}", s.row());
    rows.push(json_row("decode-chunk", 1, &s));

    // ---- random access: middle half of the rows, chunk-parallel ----
    let lo = ROWS / 4;
    let hi = 3 * ROWS / 4;
    let range_bytes = (hi - lo) * COLS * 4;
    for threads in [1usize, 4] {
        let s = bench(
            &format!("random access: rows {lo}..{hi} {threads}T"),
            range_bytes,
            opts,
            || {
                let mut dec = StreamDecompressor::new(std::io::Cursor::new(&container)).unwrap();
                std::hint::black_box(dec.decode_rows(lo..hi, threads).unwrap());
            },
        );
        println!("{}", s.row());
        rows.push(json_row("decode-rows-half", threads, &s));
    }

    // ---- Dataset handle: cold open+read vs. warm overlapping windows ----
    // Cold: a fresh handle per iteration pays open + index + chunk fill.
    // Warm: one primed handle serves two overlapping row windows from the
    // decoded-chunk LRU cache (zero chunk decodes once warm).
    for threads in [1usize, 4] {
        let s = bench(
            &format!("dataset read cold: rows {lo}..{hi} {threads}T"),
            range_bytes,
            opts,
            || {
                let ds = Dataset::open_with(
                    std::io::Cursor::new(&container),
                    DatasetOptions { threads, ..DatasetOptions::default() },
                )
                .unwrap();
                std::hint::black_box(ds.read(Region::Rows(lo..hi)).unwrap());
            },
        );
        println!("{}", s.row());
        rows.push(json_row("dataset-read-cold", threads, &s));
    }
    for threads in [1usize, 4] {
        let ds = Dataset::open_with(
            std::io::Cursor::new(&container),
            DatasetOptions { threads, ..DatasetOptions::default() },
        )
        .unwrap();
        // prime both overlapping windows so the measured loop is all hits
        ds.read(Region::Rows(lo..hi)).unwrap();
        ds.read(Region::Rows(lo + SPAN..hi + SPAN)).unwrap();
        let warm_bytes = 2 * range_bytes;
        let s = bench(
            &format!("dataset read warm: overlapping rows {threads}T"),
            warm_bytes,
            opts,
            || {
                std::hint::black_box(ds.read(Region::Rows(lo..hi)).unwrap());
                std::hint::black_box(ds.read(Region::Rows(lo + SPAN..hi + SPAN)).unwrap());
            },
        );
        println!("{}", s.row());
        rows.push(json_row("dataset-read-warm", threads, &s));
    }

    let doc = format!(
        "{{\n  \"workload\": \"walk-field-{ROWS}x{COLS}-span{SPAN}\",\n  \
         \"n_elems\": {},\n  \"raw_bytes\": {raw_bytes},\n  \"n_chunks\": {},\n  \
         \"isa\": \"{}\",\n  \"target_features\": \"{}\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        field.data.len(),
        stats.n_chunks,
        vecsz::simd::Isa::active().name(),
        vecsz::simd::compiled_target_features(),
        rows.join(",\n    ")
    );
    match std::fs::write("BENCH_stream.json", &doc) {
        Ok(()) => println!("    (wrote BENCH_stream.json)"),
        Err(e) => eprintln!("    (could not write BENCH_stream.json: {e})"),
    }
}

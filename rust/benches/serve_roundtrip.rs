//! `cargo bench --bench serve_roundtrip` — the `vsz serve` service layer:
//! request round-trips through a real loopback TCP connection against an
//! in-process server (framing + admission + shared-pool scheduling all on
//! the measured path). Single-connection compress/decompress latency plus
//! a 4-connection concurrent compress run (the admission/scheduler path
//! the smoke test gates). Emits `BENCH_serve.json`; honour
//! `VECSZ_BENCH_QUICK=1` in CI.

use vecsz::bench::{bench, BenchOpts, BenchStats};
use vecsz::blocks::Dims;
use vecsz::data::Field;
use vecsz::server::{Client, RetryPolicy, ServeConfig, Server};
use vecsz::util::prng::Pcg32;

const ROWS: usize = 512;
const COLS: usize = 256;
const SPAN: usize = 64;
const EB: f64 = 1e-3;

fn json_row(op: &str, conns: usize, s: &BenchStats) -> String {
    format!(
        "{{\"op\":\"{op}\",\"threads\":{conns},\"mb_per_s\":{:.1},\
         \"mean_s\":{:.6},\"min_s\":{:.6},\"samples\":{}}}",
        s.mean_mb_s(),
        s.mean_s,
        s.min_s,
        s.samples
    )
}

fn walk_field(name: &str, seed: u64) -> Field {
    let dims = Dims::d2(ROWS, COLS);
    let mut rng = Pcg32::seeded(seed);
    let mut x = 0.0f32;
    let data: Vec<f32> = (0..dims.len())
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    Field::new(name, dims, data)
}

fn main() {
    let opts = BenchOpts::from_env();
    let srv = Server::bind("127.0.0.1:0", ServeConfig { threads: 4, ..ServeConfig::default() })
        .expect("bind");
    let addr = srv.local_addr().expect("addr").to_string();
    let server = std::thread::spawn(move || srv.run().expect("server run"));

    let field = walk_field("bench", 7);
    let dims_s = format!("{ROWS}x{COLS}");
    let raw_bytes = field.data.len() * 4;
    let mut rows: Vec<String> = Vec::new();

    // transient `busy` replies (admission pressure) retry with backoff
    // instead of failing the whole bench run
    let policy = RetryPolicy::default();

    // ---- single connection: compress round-trip latency ----
    let mut c = Client::connect(&addr).expect("connect");
    let s = bench("serve compress 1 conn", raw_bytes, opts, || {
        let (bytes, _) = c
            .with_retry(&policy, |c| c.compress("bench", &dims_s, EB, SPAN, &field.data))
            .unwrap();
        std::hint::black_box(bytes);
    });
    println!("{}", s.row());
    rows.push(json_row("serve-compress", 1, &s));

    // ---- single connection: decompress round-trip latency ----
    let (container, _) =
        c.with_retry(&policy, |c| c.compress("bench", &dims_s, EB, SPAN, &field.data)).unwrap();
    let s = bench("serve decompress 1 conn", raw_bytes, opts, || {
        let (samples, _) = c.with_retry(&policy, |c| c.decompress(&container)).unwrap();
        std::hint::black_box(samples);
    });
    println!("{}", s.row());
    rows.push(json_row("serve-decompress", 1, &s));

    // ---- 4 connections compressing concurrently (the scheduler path) ----
    let fields: Vec<Field> = (0..4).map(|i| walk_field("cc", 100 + i as u64)).collect();
    let mut clients: Vec<Client> =
        (0..4).map(|_| Client::connect(&addr).expect("connect")).collect();
    let s = bench("serve compress 4 conns", raw_bytes * 4, opts, || {
        std::thread::scope(|scope| {
            for (cl, f) in clients.iter_mut().zip(fields.iter()) {
                let dims_s = &dims_s;
                let policy = &policy;
                scope.spawn(move || {
                    let (bytes, _) = cl
                        .with_retry(policy, |cl| cl.compress(&f.name, dims_s, EB, SPAN, &f.data))
                        .unwrap();
                    std::hint::black_box(bytes);
                });
            }
        });
    });
    println!("{}", s.row());
    rows.push(json_row("serve-compress-4conn", 4, &s));

    c.shutdown().expect("shutdown");
    drop(c);
    drop(clients);
    server.join().expect("server exits");

    let doc = format!(
        "{{\n  \"workload\": \"serve-loopback-{ROWS}x{COLS}-span{SPAN}\",\n  \
         \"n_elems\": {},\n  \"raw_bytes\": {raw_bytes},\n  \
         \"isa\": \"{}\",\n  \"target_features\": \"{}\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        field.data.len(),
        vecsz::simd::Isa::active().name(),
        vecsz::simd::compiled_target_features(),
        rows.join(",\n    ")
    );
    match std::fs::write("BENCH_serve.json", &doc) {
        Ok(()) => println!("    (wrote BENCH_serve.json)"),
        Err(e) => eprintln!("    (could not write BENCH_serve.json: {e})"),
    }
}

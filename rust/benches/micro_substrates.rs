//! `cargo bench --bench micro_substrates` — microbenchmarks of the
//! substrate stages surrounding the dual-quant hot path: Huffman encode/
//! decode, the lossless pass, block gather/scatter, the P&Q backends head
//! to head (autovectorized `vec` vs explicit-intrinsics fused `simd`, one
//! and four threads) and the decode side: the cascading scalar reference
//! vs the reverse-Lorenzo wavefront backends, plus the full decode stage
//! at 1/4 threads. These locate the non-P&Q bottlenecks that Table III's
//! Amdahl analysis attributes the residual runtime to.

use vecsz::bench::{bench, BenchOpts, BenchStats};
use vecsz::blocks::{gather_block, BlockShape, Dims, HaloBlock};
use vecsz::compressor::{compress, decompress, pq_stage, BackendChoice, Config, EbMode};
use vecsz::coordinator::pool::ThreadPool;
use vecsz::data::Field;
use vecsz::huffman;
use vecsz::lossless;
use vecsz::padding::{PadGranularity, PadScalars, PadValue, PaddingPolicy};
use vecsz::quant::decode::{
    decode_block_dualquant, DecodeBackend, ScalarDecodeBackend, SimdDecodeBackend,
};
use vecsz::quant::psz::PszBackend;
use vecsz::quant::simd::SimdBackend;
use vecsz::quant::vectorized::VecBackend;
use vecsz::quant::{CodesKind, DqConfig, PqBackend};
use vecsz::util::prng::Pcg32;

/// One machine-readable result row for `BENCH_entropy.json`.
fn json_row(op: &str, format: &str, threads: usize, s: &BenchStats) -> String {
    format!(
        "{{\"op\":\"{op}\",\"format\":\"{format}\",\"threads\":{threads},\
         \"mb_per_s\":{:.1},\"gb_per_s\":{:.3},\"mean_s\":{:.6},\"min_s\":{:.6},\
         \"samples\":{}}}",
        s.mean_mb_s(),
        s.mean_mb_s() / 1e3,
        s.mean_s,
        s.min_s,
        s.samples
    )
}

/// Emit the entropy-stage perf trajectory (tracked across PRs; GB/s over
/// the 4M-symbol skewed quant-code workload at 1/2/4/8 threads). The
/// detected/forced ISA and the compiled target features ride in the
/// metadata so `bench-compare` never diffs, say, AVX-512 numbers against
/// an SSE2 baseline (it warns and skips the gate on mismatch).
fn write_entropy_json(n_symbols: usize, rows: &[String]) {
    let doc = format!(
        "{{\n  \"workload\": \"skewed-quant-codes\",\n  \"n_symbols\": {n_symbols},\n  \
         \"alphabet\": 1024,\n  \"payload_bytes_per_run\": {},\n  \
         \"isa\": \"{}\",\n  \"target_features\": \"{}\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        n_symbols * 2,
        vecsz::simd::Isa::active().name(),
        vecsz::simd::compiled_target_features(),
        rows.join(",\n    ")
    );
    match std::fs::write("BENCH_entropy.json", &doc) {
        Ok(()) => println!("    (wrote BENCH_entropy.json)"),
        Err(e) => eprintln!("    (could not write BENCH_entropy.json: {e})"),
    }
}

/// Emit the P&Q backend trajectory (its own document — the workload is a
/// 2D smooth field, not the entropy stream, and writing it separately
/// keeps the entropy rows on disk even if a later section panics).
fn write_pq_json(rows: &[String]) {
    let doc = format!(
        "{{\n  \"workload\": \"pq-2d-smooth\",\n  \
         \"kernel_batch\": \"4096 blocks of 16x16 (4Mi elems)\",\n  \
         \"stage_field\": \"1024x1024 f32, eb 1e-3\",\n  \
         \"isa\": \"{}\",\n  \"target_features\": \"{}\",\n  \"rows\": [\n    {}\n  ]\n}}\n",
        vecsz::simd::Isa::active().name(),
        vecsz::simd::compiled_target_features(),
        rows.join(",\n    ")
    );
    match std::fs::write("BENCH_pq.json", &doc) {
        Ok(()) => println!("    (wrote BENCH_pq.json)"),
        Err(e) => eprintln!("    (could not write BENCH_pq.json: {e})"),
    }
}

fn main() {
    let opts = BenchOpts::from_env();
    let mut rng = Pcg32::seeded(1);

    // quant-code-like stream (skewed around radius)
    let n = 4_000_000usize;
    let codes: Vec<u16> = (0..n)
        .map(|_| {
            let r = rng.next_f32();
            if r < 0.85 {
                512
            } else if r < 0.97 {
                510 + rng.bounded(5) as u16
            } else {
                490 + rng.bounded(44) as u16
            }
        })
        .collect();

    let s = bench("huffman encode legacy (4M skewed codes)", n * 2, opts, || {
        std::hint::black_box(huffman::compress_u16(&codes, 1024));
    });
    println!("{}", s.row());
    let mut entropy_rows: Vec<String> = Vec::new();
    entropy_rows.push(json_row("encode", "legacy", 1, &s));

    let blob = huffman::compress_u16(&codes, 1024);
    println!("    (compressed to {:.2} bits/code)", blob.len() as f64 * 8.0 / n as f64);
    let s = bench("huffman decode legacy", n * 2, opts, || {
        std::hint::black_box(huffman::decompress_u16(&blob).unwrap());
    });
    println!("{}", s.row());
    entropy_rows.push(json_row("decode", "legacy", 1, &s));

    // chunked HUF2 entropy stage across thread counts (the perf-trajectory
    // numbers tracked in BENCH_entropy.json)
    let huf2 = huffman::compress_u16_chunked(&codes, 1024, None);
    for threads in [1usize, 2, 4, 8] {
        let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
        let s = bench(&format!("huffman encode HUF2 {threads}T"), n * 2, opts, || {
            std::hint::black_box(huffman::compress_u16_chunked(&codes, 1024, pool.as_ref()));
        });
        println!("{}", s.row());
        entropy_rows.push(json_row("encode", "huf2", threads, &s));
        let s = bench(&format!("huffman decode HUF2 {threads}T"), n * 2, opts, || {
            std::hint::black_box(huffman::decompress_u16_pooled(&huf2, pool.as_ref()).unwrap());
        });
        println!("{}", s.row());
        entropy_rows.push(json_row("decode", "huf2", threads, &s));
    }

    // HUF3: per-chunk tables + gap arrays. The decode rows are the
    // headline numbers — the gap array lets one chunk's bitstream fan out
    // across pool workers, so decode scales on threads even below one
    // HUF2 chunk of symbols.
    let entropy_opts = huffman::EntropyOptions::default();
    let huf3 = huffman::compress_u16_framed(&codes, 1024, None, &entropy_opts);
    println!("    (huf3: {:.2} bits/code)", huf3.len() as f64 * 8.0 / n as f64);
    for threads in [1usize, 2, 4, 8] {
        let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
        let s = bench(&format!("huffman encode HUF3 {threads}T"), n * 2, opts, || {
            std::hint::black_box(huffman::compress_u16_framed(
                &codes,
                1024,
                pool.as_ref(),
                &entropy_opts,
            ));
        });
        println!("{}", s.row());
        entropy_rows.push(json_row("encode", "huf3", threads, &s));
        let s = bench(&format!("huffman decode HUF3 gap-array {threads}T"), n * 2, opts, || {
            std::hint::black_box(huffman::decompress_u16_pooled(&huf3, pool.as_ref()).unwrap());
        });
        println!("{}", s.row());
        entropy_rows.push(json_row("decode", "huf3-gap", threads, &s));
    }

    // the acceptance case: ONE HUF2-chunk's worth of symbols — a single
    // bitstream — still decodes thread-parallel via its gap array
    let one = &codes[..huffman::CHUNK_SYMS];
    let huf3_one = huffman::compress_u16_framed(one, 1024, None, &entropy_opts);
    for threads in [1usize, 2, 4, 8] {
        let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
        let s = bench(
            &format!("huffman decode HUF3 single-chunk {threads}T"),
            one.len() * 2,
            opts,
            || {
                std::hint::black_box(
                    huffman::decompress_u16_pooled(&huf3_one, pool.as_ref()).unwrap(),
                );
            },
        );
        println!("{}", s.row());
        entropy_rows.push(json_row("decode", "huf3-gap-1chunk", threads, &s));
    }
    write_entropy_json(n, &entropy_rows);

    // outlier-value-like f32 stream for the lossless pass
    let vals: Vec<f32> = (0..500_000).map(|_| 270.0 + rng.next_f32() * 2.0).collect();
    let bytes = vecsz::util::f32_as_bytes(&vals);
    let s = bench("lossless compress (2MB f32 outliers)", bytes.len(), opts, || {
        std::hint::black_box(lossless::compress(bytes));
    });
    println!("{}", s.row());
    let lz = lossless::compress(bytes);
    println!("    (ratio {:.2}x)", bytes.len() as f64 / lz.len() as f64);
    let s = bench("lossless decompress", bytes.len(), opts, || {
        std::hint::black_box(lossless::decompress(&lz).unwrap());
    });
    println!("{}", s.row());

    // block gather (2D)
    let dims = Dims::d2(1024, 1024);
    let field: Vec<f32> = (0..dims.len()).map(|_| rng.next_f32()).collect();
    let bs = 16usize;
    let nb = dims.num_blocks(bs);
    let mut block = vec![0.0f32; bs * bs];
    let s = bench("gather 1Mi-elem 2D field into 16x16 blocks", dims.len() * 4, opts, || {
        for b in 0..nb {
            gather_block(&field, &dims, bs, b, 0.0, &mut block);
            std::hint::black_box(&block);
        }
    });
    println!("{}", s.row());

    // P&Q backends head-to-head on identical batch (the Fig 3 kernel view)
    let shape = BlockShape::new(2, 16);
    let elems = shape.elems();
    let nbb = 4096usize;
    let mut blocks = vec![0.0f32; nbb * elems];
    let mut x = 0.0f32;
    for v in blocks.iter_mut() {
        x += (rng.next_f32() - 0.5) * 0.1;
        *v = x;
    }
    let pads = PadScalars {
        policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
        scalars: vec![0.0],
        ndim: 2,
    };
    let cfg = DqConfig::new(1e-3, 512, shape);
    let mut qcodes = vec![0u16; blocks.len()];
    let mut outv = vec![0.0f32; blocks.len()];
    println!("    (simd backend dispatching to isa: {})", vecsz::simd::Isa::active().name());
    let mut pq_rows: Vec<String> = Vec::new();
    for be in [
        &PszBackend as &dyn PqBackend,
        &VecBackend::new(8),
        &VecBackend::new(16),
        &SimdBackend::new(8),
        &SimdBackend::new(16),
    ] {
        let s = bench(
            &format!("dual-quant kernel [{}] 4Mi elems 2D", be.name()),
            blocks.len() * 4,
            opts,
            || {
                be.run(&cfg, &blocks, 0, &pads, &mut qcodes, &mut outv);
                std::hint::black_box(&qcodes);
            },
        );
        println!("{}", s.row());
        pq_rows.push(json_row("pq-kernel", &be.name(), 1, &s));
    }

    // full P&Q stage (gather + kernel) through pq_stage at 1 and 4 threads
    // — the paper's Fig 3 unit, rows tracked per backend in the perf json
    let pq_dims = Dims::d2(1024, 1024);
    let mut x = 0.0f32;
    let pq_data: Vec<f32> = (0..pq_dims.len())
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    let pq_field = Field::new("pq-bench", pq_dims, pq_data);
    for backend in [
        BackendChoice::Vec { width: 8 },
        BackendChoice::Vec { width: 16 },
        BackendChoice::Simd { width: 8 },
        BackendChoice::Simd { width: 16 },
    ] {
        let be = backend.instantiate();
        for threads in [1usize, 4] {
            let c = Config { eb: EbMode::Abs(1e-3), threads, ..Config::default() };
            let s = bench(
                &format!("pq stage [{}] 1Mi-elem 2D {threads}T", be.name()),
                pq_field.data.len() * 4,
                opts,
                || {
                    std::hint::black_box(pq_stage(&pq_field, &c, be.as_ref()));
                },
            );
            println!("{}", s.row());
            pq_rows.push(json_row("pq", &be.name(), threads, &s));
        }
    }

    // block decode head-to-head: the cascading scalar reference vs the
    // reverse-Lorenzo wavefront backends (rows tracked in BENCH_pq.json —
    // the decode half of the kernel trajectory)
    let mut halo = HaloBlock::new(shape);
    let mut rec = vec![0.0f32; elems];
    let s = bench("decode (cascading Lorenzo reverse) 4Mi elems", blocks.len() * 4, opts, || {
        for b in 0..nbb {
            decode_block_dualquant(
                &cfg,
                &qcodes[b * elems..(b + 1) * elems],
                &outv[b * elems..(b + 1) * elems],
                &pads,
                b,
                &mut halo,
                &mut rec,
            );
            std::hint::black_box(&rec);
        }
    });
    println!("{}", s.row());
    pq_rows.push(json_row("decode-kernel", "block-scalar", 1, &s));

    let mut batch_rec = vec![0.0f32; blocks.len()];
    for de in [
        &ScalarDecodeBackend as &dyn DecodeBackend,
        &SimdDecodeBackend::new(8),
        &SimdDecodeBackend::new(16),
    ] {
        let s = bench(
            &format!("decode kernel [{}] 4Mi elems 2D", de.name()),
            blocks.len() * 4,
            opts,
            || {
                de.decode(CodesKind::DualQuant, &cfg, &qcodes, &outv, 0, &pads, &mut batch_rec);
                std::hint::black_box(&batch_rec);
            },
        );
        println!("{}", s.row());
        pq_rows.push(json_row("decode-kernel", &de.name(), 1, &s));
    }

    // full decode stage (entropy + outlier expansion + block-parallel
    // wavefront reconstruction + scatter) through `decompress` at 1 and 4
    // threads — the decompression mirror of the pq_stage rows above
    let bench_cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, _) = compress(&pq_field, &bench_cfg).expect("bench field compresses");
    for threads in [1usize, 4] {
        let s = bench(
            &format!("decode stage (v1 container) 1Mi-elem 2D {threads}T"),
            pq_field.data.len() * 4,
            opts,
            || {
                std::hint::black_box(decompress(&container, threads).unwrap());
            },
        );
        println!("{}", s.row());
        pq_rows.push(json_row("decode_stage", "v1", threads, &s));
    }
    write_pq_json(&pq_rows);
}

//! `cargo bench --bench table3_amdahl` — regenerates Tables I-III
//! (testbed description, dataset attributes, Amdahl speedup analysis).
fn main() {
    let quick = std::env::var("VECSZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    vecsz::figures::run("table1", "results", quick).expect("table1");
    println!();
    vecsz::figures::run("table2", "results", quick).expect("table2");
    println!();
    vecsz::figures::run("table3", "results", quick).expect("table3");
}

//! Minimal JSON parser (substrate — no serde in the vendored set).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and the results CSV/JSON emitted by the figure harness. Supports the
//! full JSON grammar except `\u` surrogate pairs (kept simple: BMP only).

use crate::error::{Result, VszError};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    /// Typed field access with an error message naming the key.
    pub fn req<'a>(&'a self, key: &str) -> Result<&'a Json> {
        self.get(key).ok_or_else(|| VszError::format(format!("manifest: missing key '{key}'")))
    }
}

/// Escape `s` for embedding in a JSON string literal: `\` and `"` get a
/// backslash, `\n`/`\r`/`\t` their short escapes, and every other control
/// character below 0x20 the `\u00XX` form — so error messages containing
/// newlines or tabs stay valid JSON. Round-trips through [`parse`].
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(VszError::format("json: trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(VszError::format(format!("json: expected '{}' at byte {}", c as char, self.i)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(VszError::format(format!("json: bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(VszError::format(format!("json: unexpected byte at {}", self.i))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(VszError::format("json: expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(VszError::format("json: expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| VszError::format("json: unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| VszError::format("json: bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| VszError::format("json: bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| VszError::format("json: bad \\u"))?,
                                16,
                            )
                            .map_err(|_| VszError::format("json: bad \\u"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| VszError::format("json: surrogate \\u"))?,
                            );
                        }
                        _ => return Err(VszError::format("json: unknown escape")),
                    }
                }
                _ => {
                    // collect UTF-8 continuation bytes as-is
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(VszError::format("json: bad utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| VszError::format("json: bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| VszError::format(format!("json: bad number '{txt}'")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
 "version": 1,
 "radius": 512,
 "artifacts": [
  {"name": "dq_1d_b64_l8_jnp", "file": "dq_1d_b64_l8_jnp.hlo.txt",
   "impl": "jnp", "ndim": 1, "block_size": 64, "lanes": 8, "superbatch": 16384}
 ]
}"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.req("version").unwrap().as_usize(), Some(1));
        let arts = j.req("artifacts").unwrap().as_array().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("lanes").unwrap().as_usize(), Some(8));
        assert_eq!(arts[0].get("impl").unwrap().as_str(), Some("jnp"));
    }

    #[test]
    fn scalar_values() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn nested_and_empty() {
        let j = parse(r#"{"a": [], "b": {}, "c": [1, [2, 3]]}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_array().unwrap().len(), 0);
        assert!(j.get("b").unwrap().get("x").is_none());
        let c = j.get("c").unwrap().as_array().unwrap();
        assert_eq!(c[1].as_array().unwrap()[1].as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn escape_covers_quotes_backslashes_and_every_control_char() {
        // the full hostile set: quote, backslash, the named control chars,
        // and raw control bytes with no short escape
        let nasty = "a\"b\\c\nd\re\tf\u{0}g\u{1b}h\u{1f}i";
        let escaped = escape(nasty);
        assert_eq!(escaped, "a\\\"b\\\\c\\nd\\re\\tf\\u0000g\\u001bh\\u001fi");
        // no raw control characters survive — the escaped text is a legal
        // JSON string body
        assert!(escaped.chars().all(|c| c as u32 >= 0x20));
        let back = parse(&format!("\"{escaped}\"")).unwrap();
        assert_eq!(back.as_str(), Some(nasty));
        // plain text passes through untouched
        assert_eq!(escape("plain text"), "plain text");
    }
}

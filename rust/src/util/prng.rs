//! Deterministic PRNGs (substrate — no `rand` crate in the vendored set).
//!
//! `SplitMix64` for seeding / hashing, `Pcg32` as the general-purpose
//! generator used by the synthetic dataset generators, the autotuner's block
//! sampler and the property-test driver. Both match the published reference
//! outputs (checked in tests below).

/// SplitMix64 — tiny, full-period 2^64 stream; also usable as a hash finalizer.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// One-shot SplitMix64 mixing step — stateless hash of a u64 (used by the
/// lattice-noise generators to derive per-cell gradients).
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// PCG32 (XSH-RR 64/32) — the crate's workhorse generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed with a stream id; distinct `seq` values give independent streams.
    pub fn new(seed: u64, seq: u64) -> Self {
        let mut rng = Self { state: 0, inc: (seq << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed from a single u64 via SplitMix (convenience).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = sm.next_u64();
        let q = sm.next_u64();
        Self::new(s, q)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn bounded(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller (pairs are discarded; simplicity over
    /// speed — generators run at build/bench setup time, not the hot path).
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.bounded(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.bounded((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the canonical C impl).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn pcg_determinism_and_streams() {
        let mut a = Pcg32::new(42, 54);
        let mut b = Pcg32::new(42, 54);
        let mut c = Pcg32::new(42, 55);
        let xs: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let zs: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_ranges() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
            let b = r.bounded(17);
            assert!(b < 17);
        }
    }

    #[test]
    fn bounded_hits_all_residues() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.bounded(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.next_normal() as f64;
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(5);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
    }
}

//! Cross-cutting utilities: deterministic PRNGs, timing helpers, a mini
//! property-testing driver, and small numeric/format helpers.

pub mod json;
pub mod proptest;
pub mod prng;
pub mod timer;

/// crc32 (IEEE, reflected) — container integrity checks.
/// Table-driven; table built at first use.
pub fn crc32(bytes: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

/// Human-readable byte size ("12.3 MiB").
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Reinterpret a f32 slice as bytes (little-endian host assumed; this crate
/// targets x86-64/aarch64 — both LE).
pub fn f32_as_bytes(xs: &[f32]) -> &[u8] {
    // SAFETY: f32 has no invalid bit patterns as bytes, alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

/// Copy bytes into a f32 vec (handles the unaligned case).
pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "byte length must be a multiple of 4");
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard test vector: "123456789" -> 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn human_bytes_rendering() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert!(human_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let bytes = f32_as_bytes(&xs).to_vec();
        assert_eq!(bytes_to_f32(&bytes), xs);
    }
}

//! Cross-cutting utilities: deterministic PRNGs, timing helpers, a mini
//! property-testing driver, and small numeric/format helpers.

pub mod json;
pub mod proptest;
pub mod prng;
pub mod timer;

/// crc32 (IEEE, reflected) — container integrity checks.
/// Table-driven; table built at first use.
pub fn crc32(bytes: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

/// A `Send + Sync` wrapper around a raw mutable pointer, for scoped
/// parallel loops whose workers write **disjoint** regions of one buffer.
///
/// The borrow checker cannot express "these `&mut` regions are disjoint by
/// an index computation", so the hot loops in `compressor` (and anything
/// else that partitions one output buffer across workers) smuggle the base
/// pointer into the worker closures through this wrapper and re-derive
/// their slice with `std::slice::from_raw_parts_mut`.
///
/// # Safety contract (callers must uphold all of these)
/// * Every region derived from the pointer is **disjoint** between
///   concurrently running workers (no element is written by two workers,
///   and nobody reads a region another worker writes).
/// * All derived regions stay inside the allocation the pointer was taken
///   from.
/// * The underlying buffer outlives every worker (guaranteed when workers
///   run inside `std::thread::scope` / `parallel_chunks_mut`, which join
///   before the enclosing frame returns).
pub struct SendPtr<T>(*mut T);

// SAFETY: sending/sharing the pointer itself is safe; all dereferences are
// governed by the disjointness contract documented above.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> Self {
        Self(p)
    }

    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Human-readable byte size ("12.3 MiB").
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Reinterpret a f32 slice as bytes (little-endian host assumed; this crate
/// targets x86-64/aarch64 — both LE).
pub fn f32_as_bytes(xs: &[f32]) -> &[u8] {
    // SAFETY: f32 has no invalid bit patterns as bytes, alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

/// Reinterpret a mutable f32 slice as bytes (LE host, like
/// [`f32_as_bytes`]) — lets readers fill an f32 slab directly, with no
/// per-chunk byte→f32 conversion buffer.
pub fn f32_as_bytes_mut(xs: &mut [f32]) -> &mut [u8] {
    // SAFETY: every byte pattern is a valid f32 and vice versa, alignment
    // of u8 is 1, and the borrow is exclusive for the returned lifetime.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut u8, xs.len() * 4) }
}

/// Copy bytes into a f32 vec (handles the unaligned case).
pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "byte length must be a multiple of 4");
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard test vector: "123456789" -> 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn human_bytes_rendering() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert!(human_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let xs = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let bytes = f32_as_bytes(&xs).to_vec();
        assert_eq!(bytes_to_f32(&bytes), xs);
    }

    #[test]
    fn f32_bytes_mut_fills_in_place() {
        let mut xs = vec![0.0f32; 2];
        let b = f32_as_bytes_mut(&mut xs);
        b[..4].copy_from_slice(&1.5f32.to_le_bytes());
        b[4..].copy_from_slice(&(-2.25f32).to_le_bytes());
        assert_eq!(xs, vec![1.5, -2.25]);
    }
}

//! Timing helpers used by the bench harness, the autotuner and the
//! coordinator's per-stage profile (the paper times stages with C++
//! `high_resolution_clock`; we use `std::time::Instant`).

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart and return the lap duration in seconds.
    pub fn lap_s(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.start).as_secs_f64();
        self.start = now;
        dt
    }
}

/// Time a closure; returns (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

/// MB/s throughput given bytes processed in `secs` (paper reports MB/s with
/// MB = 1e6 bytes; we follow that convention everywhere).
pub fn mb_per_s(bytes: usize, secs: f64) -> f64 {
    if secs <= 0.0 {
        return f64::INFINITY;
    }
    bytes as f64 / 1e6 / secs
}

/// Accumulates per-stage wall time for a pipeline run (Table III input).
#[derive(Debug, Default, Clone)]
pub struct StageProfile {
    entries: Vec<(String, f64)>,
}

impl StageProfile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, stage: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == stage) {
            e.1 += secs;
        } else {
            self.entries.push((stage.to_string(), secs));
        }
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|e| e.1).sum()
    }

    pub fn get(&self, stage: &str) -> f64 {
        self.entries.iter().find(|e| e.0 == stage).map(|e| e.1).unwrap_or(0.0)
    }

    /// Fraction of total time spent in `stage` (Table III's "Dual-Quant % of
    /// Runtime" row).
    pub fn fraction(&self, stage: &str) -> f64 {
        let t = self.total();
        if t == 0.0 {
            0.0
        } else {
            self.get(stage) / t
        }
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    pub fn merge(&mut self, other: &StageProfile) {
        for (s, t) in &other.entries {
            self.add(s, *t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_s() >= 0.002);
    }

    #[test]
    fn throughput_math() {
        assert_eq!(mb_per_s(2_000_000, 1.0), 2.0);
        assert!(mb_per_s(1, 0.0).is_infinite());
    }

    #[test]
    fn stage_profile_accumulates() {
        let mut p = StageProfile::new();
        p.add("dualquant", 0.3);
        p.add("huffman", 0.5);
        p.add("dualquant", 0.2);
        assert!((p.get("dualquant") - 0.5).abs() < 1e-12);
        assert!((p.total() - 1.0).abs() < 1e-12);
        assert!((p.fraction("dualquant") - 0.5).abs() < 1e-12);
    }
}

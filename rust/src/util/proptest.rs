//! Mini property-testing driver (substrate — the `proptest` crate is not in
//! the vendored set).
//!
//! A property is a closure from a seeded [`Gen`] to `Result<(), String>`.
//! The driver runs `cases` random cases; on failure it retries the failing
//! seed with progressively smaller size hints ("shrinking-lite") and reports
//! the smallest failing seed/size so the case is reproducible.

use crate::util::prng::Pcg32;

/// Random-value source handed to properties; carries a size hint that the
/// driver lowers while shrinking.
pub struct Gen {
    pub rng: Pcg32,
    /// Soft upper bound for "how big" generated structures should be.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self { rng: Pcg32::seeded(seed), size }
    }

    /// Length in [1, size].
    pub fn len(&mut self) -> usize {
        1 + self.rng.bounded(self.size.max(1) as u32) as usize
    }

    /// Uniform f32 in [-scale, scale].
    pub fn f32_in(&mut self, scale: f32) -> f32 {
        (self.rng.next_f32() * 2.0 - 1.0) * scale
    }

    /// Vec of uniform f32 in [-scale, scale].
    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(scale)).collect()
    }

    /// Smooth-ish f32 vec (random walk) — predicts well under Lorenzo, so
    /// properties exercise the in-cap path too.
    pub fn smooth_vec(&mut self, n: usize, step: f32) -> Vec<f32> {
        let mut v = Vec::with_capacity(n);
        let mut x = self.f32_in(1.0);
        for _ in 0..n {
            x += self.f32_in(step);
            v.push(x);
        }
        v
    }

    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| self.rng.next_u32() as u8).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.bounded(xs.len() as u32) as usize]
    }
}

/// Run `cases` random cases of `prop`; panic with the failing seed on error.
pub fn check(name: &str, cases: u32, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let base_seed = 0x5ECDEF00u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let size = 4 + (case as usize % 64) * 4; // grow sizes across cases
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // shrinking-lite: retry same seed at smaller sizes, report the
            // smallest size that still fails.
            let mut min_fail = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut g2 = Gen::new(seed, s);
                match prop(&mut g2) {
                    Err(m) => min_fail = (s, m),
                    Ok(()) => break,
                }
                if s == 1 {
                    break;
                }
                s /= 2;
            }
            panic!(
                "property '{name}' failed: seed={seed:#x} size={} (case {case}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 50, |g| {
            n += 1;
            let v = g.f32_vec(g.size.min(8), 1.0);
            if v.iter().all(|x| x.abs() <= 1.0) {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed")]
    fn failing_property_reports_seed() {
        check("boom", 10, |g| {
            if g.size > 2 {
                Err("too big".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn smooth_vec_is_smooth() {
        let mut g = Gen::new(1, 32);
        let v = g.smooth_vec(100, 0.1);
        for w in v.windows(2) {
            assert!((w[1] - w[0]).abs() <= 0.1 + 1e-6);
        }
    }
}

//! `Dataset`: open a VSZ3 container once, serve many region reads through a
//! memory-bounded decoded-chunk cache.
//!
//! The v3 index footer makes every chunk independently decodable, but the
//! [`StreamDecompressor`] random-access methods re-decode their chunks on
//! every call and force the caller to pick an axis-specific entry point.
//! This module turns that into an open-once / read-many handle:
//!
//! * [`Region`] is the one selector — `Chunk(k)`, `Chunks(range)`,
//!   `Rows(range)`, `Dim { dim, range }` or `All` — and
//!   [`Dataset::read`] is the one entry point. Every variant resolves to
//!   the same chunk-fetch + gather core the legacy methods now wrap, so
//!   results are bit-identical to them at any thread count.
//! * [`ChunkCache`] holds decoded slabs (`Arc<Vec<f32>>`) keyed by
//!   `(container, chunk)` under an LRU policy. **Cache-bounding
//!   invariant:** after every insert the least-recently-used slabs are
//!   evicted until resident bytes are `<= budget` — the budget is a hard
//!   ceiling, even when that means evicting the slab just inserted; a
//!   budget of 0 disables residency entirely. Hits, misses, evictions and
//!   resident bytes are atomic [`metrics::CacheStats`] gauges, readable
//!   without the cache lock.
//! * **Single-flight invariant:** at most one decode of a given chunk is
//!   in flight at a time. The first reader to miss claims the chunk and
//!   decodes it; concurrent readers of the same chunk block on the claim
//!   and receive the claimer's slab directly (even with a zero budget),
//!   so N readers of a cold chunk cost exactly one decode. A claimer that
//!   fails or unwinds publishes an error to its waiters — nobody blocks
//!   forever on an abandoned claim.
//! * Misses are filled **chunk-parallel**: the claimed chunks of a read
//!   decode as one batch on the dataset's `coordinator` pool (shared with
//!   `vsz serve`, or private to the handle). `Dim`-axis reads fetch in
//!   pool-sized batches so memory stays bounded by the batch plus the
//!   gathered output, exactly like the legacy `decode_dim`.
//!
//! [`Dataset`] is `Sync`: the reader sits behind a mutex (frame parse is
//! cheap I/O; the expensive decode happens outside it) and the cache does
//! its own locking, so one handle serves concurrent readers.
//!
//! [`metrics::CacheStats`]: crate::metrics::CacheStats

use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Seek};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::compressor::decode_body;
use crate::coordinator::pool::ThreadPool;
use crate::error::{Result, VszError};
use crate::format::StreamHeader;
use crate::metrics::{CacheSnapshot, CacheStats};

use super::{decode_batch, gather_dim_range, ChunkIndex, StreamDecompressor};

/// What to read: the one selector behind [`Dataset::read`].
///
/// Migration from the deprecated [`StreamDecompressor`] methods:
/// `decode_chunk(k)` → `Chunk(k)`, `decode_range(r, _)` → `Chunks(r)`,
/// `decode_rows(r, _)` → `Rows(r)`, `decode_dim(d, r, _)` →
/// `Dim { dim: d, range: r }`, `decode_cols(r, _)` →
/// `Dim { dim: ndim - 1, range: r }`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Region {
    /// One chunk's whole slab, in field order.
    Chunk(usize),
    /// A contiguous chunk range's slabs, concatenated in field order.
    Chunks(Range<usize>),
    /// Leading-dimension rows `[start, end)` — touches only the covering
    /// chunks.
    Rows(Range<usize>),
    /// The sub-field whose `dim`-axis extent is clipped to `range` (all
    /// other axes full), in field row-major order. `dim = 0` is the same
    /// as `Rows`.
    Dim { dim: usize, range: Range<usize> },
    /// The whole field.
    All,
}

/// How a resolved region pulls values out of each decoded slab.
pub(crate) enum Gather {
    /// Append whole slabs (chunks tile the field, so concatenation is the
    /// field order).
    Slabs,
    /// Append each slab's overlap with this global row range.
    Rows(Range<usize>),
    /// Append each slab's `dim`-axis clip (dim >= 1; every chunk
    /// overlaps).
    DimRange { dim: usize, range: Range<usize>, kept_row: usize },
}

/// A validated region: which chunks to fetch and how to gather them.
pub(crate) struct RegionPlan {
    pub(crate) chunks: Range<usize>,
    pub(crate) gather: Gather,
    pub(crate) out_len: usize,
}

/// Validate `region` against the container geometry and plan the fetch.
/// The bounds checks (and their error text) match the legacy methods.
pub(crate) fn resolve_region(
    header: &StreamHeader,
    index: &ChunkIndex,
    region: &Region,
) -> Result<RegionPlan> {
    let dims = header.header.dims;
    let n = index.n_chunks();
    let row_elems = dims.shape[1] * dims.shape[2];
    match region {
        Region::Chunk(k) => {
            if *k >= n {
                return Err(VszError::config(format!(
                    "chunk {k} out of range (container has {n})"
                )));
            }
            let extent = index.entries[*k].lead_extent as usize;
            let out_len = extent * row_elems;
            Ok(RegionPlan { chunks: *k..*k + 1, gather: Gather::Slabs, out_len })
        }
        Region::Chunks(r) => {
            if r.start >= r.end || r.end > n {
                return Err(VszError::config(format!(
                    "chunk range {}..{} out of range (container has {n})",
                    r.start, r.end
                )));
            }
            let rows: usize = r.clone().map(|k| index.entries[k].lead_extent as usize).sum();
            Ok(RegionPlan { chunks: r.clone(), gather: Gather::Slabs, out_len: rows * row_elems })
        }
        Region::Rows(rows) => {
            let total = dims.shape[0];
            if rows.start >= rows.end || rows.end > total {
                return Err(VszError::config(format!(
                    "row range {}..{} out of range (field has {total} rows)",
                    rows.start, rows.end
                )));
            }
            // lead_offsets is sorted and starts at 0, so the covering
            // chunk of a row is the last offset <= it
            let chunk_of = |row: usize| match index.lead_offsets.binary_search(&row) {
                Ok(i) => i,
                Err(i) => i - 1,
            };
            let first = chunk_of(rows.start);
            let last = chunk_of(rows.end - 1);
            Ok(RegionPlan {
                chunks: first..last + 1,
                gather: Gather::Rows(rows.clone()),
                out_len: rows.len() * row_elems,
            })
        }
        Region::Dim { dim, range } => {
            if *dim >= dims.ndim {
                return Err(VszError::config(format!(
                    "dim {dim} out of range (field has {} dims)",
                    dims.ndim
                )));
            }
            if *dim == 0 {
                return resolve_region(header, index, &Region::Rows(range.clone()));
            }
            let total = dims.shape[*dim];
            if range.start >= range.end || range.end > total {
                return Err(VszError::config(format!(
                    "dim-{dim} range {}..{} out of range (extent {total})",
                    range.start, range.end
                )));
            }
            let kept_row = match dim {
                1 => range.len() * dims.shape[2],
                _ => range.len(),
            };
            Ok(RegionPlan {
                chunks: 0..n,
                gather: Gather::DimRange { dim: *dim, range: range.clone(), kept_row },
                out_len: dims.len() / dims.shape[*dim] * range.len(),
            })
        }
        Region::All => Ok(RegionPlan { chunks: 0..n, gather: Gather::Slabs, out_len: dims.len() }),
    }
}

/// Append the gathered part of chunk `k`'s slab to `out`. Chunks arrive in
/// lead order, so plain appending reassembles the sub-field.
pub(crate) fn gather_into(
    slab: &[f32],
    k: usize,
    header: &StreamHeader,
    index: &ChunkIndex,
    gather: &Gather,
    out: &mut Vec<f32>,
) {
    let dims = header.header.dims;
    match gather {
        Gather::Slabs => out.extend_from_slice(slab),
        Gather::Rows(rows) => {
            let row_elems = dims.shape[1] * dims.shape[2];
            let lead = index.lead_offsets[k];
            let extent = index.entries[k].lead_extent as usize;
            let lo = rows.start.max(lead) - lead;
            let hi = rows.end.min(lead + extent) - lead;
            out.extend_from_slice(&slab[lo * row_elems..hi * row_elems]);
        }
        Gather::DimRange { dim, range, kept_row } => {
            let extent = index.entries[k].lead_extent as usize;
            gather_dim_range(slab, extent, dims, *dim, range, *kept_row, out);
        }
    }
}

/// Uncached region read over a bare decoder — the shared core behind the
/// deprecated `decode_*` methods. Same resolution, same gather, same
/// batching and pool policy they always had, so outputs stay bit-identical.
pub(crate) fn read_region_uncached<R: Read + Seek>(
    dec: &mut StreamDecompressor<R>,
    region: &Region,
    threads: usize,
) -> Result<Vec<f32>> {
    dec.load_index()?;
    let header = *dec.header();
    let index = dec.index.as_ref().unwrap().clone();
    let plan = resolve_region(&header, &index, region)?;
    let threads = threads.max(1);
    let n = plan.chunks.len();
    let pool = if threads > 1 && n > 1 { Some(ThreadPool::new(threads)) } else { None };
    // Dim reads touch every chunk, so they fetch in pool-sized batches to
    // bound memory; the other shapes decode their whole (already pruned)
    // range as one batch, exactly like the legacy methods.
    let batch_cap = match plan.gather {
        Gather::DimRange { .. } => threads.max(2),
        _ => n.max(1),
    };
    let mut out = Vec::with_capacity(plan.out_len);
    let mut k = plan.chunks.start;
    while k < plan.chunks.end {
        let take = (plan.chunks.end - k).min(batch_cap);
        let mut batch = Vec::with_capacity(take);
        for kk in k..k + take {
            batch.push(dec.parse_indexed_frame(kk)?);
        }
        let slabs = decode_batch(batch, pool.as_ref())?;
        for (i, slab) in slabs.iter().enumerate() {
            gather_into(slab, k + i, &header, &index, &plan.gather, &mut out);
        }
        k += take;
    }
    Ok(out)
}

/// Stable identity for a container's cache entries when one [`ChunkCache`]
/// is shared across containers (the `vsz serve` case, where each request
/// carries its own body): FNV-1a 64 over the container bytes.
pub fn container_fingerprint(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

type Key = (u64, u32);
type SlabResult = std::result::Result<Arc<Vec<f32>>, String>;

/// One in-flight decode: waiters block on `ready` until the claimer
/// publishes a slab (or an error) into `slot`.
#[derive(Default)]
struct Flight {
    slot: Mutex<Option<SlabResult>>,
    ready: Condvar,
}

impl Flight {
    fn wait(&self) -> Result<Arc<Vec<f32>>> {
        let mut slot = self.slot.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            match slot.as_ref() {
                Some(Ok(slab)) => return Ok(Arc::clone(slab)),
                Some(Err(msg)) => return Err(VszError::runtime(msg.clone())),
                None => slot = self.ready.wait(slot).unwrap_or_else(|p| p.into_inner()),
            }
        }
    }
}

struct Resident {
    data: Arc<Vec<f32>>,
    /// This entry's position in the LRU order (its key in `lru`).
    tick: u64,
    bytes: u64,
}

#[derive(Default)]
struct CacheState {
    slabs: HashMap<Key, Resident>,
    /// Recency order: ascending tick = least- to most-recently used.
    lru: BTreeMap<u64, Key>,
    tick: u64,
    resident_bytes: u64,
    inflight: HashMap<Key, Arc<Flight>>,
}

enum Lookup {
    /// Resident — counted as a hit, recency refreshed.
    Hit(Arc<Vec<f32>>),
    /// Another reader is decoding it — wait for their slab (also a hit:
    /// served without a decode of our own).
    Pending(Arc<Flight>),
    /// The caller now owns the decode and MUST publish a result.
    Claimed,
}

/// Memory-bounded LRU cache of decoded chunk slabs with single-flight
/// miss filling. Sharable across [`Dataset`] handles (and across request
/// containers via [`container_fingerprint`] keys).
pub struct ChunkCache {
    budget: u64,
    state: Mutex<CacheState>,
    stats: CacheStats,
}

impl ChunkCache {
    /// A cache holding at most `budget_bytes` of decoded slabs; 0 disables
    /// residency (single-flight dedup still applies).
    pub fn new(budget_bytes: u64) -> Self {
        Self {
            budget: budget_bytes,
            state: Mutex::new(CacheState::default()),
            stats: CacheStats::default(),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// The live hit/miss/eviction/resident gauges (lock-free reads).
    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Number of resident slabs right now (test/diagnostic aid).
    pub fn resident_chunks(&self) -> usize {
        self.state.lock().unwrap_or_else(|p| p.into_inner()).slabs.len()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, CacheState> {
        // Poison recovery is sound here: every mutation below keeps
        // slabs/lru/resident_bytes consistent before releasing the lock.
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn lookup_or_claim(&self, key: Key) -> Lookup {
        let mut st = self.lock();
        if st.slabs.contains_key(&key) {
            st.tick += 1;
            let tick = st.tick;
            let r = st.slabs.get_mut(&key).unwrap();
            let old = r.tick;
            r.tick = tick;
            let data = Arc::clone(&r.data);
            st.lru.remove(&old);
            st.lru.insert(tick, key);
            self.stats.record_hit();
            return Lookup::Hit(data);
        }
        if let Some(fl) = st.inflight.get(&key) {
            self.stats.record_hit();
            return Lookup::Pending(Arc::clone(fl));
        }
        st.inflight.insert(key, Arc::new(Flight::default()));
        self.stats.record_miss();
        Lookup::Claimed
    }

    /// Resolve a claim: wake the waiters with `res`, then (on success and
    /// a non-zero budget) make the slab resident and enforce the budget by
    /// evicting least-recently-used slabs — strictly, even if that evicts
    /// the slab just inserted.
    fn publish(&self, key: Key, res: SlabResult) {
        let mut st = self.lock();
        if let Some(fl) = st.inflight.remove(&key) {
            *fl.slot.lock().unwrap_or_else(|p| p.into_inner()) = Some(res.clone());
            fl.ready.notify_all();
        }
        let data = match res {
            Ok(data) if self.budget > 0 => data,
            _ => return,
        };
        let bytes = (data.len() * std::mem::size_of::<f32>()) as u64;
        st.tick += 1;
        let tick = st.tick;
        if let Some(old) = st.slabs.insert(key, Resident { data, tick, bytes }) {
            st.lru.remove(&old.tick);
            st.resident_bytes -= old.bytes;
            self.stats.sub_resident(old.bytes);
        }
        st.lru.insert(tick, key);
        st.resident_bytes += bytes;
        self.stats.add_resident(bytes);
        while st.resident_bytes > self.budget {
            let (&t, &k) = match st.lru.iter().next() {
                Some(e) => e,
                None => break,
            };
            st.lru.remove(&t);
            if let Some(r) = st.slabs.remove(&k) {
                st.resident_bytes -= r.bytes;
                self.stats.sub_resident(r.bytes);
                self.stats.record_eviction();
            }
        }
    }
}

/// Unwind safety for claimed chunks: publishes an error for every claim
/// not yet resolved, so waiters never block on a claimer that panicked or
/// bailed early.
struct ClaimGuard<'a> {
    cache: &'a ChunkCache,
    pending: Vec<Key>,
}

impl ClaimGuard<'_> {
    fn publish(&mut self, key: Key, res: SlabResult) {
        self.pending.retain(|k| *k != key);
        self.cache.publish(key, res);
    }
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        for k in self.pending.drain(..) {
            self.cache.publish(k, Err("chunk decode abandoned by its claimer".into()));
        }
    }
}

/// Construction knobs for a self-contained [`Dataset`].
#[derive(Clone, Copy, Debug)]
pub struct DatasetOptions {
    /// Decode parallelism for miss fills (the one thread setting — the
    /// per-call `threads` parameters of the legacy methods are deprecated
    /// in its favor).
    pub threads: usize,
    /// Decoded-slab cache budget in bytes; 0 disables caching.
    pub cache_bytes: u64,
}

impl Default for DatasetOptions {
    fn default() -> Self {
        Self { threads: 1, cache_bytes: 64 << 20 }
    }
}

/// Open-once random-access handle over a VSZ3 container: owns the reader
/// and the loaded index, serves [`Region`] reads through a [`ChunkCache`].
/// See the [module docs](self) for the cache-bounding and single-flight
/// invariants.
pub struct Dataset<R: Read + Seek> {
    reader: Mutex<StreamDecompressor<R>>,
    header: StreamHeader,
    index: ChunkIndex,
    cache: Arc<ChunkCache>,
    container_id: u64,
    pool: Option<Arc<ThreadPool>>,
    threads: usize,
    /// Chunk decodes performed by this handle — the test hook proving a
    /// warm read decodes nothing.
    decodes: AtomicU64,
}

impl<R: Read + Seek> Dataset<R> {
    /// Open with [`DatasetOptions::default`]: single-threaded fills, a
    /// private 64 MiB cache.
    pub fn open(reader: R) -> Result<Self> {
        Self::open_with(reader, DatasetOptions::default())
    }

    /// Open with a private cache and (for `threads > 1`) a private pool.
    /// Errors on pre-v3 containers (no index, no random access).
    pub fn open_with(reader: R, opts: DatasetOptions) -> Result<Self> {
        let threads = opts.threads.max(1);
        let pool = if threads > 1 { Some(Arc::new(ThreadPool::new(threads))) } else { None };
        Self::build(reader, threads, Arc::new(ChunkCache::new(opts.cache_bytes)), 0, pool)
    }

    /// Open against a shared cache and pool (the `vsz serve` shape: one
    /// server-wide cache, one worker pool, a short-lived handle per
    /// request). `container_id` namespaces this container's chunks within
    /// the shared cache — see [`container_fingerprint`].
    pub fn open_shared(
        reader: R,
        threads: usize,
        cache: Arc<ChunkCache>,
        container_id: u64,
        pool: Option<Arc<ThreadPool>>,
    ) -> Result<Self> {
        Self::build(reader, threads.max(1), cache, container_id, pool)
    }

    fn build(
        reader: R,
        threads: usize,
        cache: Arc<ChunkCache>,
        container_id: u64,
        pool: Option<Arc<ThreadPool>>,
    ) -> Result<Self> {
        let mut dec = StreamDecompressor::new(reader)?;
        let index = dec.load_index()?.clone();
        let header = *dec.header();
        Ok(Self {
            reader: Mutex::new(dec),
            header,
            index,
            cache,
            container_id,
            pool,
            threads,
            decodes: AtomicU64::new(0),
        })
    }

    pub fn header(&self) -> &StreamHeader {
        &self.header
    }

    pub fn n_chunks(&self) -> usize {
        self.index.n_chunks()
    }

    /// The leading-dim row range chunk `k` covers, if it exists.
    pub fn chunk_rows(&self, k: usize) -> Option<Range<usize>> {
        let e = self.index.entries.get(k)?;
        let lo = self.index.lead_offsets[k];
        Some(lo..lo + e.lead_extent as usize)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn cache(&self) -> &ChunkCache {
        &self.cache
    }

    /// Snapshot of the cache gauges (shared caches aggregate across
    /// handles).
    pub fn cache_stats(&self) -> CacheSnapshot {
        self.cache.stats().snapshot()
    }

    /// Chunk decodes this handle has performed — stays flat across
    /// warm-cache reads.
    pub fn decode_count(&self) -> u64 {
        self.decodes.load(Ordering::Relaxed)
    }

    /// Read one region, bit-identical to the legacy `decode_*` method for
    /// the same selection at any thread count. Resident chunks are served
    /// from the cache; missing chunks decode once (single-flight) on the
    /// pool and become resident within the byte budget.
    pub fn read(&self, region: Region) -> Result<Vec<f32>> {
        let plan = resolve_region(&self.header, &self.index, &region)?;
        let batch_cap = match plan.gather {
            Gather::DimRange { .. } => self.threads.max(2),
            _ => plan.chunks.len().max(1),
        };
        let mut out = Vec::with_capacity(plan.out_len);
        let mut k = plan.chunks.start;
        while k < plan.chunks.end {
            let take = (plan.chunks.end - k).min(batch_cap);
            let slabs = self.fetch_chunks(k..k + take)?;
            for (i, slab) in slabs.iter().enumerate() {
                gather_into(slab, k + i, &self.header, &self.index, &plan.gather, &mut out);
            }
            k += take;
        }
        Ok(out)
    }

    /// Fetch one contiguous chunk range as slabs: classify every chunk as
    /// resident / in-flight elsewhere / claimed here, decode the claims as
    /// one chunk-parallel batch, publish them, then collect the waits.
    fn fetch_chunks(&self, chunks: Range<usize>) -> Result<Vec<Arc<Vec<f32>>>> {
        let base = chunks.start;
        let mut slots: Vec<Option<Arc<Vec<f32>>>> = vec![None; chunks.len()];
        let mut waits: Vec<(usize, Arc<Flight>)> = Vec::new();
        let mut claimed: Vec<usize> = Vec::new();
        for (i, k) in chunks.enumerate() {
            match self.cache.lookup_or_claim(self.key(k)) {
                Lookup::Hit(slab) => slots[i] = Some(slab),
                Lookup::Pending(fl) => waits.push((i, fl)),
                Lookup::Claimed => claimed.push(k),
            }
        }
        let keys: Vec<Key> = claimed.iter().map(|&k| self.key(k)).collect();
        let mut guard = ClaimGuard { cache: &self.cache, pending: keys };
        if !claimed.is_empty() {
            // Parse the claimed frames under the reader lock; decode
            // outside it so concurrent readers of other chunks are not
            // serialized behind the expensive part.
            let mut frames = Vec::with_capacity(claimed.len());
            {
                let mut dec = self.reader.lock().unwrap_or_else(|p| p.into_inner());
                for &k in &claimed {
                    // On error the guard publishes the abandonment to any
                    // waiters of the remaining claims.
                    match dec.parse_indexed_frame(k) {
                        Ok(frame) => frames.push(frame),
                        Err(err) if self.index.parity.is_some() => {
                            // transparent recovery: a single lost frame per
                            // parity group rebuilds from the XOR of the
                            // survivors (CRC-gated); only a ≥2-loss group
                            // still surfaces the original error
                            match dec.rebuild_indexed_frame(k) {
                                Ok(frame) => {
                                    self.cache.stats().record_repair();
                                    frames.push(frame);
                                }
                                Err(_) => return Err(err),
                            }
                        }
                        Err(err) => return Err(err),
                    }
                }
            }
            let decodes = &self.decodes;
            let job = move |i: usize| -> Result<Vec<f32>> {
                crate::failpoint::hit("chunk_decode")?;
                decodes.fetch_add(1, Ordering::Relaxed);
                let (h, sections) = &frames[i];
                decode_body(h, sections, 1)
            };
            let results: Vec<Result<Vec<f32>>> = match &self.pool {
                Some(pool) if claimed.len() > 1 => pool.scoped_scatter_gather(claimed.len(), job),
                _ => (0..claimed.len()).map(job).collect(),
            };
            let mut first_err: Option<VszError> = None;
            for (&k, res) in claimed.iter().zip(results) {
                match res {
                    Ok(slab) => {
                        let slab = Arc::new(slab);
                        slots[k - base] = Some(Arc::clone(&slab));
                        guard.publish(self.key(k), Ok(slab));
                    }
                    Err(e) => {
                        guard.publish(self.key(k), Err(e.to_string()));
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
        }
        for (i, fl) in waits {
            slots[i] = Some(fl.wait()?);
        }
        Ok(slots.into_iter().map(|s| s.expect("every chunk classified")).collect())
    }

    fn key(&self, k: usize) -> Key {
        (self.container_id, k as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slab(key: Key, n: usize) -> (Key, SlabResult) {
        (key, Ok(Arc::new(vec![key.1 as f32; n])))
    }

    #[test]
    fn cache_hits_after_publish_and_tracks_bytes() {
        let c = ChunkCache::new(1 << 20);
        let key = (7u64, 3u32);
        assert!(matches!(c.lookup_or_claim(key), Lookup::Claimed));
        let (_, res) = slab(key, 100);
        c.publish(key, res);
        match c.lookup_or_claim(key) {
            Lookup::Hit(s) => assert_eq!(s.len(), 100),
            _ => panic!("expected a hit"),
        }
        let snap = c.stats().snapshot();
        assert_eq!((snap.hits, snap.misses, snap.evictions), (1, 1, 0));
        assert_eq!(snap.resident_bytes, 400);
        assert_eq!(c.resident_chunks(), 1);
    }

    #[test]
    fn cache_evicts_least_recently_used_to_stay_under_budget() {
        // budget fits two 100-element slabs, not three
        let c = ChunkCache::new(900);
        for k in 0..3u32 {
            let key = (0, k);
            assert!(matches!(c.lookup_or_claim(key), Lookup::Claimed));
            if k == 2 {
                // touch chunk 0 so chunk 1 is the LRU victim
                match c.lookup_or_claim((0, 0)) {
                    Lookup::Hit(_) => {}
                    _ => panic!("chunk 0 should be resident"),
                }
            }
            let (_, res) = slab(key, 100);
            c.publish(key, res);
        }
        let snap = c.stats().snapshot();
        assert_eq!(snap.evictions, 1);
        assert!(snap.resident_bytes <= 900, "resident {}", snap.resident_bytes);
        assert!(matches!(c.lookup_or_claim((0, 1)), Lookup::Claimed), "LRU chunk 1 evicted");
        c.publish((0, 1), Err("abandon the re-claim".into()));
    }

    #[test]
    fn zero_budget_disables_residency_but_not_single_flight() {
        let c = ChunkCache::new(0);
        let key = (0u64, 0u32);
        assert!(matches!(c.lookup_or_claim(key), Lookup::Claimed));
        // a second reader meanwhile joins the same flight
        let fl = match c.lookup_or_claim(key) {
            Lookup::Pending(fl) => fl,
            _ => panic!("expected to join the in-flight decode"),
        };
        let (_, res) = slab(key, 10);
        c.publish(key, res);
        assert_eq!(fl.wait().unwrap().len(), 10);
        assert_eq!(c.resident_chunks(), 0);
        assert_eq!(c.stats().snapshot().resident_bytes, 0);
        // next lookup is a fresh claim, not a hit
        assert!(matches!(c.lookup_or_claim(key), Lookup::Claimed));
        c.publish(key, Err("done".into()));
    }

    #[test]
    fn claim_guard_publishes_abandonment_to_waiters() {
        let c = ChunkCache::new(1 << 20);
        let key = (1u64, 9u32);
        assert!(matches!(c.lookup_or_claim(key), Lookup::Claimed));
        let fl = match c.lookup_or_claim(key) {
            Lookup::Pending(fl) => fl,
            _ => panic!("expected pending"),
        };
        drop(ClaimGuard { cache: &c, pending: vec![key] });
        let err = fl.wait().unwrap_err().to_string();
        assert!(err.contains("abandoned"), "unexpected error: {err}");
        // the claim slot was cleared — the chunk is claimable again
        assert!(matches!(c.lookup_or_claim(key), Lookup::Claimed));
        c.publish(key, Err("cleanup".into()));
    }

    #[test]
    fn fingerprint_separates_containers() {
        let a = container_fingerprint(b"VSZ3-container-a");
        let b = container_fingerprint(b"VSZ3-container-b");
        assert_ne!(a, b);
        assert_eq!(a, container_fingerprint(b"VSZ3-container-a"));
    }
}

//! Streaming chunked-container engine — compress/decompress fields larger
//! than RAM in bounded memory over `std::io::Read`/`Write`.
//!
//! The v2 container (see [`crate::format`]) frames a field as a sequence of
//! independently-decodable **chunks**: contiguous slabs along the leading
//! dimension, each a whole number of block rows, each carrying its own
//! CODES / OUTLIER_POS / OUTLIER_VAL / PAD_SCALARS sections with per-section
//! CRCs. Because row-major slabs are contiguous in memory, a chunk is
//! exactly a sub-field and reuses the whole-field encode/decode cores
//! ([`crate::compressor`]): same backends, same bitstreams, same error
//! bound per element.
//!
//! * [`StreamCompressor`] accepts samples incrementally (`push`) and emits
//!   one frame per completed slab. Memory is bounded by
//!   `chunk_elems × in-flight window`, never the whole field, and never a
//!   full-field codes buffer.
//! * With `threads > 1` the compressor pipelines **across chunks** through
//!   the [`ThreadPool`]: chunk N compresses on a worker while chunk N+1
//!   gathers on the caller's thread (cuSZ-style coarse-grained
//!   parallelism). Frames are re-ordered before writing, so the output
//!   bytes are identical for every thread count.
//! * [`StreamDecompressor`] reads frames one at a time;
//!   [`decompress_stream`]/[`decompress_chunked`] decode batches of chunks
//!   concurrently via [`ThreadPool::scatter_gather`] — byte-identical to
//!   serial decode because slabs are assembled by offset.
//! * The default output is the **v3 indexed container**: a CRC'd,
//!   length-suffixed footer records every chunk's byte range, slab extent
//!   and encode config, so a `Read + Seek` reader can decode an arbitrary
//!   part of a huge field reading only the header, the footer and the
//!   frames it needs. Random access lives behind [`dataset::Dataset`]:
//!   open the container once, then [`read`](dataset::Dataset::read) any
//!   [`dataset::Region`] (`Chunk` / `Chunks` / `Rows` / `Dim` / `All`)
//!   through a memory-bounded decoded-chunk LRU cache with single-flight,
//!   chunk-parallel miss filling. The older per-call
//!   `StreamDecompressor::decode_*` methods are deprecated thin wrappers
//!   over the same resolution and gather core, so their results stay
//!   bit-identical to `Dataset::read` at any thread count.
//! * With [`StreamOptions::chunk_autotune`] the compressor re-runs the
//!   §III-E autotune heuristic on each chunk's slab (size-gated), so the
//!   (block size × lane width) configuration tracks non-stationary fields;
//!   the per-chunk choice is recorded in the frame and the index.
//!
//! Streaming requires an **absolute** error bound: a range-relative bound
//! needs the whole field before the first byte can be emitted.

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

use crate::autotune::{autotune, TuneSettings};
use crate::blocks::Dims;
use crate::compressor::{
    decode_body, default_block_size, encode_body, BackendChoice, Config, EbMode,
};
use crate::coordinator::pool::ThreadPool;
use crate::data::Field;
use crate::error::{Result, VszError};
use crate::format::{self, ChunkIndexEntry, ChunkMeta, Frame, Header, Section, StreamHeader};
use crate::quant::CodesKind;
use crate::util::crc32;
use crate::util::{f32_as_bytes, f32_as_bytes_mut};

pub mod dataset;

pub use dataset::{ChunkCache, Dataset, DatasetOptions, Region};

/// Upper bound on a single section payload accepted from a stream (guards
/// allocations against forged lengths).
const MAX_SECTION_LEN: u64 = 1 << 30;

/// Element-count floor below which per-chunk autotuning is skipped: on a
/// tiny slab the sampling run costs more than the encode it would tune.
pub const CHUNK_AUTOTUNE_MIN_ELEMS: usize = 1 << 14;

/// Writer-side options beyond the compression [`Config`].
#[derive(Clone, Copy, Debug)]
pub struct StreamOptions {
    /// Container version to write: [`format::VERSION3`] (indexed footer,
    /// the default) or [`format::VERSION2`] (legacy layout, no footer).
    pub version: u16,
    /// Re-run the autotune heuristic on each chunk's slab and encode the
    /// chunk with the winning (block size × lane width). v3 only (the
    /// choice must be recorded per chunk); skipped for slabs smaller than
    /// [`CHUNK_AUTOTUNE_MIN_ELEMS`] and for non-vectorized backends.
    pub chunk_autotune: Option<TuneSettings>,
    /// Lane widths the per-chunk tuner considers.
    pub tune_widths: [usize; 2],
    /// Data chunks per XOR parity group; `0` (the default) writes no
    /// parity. With `G > 0` the compressor emits one parity frame per `G`
    /// data frames (the last group may be shorter) after the data frames,
    /// and records the group geometry in the footer-v2 index — any single
    /// lost or corrupted frame per group becomes recoverable. v3 only.
    pub parity_group: usize,
}

impl Default for StreamOptions {
    fn default() -> Self {
        Self {
            version: format::VERSION3,
            chunk_autotune: None,
            tune_widths: [8, 16],
            parity_group: 0,
        }
    }
}

impl StreamOptions {
    /// Start a [`StreamOptionsBuilder`] seeded with the defaults. The
    /// struct-literal path (`StreamOptions { .. }`) keeps working; the
    /// builder is the forward-compatible spelling — future codec presets
    /// (`fast()` / `balanced()` / `best()`) will hang off the same shape.
    pub fn builder() -> StreamOptionsBuilder {
        StreamOptionsBuilder { opts: Self::default() }
    }
}

/// Fluent constructor for [`StreamOptions`]:
/// `StreamOptions::builder().version(3).chunk_autotune(true).build()`.
#[derive(Clone, Copy, Debug)]
pub struct StreamOptionsBuilder {
    opts: StreamOptions,
}

impl StreamOptionsBuilder {
    /// Container version to write ([`format::VERSION3`] or
    /// [`format::VERSION2`]).
    pub fn version(mut self, version: u16) -> Self {
        self.opts.version = version;
        self
    }

    /// Toggle per-chunk autotuning with default [`TuneSettings`]; `false`
    /// clears any settings set so far.
    pub fn chunk_autotune(mut self, on: bool) -> Self {
        self.opts.chunk_autotune = if on { Some(TuneSettings::default()) } else { None };
        self
    }

    /// Enable per-chunk autotuning with explicit [`TuneSettings`].
    pub fn chunk_autotune_with(mut self, settings: TuneSettings) -> Self {
        self.opts.chunk_autotune = Some(settings);
        self
    }

    /// Lane widths the per-chunk tuner considers.
    pub fn tune_widths(mut self, widths: [usize; 2]) -> Self {
        self.opts.tune_widths = widths;
        self
    }

    /// Data chunks per XOR parity group (`0` disables parity).
    pub fn parity(mut self, group: usize) -> Self {
        self.opts.parity_group = group;
        self
    }

    pub fn build(self) -> StreamOptions {
        self.opts
    }
}

/// Aggregate statistics of one streaming compression run.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    pub n_chunks: usize,
    pub n_elements: usize,
    pub n_outliers: usize,
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
    /// Summed P&Q stage seconds across chunks (worker wall time, not
    /// end-to-end wall time when pipelined).
    pub pq_seconds: f64,
}

impl StreamStats {
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes.max(1) as f64
    }
}

/// Pick a chunk span (leading-dim extent) targeting ~4 MiB of raw samples
/// per chunk, rounded up to a whole number of block rows.
pub fn default_chunk_span(dims: Dims, block_size: usize) -> usize {
    let bs = if block_size == 0 { default_block_size(dims.ndim) } else { block_size };
    let row_elems: usize = dims.shape[1] * dims.shape[2];
    let target_rows = ((1usize << 20) / row_elems.max(1)).max(1); // 4 MiB / 4 B
    let span = target_rows.div_ceil(bs) * bs;
    span.max(bs)
}

/// Fold `frame` into a running XOR accumulator under the length-padding
/// rule: the accumulator grows (zero-filled) to the longest frame seen, and
/// shorter frames XOR as if zero-padded at the tail — so XOR-ing the
/// accumulator with every *other* member of a parity group, then truncating
/// to the missing member's frame length, reproduces that member's bytes.
fn xor_into(acc: &mut Vec<u8>, frame: &[u8]) {
    if acc.len() < frame.len() {
        acc.resize(frame.len(), 0);
    }
    for (a, b) in acc.iter_mut().zip(frame) {
        *a ^= *b;
    }
}

/// Per-chunk numbers sent back from encode workers.
pub(crate) struct ChunkOut {
    pub(crate) n_outliers: usize,
    pub(crate) pq_seconds: f64,
    pub(crate) lead_extent: u64,
    pub(crate) meta: ChunkMeta,
}

/// Resolved geometry + header of a chunked container, shared between
/// [`StreamCompressor`] and the `coordinator::sched` chunk scheduler so the
/// two paths stay byte-identical by construction: same validation, same
/// block-size/span rounding, same encoded stream header.
pub(crate) struct ChunkPlan {
    /// Input config with `block_size` resolved (never 0).
    pub(crate) cfg: Config,
    /// Chunk span (leading-dim extent), block-row aligned.
    pub(crate) span: usize,
    /// Encoded stream header (the container's first bytes).
    pub(crate) header: Vec<u8>,
}

impl ChunkPlan {
    /// Leading-dim extent of chunk `i` under this plan.
    pub(crate) fn extent(&self, dims: Dims, i: usize) -> usize {
        (dims.shape[0] - (i * self.span).min(dims.shape[0])).min(self.span)
    }

    pub(crate) fn n_chunks(&self, dims: Dims) -> usize {
        dims.shape[0].div_ceil(self.span)
    }
}

/// Validate a chunked-compression request and resolve its geometry (the
/// front half of [`StreamCompressor::with_options`], reused by the chunk
/// scheduler).
pub(crate) fn plan_chunks(
    dims: Dims,
    cfg: &Config,
    chunk_span: usize,
    opts: StreamOptions,
) -> Result<ChunkPlan> {
    if opts.version != format::VERSION2 && opts.version != format::VERSION3 {
        return Err(VszError::config(format!("unsupported stream version {}", opts.version)));
    }
    if opts.chunk_autotune.is_some() && opts.version < format::VERSION3 {
        return Err(VszError::config(
            "per-chunk autotuning needs the v3 container (the per-chunk \
             block size must be recorded in the frame and index)",
        ));
    }
    if opts.parity_group > 0 && opts.version < format::VERSION3 {
        return Err(VszError::config(
            "parity needs the v3 container (the group geometry must be \
             recorded in the index footer)",
        ));
    }
    let eb = match cfg.eb {
        EbMode::Abs(e) if e > 0.0 && e.is_finite() => e,
        EbMode::Abs(_) => return Err(VszError::config("invalid absolute error bound")),
        EbMode::Rel(_) => {
            return Err(VszError::config(
                "streaming requires an absolute error bound (--eb), not a relative one",
            ))
        }
    };
    if dims.is_empty() {
        return Err(VszError::config("empty field"));
    }
    let bs = if cfg.block_size == 0 { default_block_size(dims.ndim) } else { cfg.block_size };
    let mut cfg = *cfg;
    cfg.block_size = bs;
    let span = if chunk_span == 0 { default_chunk_span(dims, bs) } else { chunk_span };
    let span = span.div_ceil(bs) * bs;
    let codes_kind = match cfg.backend {
        crate::compressor::BackendChoice::Sz14 => CodesKind::Sz14,
        _ => CodesKind::DualQuant,
    };
    let header = StreamHeader {
        header: Header {
            dims,
            codes_kind,
            eb,
            radius: cfg.radius,
            block_size: bs as u32,
            padding: cfg.padding.normalized(),
        },
        chunk_span: span as u64,
        version: opts.version,
    };
    Ok(ChunkPlan { cfg, span, header: format::write_stream_header(&header)? })
}

/// Encode one slab sub-field into a framed chunk (free function so the
/// thread-pool job owns everything it needs). With per-chunk autotuning
/// enabled the §III-E heuristic runs on this slab first and the winning
/// (block size × lane width) replaces the base config — the choice is
/// returned in [`ChunkOut::meta`] so the writer can index it.
pub(crate) fn encode_chunk(
    index: u64,
    field: Field,
    cfg: Config,
    overlap_aux: bool,
    opts: StreamOptions,
) -> Result<(Vec<u8>, ChunkOut)> {
    crate::failpoint::hit("chunk_encode")?;
    let mut cfg = cfg;
    if let Some(ts) = opts.chunk_autotune {
        if field.data.len() >= CHUNK_AUTOTUNE_MIN_ELEMS
            && matches!(cfg.backend, BackendChoice::Vec { .. } | BackendChoice::Simd { .. })
        {
            let eb = cfg.eb.resolve(&field.data);
            let r = autotune(&field, eb, cfg.radius, cfg.padding, &opts.tune_widths, ts);
            cfg.block_size = r.best.block_size;
            cfg.backend = r.best.backend_choice();
        }
    }
    let backend = cfg.backend.instantiate();
    // entropy_threads = 1: streaming parallelism is across chunks, not
    // within one. Pipelined runs (threads > 1) still overlap each chunk's
    // lossless streams with its Huffman pass on scoped helper threads;
    // serial runs (threads = 1) stay strictly single-threaded.
    let body = encode_body(&field, &cfg, backend.as_ref(), 1, overlap_aux)?;
    let meta = ChunkMeta {
        block_size: body.block_size as u32,
        width: match cfg.backend {
            BackendChoice::Vec { width } => width as u8,
            BackendChoice::Simd { width } => width as u8 | format::WIDTH_SIMD_FLAG,
            _ => 0,
        },
    };
    let lead_extent = field.dims.shape[0] as u64;
    let mut frame = Vec::new();
    format::write_chunk_frame(
        &mut frame,
        index,
        lead_extent,
        (opts.version >= format::VERSION3).then_some(meta),
        &body.sections,
    );
    Ok((frame, ChunkOut {
        n_outliers: body.n_outliers,
        pq_seconds: body.pq_seconds,
        lead_extent,
        meta,
    }))
}

type ChunkResult = (u64, Result<(Vec<u8>, ChunkOut)>);

/// Incremental compressor writing a chunked container (v3 indexed by
/// default, v2 via [`StreamOptions::version`]) to `W`.
///
/// Feed samples in row-major order with [`push`](Self::push) (any slice
/// granularity), then call [`finish`](Self::finish). The compressor holds
/// at most one gathering slab plus `threads` in-flight slabs.
pub struct StreamCompressor<W: Write> {
    out: W,
    cfg: Config,
    opts: StreamOptions,
    dims: Dims,
    chunk_span: usize,
    row_elems: usize,
    total_elems: usize,
    received: usize,
    lead_done: usize,
    buf: Vec<f32>,
    chunk_index: u64,
    stats: StreamStats,
    /// One entry per written frame, in order — becomes the v3 footer.
    index: Vec<ChunkIndexEntry>,
    /// Completed parity-group payloads, emitted as frames by `finish`.
    parity_payloads: Vec<Vec<u8>>,
    /// XOR of the length-padded frames of the group being accumulated.
    parity_acc: Vec<u8>,
    /// Data frames folded into `parity_acc` so far.
    parity_members: usize,
    // chunk-pipeline state (threads > 1)
    pool: Option<ThreadPool>,
    tx: Sender<ChunkResult>,
    rx: Receiver<ChunkResult>,
    window: usize,
    in_flight: usize,
    next_write: u64,
    ready: BTreeMap<u64, (Vec<u8>, u64, ChunkMeta)>,
}

impl<W: Write> StreamCompressor<W> {
    /// Create a compressor with default [`StreamOptions`] (v3 indexed
    /// container, no per-chunk autotuning) and write the stream header.
    ///
    /// `chunk_span` is the leading-dim extent per chunk (rounded up to a
    /// whole number of block rows); 0 picks [`default_chunk_span`]. The
    /// error bound must be [`EbMode::Abs`].
    pub fn new(out: W, dims: Dims, cfg: &Config, chunk_span: usize) -> Result<Self> {
        Self::with_options(out, dims, cfg, chunk_span, StreamOptions::default())
    }

    /// [`new`](Self::new) with explicit writer options (container version,
    /// per-chunk autotuning).
    pub fn with_options(
        mut out: W,
        dims: Dims,
        cfg: &Config,
        chunk_span: usize,
        opts: StreamOptions,
    ) -> Result<Self> {
        let plan = plan_chunks(dims, cfg, chunk_span, opts)?;
        let ChunkPlan { cfg, span, header: hdr } = plan;
        out.write_all(&hdr)?;

        let threads = cfg.threads.max(1);
        let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
        let (tx, rx) = channel();
        Ok(Self {
            out,
            cfg,
            opts,
            dims,
            chunk_span: span,
            row_elems: dims.shape[1] * dims.shape[2],
            total_elems: dims.len(),
            received: 0,
            lead_done: 0,
            buf: Vec::new(),
            chunk_index: 0,
            stats: StreamStats {
                raw_bytes: dims.len() * 4,
                n_elements: dims.len(),
                compressed_bytes: hdr.len(),
                ..StreamStats::default()
            },
            index: Vec::new(),
            parity_payloads: Vec::new(),
            parity_acc: Vec::new(),
            parity_members: 0,
            pool,
            tx,
            rx,
            window: threads,
            in_flight: 0,
            next_write: 0,
            ready: BTreeMap::new(),
        })
    }

    fn next_chunk_extent(&self) -> usize {
        (self.dims.shape[0] - self.lead_done).min(self.chunk_span)
    }

    fn chunk_dims(&self, extent: usize) -> Dims {
        let mut shape = self.dims.shape;
        shape[0] = extent;
        Dims { shape, ndim: self.dims.ndim }
    }

    /// Record a frame's index entry (offset = bytes written so far, which
    /// is the frame's first byte because frames are written in order) and
    /// write it out. v2 output writes no footer, so it accumulates no
    /// entries — the index must not grow unboundedly on a long v2 run.
    fn write_frame(&mut self, frame: &[u8], lead_extent: u64, meta: ChunkMeta) -> Result<()> {
        if self.opts.version >= format::VERSION3 {
            self.index.push(ChunkIndexEntry {
                offset: self.stats.compressed_bytes as u64,
                frame_len: frame.len() as u64,
                lead_extent,
                meta,
            });
        }
        crate::failpoint::write_through("frame_write", &mut self.out, frame)?;
        self.stats.compressed_bytes += frame.len();
        self.next_write += 1;
        if self.opts.parity_group > 0 {
            xor_into(&mut self.parity_acc, frame);
            self.parity_members += 1;
            if self.parity_members == self.opts.parity_group {
                self.parity_payloads.push(std::mem::take(&mut self.parity_acc));
                self.parity_members = 0;
            }
        }
        Ok(())
    }

    /// Write every frame that is next in line.
    fn write_ready(&mut self) -> Result<()> {
        while let Some((frame, lead_extent, meta)) = self.ready.remove(&self.next_write) {
            self.write_frame(&frame, lead_extent, meta)?;
        }
        Ok(())
    }

    /// Receive one worker result; `blocking` waits (with a generous
    /// timeout so a crashed worker cannot deadlock the writer — the
    /// compressor keeps a master `Sender`, so the channel never reports
    /// disconnection on its own), otherwise returns Ok(false) when nothing
    /// is pending.
    fn recv_one(&mut self, blocking: bool) -> Result<bool> {
        let msg = if blocking {
            self.rx
                .recv_timeout(std::time::Duration::from_secs(300))
                .map_err(|_| VszError::runtime("stream worker stalled or died"))?
        } else {
            match self.rx.try_recv() {
                Ok(m) => m,
                Err(TryRecvError::Empty) => return Ok(false),
                Err(TryRecvError::Disconnected) => {
                    return Err(VszError::runtime("stream worker disconnected"))
                }
            }
        };
        self.in_flight -= 1;
        let (index, res) = msg;
        let (frame, info) = res?;
        self.stats.n_outliers += info.n_outliers;
        self.stats.pq_seconds += info.pq_seconds;
        self.ready.insert(index, (frame, info.lead_extent, info.meta));
        Ok(true)
    }

    fn emit_chunk(&mut self, data: Vec<f32>, extent: usize) -> Result<()> {
        let index = self.chunk_index;
        self.chunk_index += 1;
        self.stats.n_chunks += 1;
        let field = Field::new(format!("chunk{index}"), self.chunk_dims(extent), data);
        if self.pool.is_some() {
            // pipelined: bound in-flight chunks, then hand off to a worker
            while self.in_flight >= self.window {
                self.recv_one(true)?;
                self.write_ready()?;
            }
            let mut job_cfg = self.cfg;
            job_cfg.threads = 1; // parallelism is across chunks here
            let tx = self.tx.clone();
            let opts = self.opts;
            self.pool.as_ref().unwrap().submit(move || {
                let res = encode_chunk(index, field, job_cfg, true, opts);
                let _ = tx.send((index, res));
            });
            self.in_flight += 1;
            // opportunistically drain finished workers
            while self.recv_one(false)? {}
            self.write_ready()?;
        } else {
            let (frame, info) = encode_chunk(index, field, self.cfg, false, self.opts)?;
            self.stats.n_outliers += info.n_outliers;
            self.stats.pq_seconds += info.pq_seconds;
            self.write_frame(&frame, info.lead_extent, info.meta)?;
        }
        Ok(())
    }

    /// Feed the next samples (row-major order, any slice size).
    pub fn push(&mut self, mut samples: &[f32]) -> Result<()> {
        if self.received + samples.len() > self.total_elems {
            return Err(VszError::config(format!(
                "more samples than dims describe ({} > {})",
                self.received + samples.len(),
                self.total_elems
            )));
        }
        self.received += samples.len();
        while !samples.is_empty() {
            let extent = self.next_chunk_extent();
            let chunk_elems = extent * self.row_elems;
            let need = chunk_elems - self.buf.len();
            let take = need.min(samples.len());
            if self.buf.is_empty() && take == chunk_elems {
                // whole chunk available in the caller's slice: skip the copy
                self.emit_chunk(samples[..take].to_vec(), extent)?;
                self.lead_done += extent;
            } else {
                self.buf.extend_from_slice(&samples[..take]);
                if self.buf.len() == chunk_elems {
                    let data = std::mem::take(&mut self.buf);
                    self.emit_chunk(data, extent)?;
                    self.lead_done += extent;
                }
            }
            samples = &samples[take..];
        }
        Ok(())
    }

    /// Drain in-flight chunks, write the trailer and return the writer plus
    /// run statistics. Errors if fewer samples than `dims` describe were
    /// pushed.
    pub fn finish(mut self) -> Result<(W, StreamStats)> {
        if self.received != self.total_elems {
            return Err(VszError::config(format!(
                "incomplete field: got {} of {} samples",
                self.received, self.total_elems
            )));
        }
        while self.in_flight > 0 {
            self.recv_one(true)?;
            self.write_ready()?;
        }
        self.write_ready()?;
        debug_assert!(self.ready.is_empty());
        debug_assert_eq!(self.next_write, self.chunk_index);
        // flush the parity layer: the final (possibly short) group, then
        // one frame per group, each indexed for the footer-v2 table
        let mut parity_entries: Vec<format::ParityIndexEntry> = Vec::new();
        if self.opts.parity_group > 0 {
            if self.parity_members > 0 {
                self.parity_payloads.push(std::mem::take(&mut self.parity_acc));
                self.parity_members = 0;
            }
            let g_size = self.opts.parity_group as u64;
            for (g, payload) in self.parity_payloads.iter().enumerate() {
                let members =
                    (self.chunk_index - g as u64 * g_size).min(g_size);
                let mut frame = Vec::new();
                format::write_parity_frame(&mut frame, g as u64, members, payload);
                parity_entries.push(format::ParityIndexEntry {
                    offset: self.stats.compressed_bytes as u64,
                    frame_len: frame.len() as u64,
                });
                crate::failpoint::write_through("parity_write", &mut self.out, &frame)?;
                self.stats.compressed_bytes += frame.len();
            }
        }
        let mut tail = Vec::new();
        format::write_trailer(&mut tail, self.chunk_index);
        if self.opts.version >= format::VERSION3 {
            if parity_entries.is_empty() {
                // parity-less containers keep the v1 footer byte-for-byte
                format::write_index_footer(&mut tail, &self.index);
            } else {
                let parity = format::ParityFooter {
                    group_size: self.opts.parity_group as u64,
                    entries: parity_entries,
                };
                format::write_index_footer_v2(&mut tail, &self.index, &parity);
            }
        }
        self.out.write_all(&tail)?;
        self.stats.compressed_bytes += tail.len();
        self.out.flush()?;
        Ok((self.out, self.stats))
    }
}

/// Cap on the streaming read buffer (multiple of 4). A chunk span
/// targeting ~4 MiB never gets near this; it only bites when the caller
/// forces a gigantic explicit span.
const MAX_READ_CHUNK_BYTES: usize = 1 << 28;

/// Compress a raw little-endian f32 stream (e.g. an `.f32` file) to a
/// chunked container in bounded memory (v3 indexed by default).
pub fn compress_stream<R: Read, W: Write>(
    input: R,
    out: W,
    dims: Dims,
    cfg: &Config,
    chunk_span: usize,
) -> Result<StreamStats> {
    compress_stream_with(input, out, dims, cfg, chunk_span, StreamOptions::default())
}

/// [`compress_stream`] with explicit writer options.
///
/// Reads directly into one reused, chunk-span-sized f32 slab (sized once
/// from the span), so `push` takes its zero-copy whole-slab path, no
/// per-chunk byte→f32 conversion buffer is allocated, and memory stays
/// bounded by one slab (plus the compressor's in-flight window) no matter
/// how large the input file is — the cheap half of the memory-mapped-input
/// roadmap item.
pub fn compress_stream_with<R: Read, W: Write>(
    input: R,
    out: W,
    dims: Dims,
    cfg: &Config,
    chunk_span: usize,
    opts: StreamOptions,
) -> Result<StreamStats> {
    let sc = StreamCompressor::with_options(out, dims, cfg, chunk_span, opts)?;
    drive_stream(input, sc)
}

/// Pump a raw little-endian f32 reader through an already-constructed
/// compressor (fresh or [resumed](StreamCompressor::resume)) to
/// completion: the shared back half of [`compress_stream_with`] and
/// [`resume_stream_with`].
fn drive_stream<R: Read, W: Write>(
    mut input: R,
    mut sc: StreamCompressor<W>,
) -> Result<StreamStats> {
    let slab_elems =
        sc.chunk_span.saturating_mul(sc.row_elems).clamp(1, MAX_READ_CHUNK_BYTES / 4);
    let mut slab = vec![0.0f32; slab_elems];
    loop {
        // fill the slab completely (short `read`s happen on pipes and
        // sockets) so each push is one whole chunk when possible; the
        // bytes land straight in the f32 buffer (LE host, as everywhere)
        let buf = f32_as_bytes_mut(&mut slab);
        let mut filled = 0usize;
        while filled < buf.len() {
            let n = input.read(&mut buf[filled..])?;
            if n == 0 {
                break;
            }
            filled += n;
        }
        if filled == 0 {
            break;
        }
        if filled % 4 != 0 {
            return Err(VszError::format("input length is not a multiple of 4 bytes"));
        }
        let short = filled < slab_elems * 4;
        sc.push(&slab[..filled / 4])?;
        if short {
            break; // EOF mid-slab
        }
    }
    let (_, stats) = sc.finish()?;
    Ok(stats)
}

/// Compress an in-memory field to a chunked container (v3 indexed).
pub fn compress_chunked(
    field: &Field,
    cfg: &Config,
    chunk_span: usize,
) -> Result<(Vec<u8>, StreamStats)> {
    compress_chunked_with(field, cfg, chunk_span, StreamOptions::default())
}

/// [`compress_chunked`] with explicit writer options (container version,
/// per-chunk autotuning).
pub fn compress_chunked_with(
    field: &Field,
    cfg: &Config,
    chunk_span: usize,
    opts: StreamOptions,
) -> Result<(Vec<u8>, StreamStats)> {
    let mut sc = StreamCompressor::with_options(Vec::new(), field.dims, cfg, chunk_span, opts)?;
    sc.push(&field.data)?;
    sc.finish()
}

// ------------------------------------------------------------------ decode

fn read_u8_io<R: Read>(r: &mut R) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u32_io<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_uvarint_io<R: Read>(r: &mut R) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        if shift >= 64 {
            return Err(VszError::format("varint overflow"));
        }
        let b = read_u8_io(r)?;
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn read_section_io<R: Read>(r: &mut R) -> Result<Section> {
    let tag = read_u8_io(r)?;
    let raw_len = read_uvarint_io(r)?;
    let enc_len = read_uvarint_io(r)?;
    if enc_len > MAX_SECTION_LEN {
        return Err(VszError::format(format!("section {tag}: implausible length {enc_len}")));
    }
    let crc = read_u32_io(r)?;
    let mut payload = vec![0u8; enc_len as usize];
    r.read_exact(&mut payload)?;
    if crc32(&payload) != crc {
        return Err(VszError::Integrity(format!("section {tag}: crc mismatch")));
    }
    Ok(Section { tag, raw_len, payload })
}

fn read_frame_io<R: Read>(r: &mut R, version: u16) -> Result<Frame> {
    let marker = read_u8_io(r)?;
    match marker {
        format::CHUNK_TAG => {
            let index = read_uvarint_io(r)?;
            let lead_extent = read_uvarint_io(r)?;
            if lead_extent == 0 {
                return Err(VszError::format("empty chunk"));
            }
            let meta = if version >= format::VERSION3 {
                let block_size = format::check_block_size(read_uvarint_io(r)?)?;
                let width = read_u8_io(r)?;
                Some(ChunkMeta { block_size, width })
            } else {
                None
            };
            let n_sections = read_u8_io(r)? as usize;
            let mut sections = Vec::with_capacity(n_sections);
            for _ in 0..n_sections {
                sections.push(read_section_io(r)?);
            }
            Ok(Frame::Chunk { index, lead_extent, meta, sections })
        }
        format::PARITY_TAG => {
            let group = read_uvarint_io(r)?;
            let members = read_uvarint_io(r)?;
            if members == 0 {
                return Err(VszError::format("empty parity group"));
            }
            let len = read_uvarint_io(r)?;
            if len > MAX_SECTION_LEN {
                return Err(VszError::format(format!(
                    "parity group {group}: implausible length {len}"
                )));
            }
            let crc = read_u32_io(r)?;
            let mut payload = vec![0u8; len as usize];
            r.read_exact(&mut payload)?;
            if crc32(&payload) != crc {
                return Err(VszError::Integrity(format!("parity group {group}: crc mismatch")));
            }
            Ok(Frame::Parity { group, members, payload })
        }
        format::END_TAG => {
            let n_chunks = read_uvarint_io(r)?;
            let crc = read_u32_io(r)?;
            if crc32(&n_chunks.to_le_bytes()) != crc {
                return Err(VszError::Integrity("trailer crc mismatch".into()));
            }
            Ok(Frame::End { n_chunks })
        }
        other => Err(VszError::format(format!("unknown frame marker {other:#x}"))),
    }
}

/// One decoded slab handed out by [`StreamDecompressor::next_chunk`].
pub struct DecodedChunk {
    pub index: u64,
    /// Leading-dim offset of this slab within the full field.
    pub lead_offset: usize,
    /// Leading-dim extent of this slab.
    pub lead_extent: usize,
    pub data: Vec<f32>,
}

/// The loaded v3 chunk index: one entry per chunk plus derived positions.
#[derive(Clone, Debug)]
pub struct ChunkIndex {
    pub entries: Vec<ChunkIndexEntry>,
    /// Leading-dim offset of each chunk's slab within the full field.
    pub lead_offsets: Vec<usize>,
    /// Byte position where the footer begins (frames + trailer end here).
    pub footer_start: u64,
    /// Parity geometry when the container carries a footer-v2 parity layer.
    pub parity: Option<format::ParityFooter>,
}

impl ChunkIndex {
    pub fn n_chunks(&self) -> usize {
        self.entries.len()
    }
}

/// Build per-chunk slab positions from footer entries, enforcing the
/// invariants the writer guarantees: frames are contiguous from the
/// header, extents tile the leading dimension, block sizes are sane.
fn validate_index(
    header: &StreamHeader,
    entries: Vec<ChunkIndexEntry>,
    parity: Option<format::ParityFooter>,
    footer_start: u64,
) -> Result<ChunkIndex> {
    let dims = header.header.dims;
    let span = header.chunk_span as usize;
    let mut lead_offsets = Vec::with_capacity(entries.len());
    let mut lead_done = 0usize;
    let mut pos = format::STREAM_HEADER_LEN as u64;
    for (k, e) in entries.iter().enumerate() {
        if e.offset != pos {
            return Err(VszError::format(format!(
                "index entry {k}: offset {} does not follow the previous frame",
                e.offset
            )));
        }
        // checked arithmetic throughout: a CRC-consistent but forged entry
        // with frame_len near u64::MAX must not wrap past the bound check
        // and reach the frame allocation below
        pos = e
            .offset
            .checked_add(e.frame_len)
            .ok_or_else(|| VszError::format("index offset overflow"))?;
        // the END trailer (>= 6 bytes) sits between the last frame and the
        // footer, so every frame must end strictly before it; this also
        // caps every frame_len at the file size
        let end = pos
            .checked_add(6)
            .ok_or_else(|| VszError::format("index offset overflow"))?;
        if end > footer_start {
            return Err(VszError::format(format!("index entry {k} overruns the trailer")));
        }
        let extent = e.lead_extent as usize;
        let remaining = dims.shape[0] - lead_done;
        if extent == 0 || extent > remaining || (extent != span && extent != remaining) {
            return Err(VszError::format(format!("index entry {k}: bad extent {extent}")));
        }
        lead_offsets.push(lead_done);
        lead_done += extent;
    }
    if lead_done != dims.shape[0] {
        return Err(VszError::format("index does not cover the field"));
    }
    // parity frames are contiguous after the last data frame, and the last
    // one still ends strictly before the END trailer — same checked
    // arithmetic, so a forged parity entry cannot drive an allocation past
    // the container either
    if let Some(p) = &parity {
        for (g, pe) in p.entries.iter().enumerate() {
            if pe.offset != pos {
                return Err(VszError::format(format!(
                    "parity entry {g}: offset {} does not follow the previous frame",
                    pe.offset
                )));
            }
            pos = pe
                .offset
                .checked_add(pe.frame_len)
                .ok_or_else(|| VszError::format("parity offset overflow"))?;
            let end = pos
                .checked_add(6)
                .ok_or_else(|| VszError::format("parity offset overflow"))?;
            if end > footer_start {
                return Err(VszError::format(format!("parity entry {g} overruns the trailer")));
            }
        }
    }
    Ok(ChunkIndex { entries, lead_offsets, footer_start, parity })
}

/// Incremental decoder for v2/v3 chunked containers over any `Read`; with
/// `Read + Seek` input it additionally offers footer-driven random access
/// ([`decode_chunk`](Self::decode_chunk) and friends).
pub struct StreamDecompressor<R: Read> {
    input: R,
    header: StreamHeader,
    next_index: u64,
    lead_done: usize,
    finished: bool,
    index: Option<ChunkIndex>,
}

impl<R: Read> StreamDecompressor<R> {
    pub fn new(mut input: R) -> Result<Self> {
        let mut hdr = [0u8; format::STREAM_HEADER_LEN];
        input.read_exact(&mut hdr)?;
        let header = format::read_stream_header(&hdr)?;
        Ok(Self { input, header, next_index: 0, lead_done: 0, finished: false, index: None })
    }

    pub fn header(&self) -> &StreamHeader {
        &self.header
    }

    /// Per-chunk decode header: the slab's dims plus the block size the
    /// chunk was actually encoded with (v3 frames may override the base).
    fn chunk_header(&self, extent: usize, meta: Option<ChunkMeta>) -> Header {
        let mut h = self.header.header;
        h.dims.shape[0] = extent;
        if let Some(m) = meta {
            h.block_size = m.block_size;
        }
        h
    }

    /// Validate one frame's geometry against the running position.
    fn check_chunk(&self, index: u64, extent: u64) -> Result<usize> {
        if index != self.next_index {
            return Err(VszError::format(format!(
                "chunk out of order: got {index}, expected {}",
                self.next_index
            )));
        }
        let remaining = self.header.header.dims.shape[0] - self.lead_done;
        let extent = extent as usize;
        if extent > remaining || (extent != self.header.chunk_span as usize && extent != remaining)
        {
            return Err(VszError::format(format!("bad chunk extent {extent}")));
        }
        Ok(extent)
    }

    /// Read and validate the next frame without decoding it, advancing the
    /// running position. Returns the chunk's decode header (dims +
    /// per-chunk block size) and sections, or `None` once the trailer has
    /// been consumed and verified. Shared by [`Self::next_chunk`] and
    /// [`decompress_stream`] so the trailer checks live in one place.
    fn next_frame(&mut self) -> Result<Option<(Header, Vec<Section>)>> {
        if self.finished {
            return Ok(None);
        }
        loop {
            return match read_frame_io(&mut self.input, self.header.version)? {
                Frame::Chunk { index, lead_extent, meta, sections } => {
                    let extent = self.check_chunk(index, lead_extent)?;
                    self.lead_done += extent;
                    self.next_index += 1;
                    Ok(Some((self.chunk_header(extent, meta), sections)))
                }
                // sequential decode does not need the parity layer
                Frame::Parity { .. } => continue,
                Frame::End { n_chunks } => {
                    if n_chunks != self.next_index {
                        return Err(VszError::format(format!(
                            "trailer says {n_chunks} chunks, read {}",
                            self.next_index
                        )));
                    }
                    if self.lead_done != self.header.header.dims.shape[0] {
                        return Err(VszError::format(
                            "stream ended before the field was complete",
                        ));
                    }
                    self.finished = true;
                    Ok(None)
                }
            };
        }
    }

    /// Read and validate the next chunk frame **without decoding its
    /// payload**: the chunk's decode header and raw sections, or `None`
    /// after the trailer. This is the introspection surface `vsz stream
    /// inspect` uses to report per-chunk entropy framing (via
    /// [`crate::huffman::inspect_payload`]) without paying for a decode.
    pub fn next_raw_chunk(&mut self) -> Result<Option<(Header, Vec<Section>)>> {
        self.next_frame()
    }

    /// Decode the next chunk, or `None` after the trailer.
    pub fn next_chunk(&mut self) -> Result<Option<DecodedChunk>> {
        match self.next_frame()? {
            None => Ok(None),
            Some((h, sections)) => {
                let extent = h.dims.shape[0];
                crate::failpoint::hit("chunk_decode")?;
                let data = decode_body(&h, &sections, 1)?;
                Ok(Some(DecodedChunk {
                    index: self.next_index - 1,
                    lead_offset: self.lead_done - extent,
                    lead_extent: extent,
                    data,
                }))
            }
        }
    }
}

impl<R: Read + Seek> StreamDecompressor<R> {
    /// Load (and cache) the v3 chunk index: seek to EOF, read the trailing
    /// length word, CRC-check the footer, validate its geometry. Errors on
    /// v2 containers (they carry no index).
    pub fn load_index(&mut self) -> Result<&ChunkIndex> {
        if self.index.is_none() {
            let idx = self.read_index()?;
            self.index = Some(idx);
        }
        Ok(self.index.as_ref().unwrap())
    }

    /// Run `f` (which may seek freely), then restore the reader to where
    /// it was — random access must not derail a concurrent sequential
    /// [`next_chunk`](Self::next_chunk) walk over the same decoder.
    fn with_restored_position<T>(
        &mut self,
        f: impl FnOnce(&mut Self) -> Result<T>,
    ) -> Result<T> {
        let saved = self.input.stream_position()?;
        let res = f(self);
        self.input.seek(SeekFrom::Start(saved))?;
        res
    }

    fn read_index(&mut self) -> Result<ChunkIndex> {
        if self.header.version < format::VERSION3 {
            return Err(VszError::format(
                "container has no chunk index (pre-v3): random access needs a VSZ3 container",
            ));
        }
        self.with_restored_position(|this| this.read_index_inner())
    }

    fn read_index_inner(&mut self) -> Result<ChunkIndex> {
        let file_len = self.input.seek(SeekFrom::End(0))?;
        let min = format::STREAM_HEADER_LEN as u64;
        if file_len < min + 4 {
            return Err(VszError::format("truncated container: no index footer"));
        }
        self.input.seek(SeekFrom::End(-4))?;
        let len = read_u32_io(&mut self.input)? as u64;
        if len < 6 || len > file_len - min - 4 {
            return Err(VszError::format(format!("implausible index footer length {len}")));
        }
        let footer_start = file_len - 4 - len;
        self.input.seek(SeekFrom::Start(footer_start))?;
        let mut buf = vec![0u8; len as usize];
        self.input.read_exact(&mut buf)?;
        let (entries, parity) = format::read_index_footer_any(&buf)?;
        validate_index(&self.header, entries, parity, footer_start)
    }

    /// Fetch and parse one chunk's frame through the index, verifying the
    /// frame agrees with its index entry. The reader position is restored
    /// afterwards, so sequential decoding can continue unharmed.
    fn parse_indexed_frame(&mut self, k: usize) -> Result<(Header, Vec<Section>)> {
        self.with_restored_position(|this| this.parse_indexed_frame_inner(k))
    }

    fn parse_indexed_frame_inner(&mut self, k: usize) -> Result<(Header, Vec<Section>)> {
        crate::failpoint::hit("frame_read")?;
        let e = self.index.as_ref().unwrap().entries[k];
        // frame_len was bounded by the file size in `validate_index`, so
        // this allocation cannot be driven past the container itself
        let buf = self.read_raw_span(e.offset, e.frame_len)?;
        self.check_chunk_frame_bytes(k, &e, &buf)
    }

    /// Read `len` raw bytes at `offset` (no position restore — callers
    /// wrap in [`Self::with_restored_position`]).
    fn read_raw_span(&mut self, offset: u64, len: u64) -> Result<Vec<u8>> {
        self.input.seek(SeekFrom::Start(offset))?;
        let mut buf = vec![0u8; len as usize];
        self.input.read_exact(&mut buf)?;
        Ok(buf)
    }

    /// Parse `buf` as chunk `k`'s complete frame and cross-check it against
    /// its index entry — the shared acceptance gate for frames read from
    /// disk and frames rebuilt from parity (a rebuilt frame is accepted
    /// only if its section CRCs and index geometry all check out).
    fn check_chunk_frame_bytes(
        &self,
        k: usize,
        e: &ChunkIndexEntry,
        buf: &[u8],
    ) -> Result<(Header, Vec<Section>)> {
        let mut c = crate::bitio::Cursor::new(buf);
        match format::read_frame(&mut c, self.header.version)? {
            Frame::Chunk { index, lead_extent, meta, sections } => {
                let meta_bs = meta.map(|m| m.block_size);
                if index != k as u64
                    || lead_extent != e.lead_extent
                    || meta_bs != Some(e.meta.block_size)
                {
                    return Err(VszError::format(format!(
                        "chunk {k}: frame does not match its index entry"
                    )));
                }
                if c.remaining() != 0 {
                    return Err(VszError::format(format!(
                        "chunk {k}: index frame length overshoots the frame"
                    )));
                }
                Ok((self.chunk_header(lead_extent as usize, meta), sections))
            }
            Frame::Parity { .. } | Frame::End { .. } => Err(VszError::format(format!(
                "chunk {k}: index points at a non-chunk frame"
            ))),
        }
    }

    /// Reconstruct chunk `k`'s frame from its parity group: XOR the
    /// group's parity payload with every *other* member's on-disk bytes,
    /// truncate to `k`'s frame length, and accept the result only if it
    /// parses CRC-clean and matches `k`'s index entry. Errors when the
    /// container carries no parity layer, or when a second frame in the
    /// group is also damaged — the rebuilt bytes then fail their CRCs.
    pub(crate) fn rebuild_indexed_frame(&mut self, k: usize) -> Result<(Header, Vec<Section>)> {
        self.with_restored_position(|this| this.rebuild_indexed_frame_inner(k))
    }

    fn rebuild_indexed_frame_inner(&mut self, k: usize) -> Result<(Header, Vec<Section>)> {
        let idx = self.index.as_ref().ok_or_else(|| {
            VszError::format("rebuild needs the chunk index loaded first")
        })?;
        let parity = match &idx.parity {
            Some(p) => p.clone(),
            None => {
                return Err(VszError::format(format!(
                    "chunk {k}: container has no parity layer to rebuild from"
                )))
            }
        };
        let e = idx.entries[k];
        let n = idx.entries.len();
        let g_size = parity.group_size as usize;
        let g = k / g_size;
        let lo = g * g_size;
        let hi = (lo + g_size).min(n);
        let member_entries: Vec<(usize, ChunkIndexEntry)> =
            (lo..hi).map(|j| (j, idx.entries[j])).collect();
        let pe = parity.entries[g];

        // the parity frame itself must parse CRC-clean and agree with the
        // (independently CRC'd) footer geometry
        let praw = self.read_raw_span(pe.offset, pe.frame_len)?;
        let mut c = crate::bitio::Cursor::new(&praw);
        let mut acc = match format::read_frame(&mut c, self.header.version)? {
            Frame::Parity { group, members, payload }
                if group == g as u64 && members as usize == hi - lo && c.remaining() == 0 =>
            {
                payload
            }
            Frame::Parity { .. } => {
                return Err(VszError::format(format!(
                    "parity group {g}: frame does not match its footer entry"
                )))
            }
            _ => {
                return Err(VszError::format(format!(
                    "parity group {g}: footer points at a non-parity frame"
                )))
            }
        };
        for (j, ej) in member_entries {
            if j == k {
                continue;
            }
            let raw = self.read_raw_span(ej.offset, ej.frame_len)?;
            xor_into(&mut acc, &raw);
        }
        if acc.len() < e.frame_len as usize {
            return Err(VszError::format(format!(
                "parity group {g}: payload shorter than chunk {k}'s frame"
            )));
        }
        acc.truncate(e.frame_len as usize);
        self.check_chunk_frame_bytes(k, &e, &acc)
    }

    /// Random access: decode chunk `k`, reading only the index footer
    /// (once) and that chunk's byte range.
    #[deprecated(
        since = "0.3.0",
        note = "open a `stream::Dataset` and call `read(Region::Chunk(k))` — it caches \
                decoded slabs across calls"
    )]
    pub fn decode_chunk(&mut self, k: usize) -> Result<DecodedChunk> {
        let idx = self.load_index()?;
        let n = idx.n_chunks();
        if k >= n {
            return Err(VszError::config(format!("chunk {k} out of range (container has {n})")));
        }
        let lead_offset = idx.lead_offsets[k];
        let lead_extent = idx.entries[k].lead_extent as usize;
        let data = dataset::read_region_uncached(self, &Region::Chunk(k), 1)?;
        Ok(DecodedChunk { index: k as u64, lead_offset, lead_extent, data })
    }

    /// Random access: decode the chunk range `chunks` and return the
    /// concatenated slabs in field order. Multi-chunk ranges decode
    /// chunk-parallel on a pool of `threads` workers.
    #[deprecated(
        since = "0.3.0",
        note = "open a `stream::Dataset` and call `read(Region::Chunks(chunks))`; the \
                per-call `threads` parameter moves to the Dataset"
    )]
    pub fn decode_range(&mut self, chunks: Range<usize>, threads: usize) -> Result<Vec<f32>> {
        dataset::read_region_uncached(self, &Region::Chunks(chunks), threads)
    }

    /// Random access by leading-dim position: decode rows `[rows.start,
    /// rows.end)` of the field, touching only the chunks that overlap the
    /// range.
    #[deprecated(
        since = "0.3.0",
        note = "open a `stream::Dataset` and call `read(Region::Rows(rows))`; the \
                per-call `threads` parameter moves to the Dataset"
    )]
    pub fn decode_rows(&mut self, rows: Range<usize>, threads: usize) -> Result<Vec<f32>> {
        dataset::read_region_uncached(self, &Region::Rows(rows), threads)
    }

    /// Random access along **any** dimension: return the sub-field whose
    /// `dim`-axis extent is clipped to `range` (all other axes full), in
    /// field row-major order.
    ///
    /// `dim = 0` prunes to the covering chunks (chunks tile the leading
    /// dimension). For `dim >= 1` every chunk overlaps the range, so all
    /// chunks are decoded — chunk-parallel, in pool-sized batches so memory
    /// stays bounded by the batch plus the gathered output, never the full
    /// field — and the requested extent is gathered from each slab.
    #[deprecated(
        since = "0.3.0",
        note = "open a `stream::Dataset` and call `read(Region::Dim { dim, range })`; \
                the per-call `threads` parameter moves to the Dataset"
    )]
    pub fn decode_dim(
        &mut self,
        dim: usize,
        range: Range<usize>,
        threads: usize,
    ) -> Result<Vec<f32>> {
        dataset::read_region_uncached(self, &Region::Dim { dim, range }, threads)
    }

    /// Random access by column position: decode columns `[cols.start,
    /// cols.end)` — the last (fastest-varying) axis — of every row/plane.
    #[deprecated(
        since = "0.3.0",
        note = "open a `stream::Dataset` and call `read(Region::Dim { dim: ndim - 1, \
                range: cols })`; the per-call `threads` parameter moves to the Dataset"
    )]
    pub fn decode_cols(&mut self, cols: Range<usize>, threads: usize) -> Result<Vec<f32>> {
        let last = self.header.header.dims.ndim - 1;
        dataset::read_region_uncached(self, &Region::Dim { dim: last, range: cols }, threads)
    }
}

/// Append the `dim`-axis `range` extent of one decoded slab (leading-dim
/// extent `extent`, full field dims `dims`) to `out`, preserving row-major
/// order. Slabs arrive in lead order, so plain appending reassembles the
/// sub-field.
fn gather_dim_range(
    slab: &[f32],
    extent: usize,
    dims: Dims,
    dim: usize,
    range: &Range<usize>,
    kept_row: usize,
    out: &mut Vec<f32>,
) {
    let (d1, d2) = (dims.shape[1], dims.shape[2]);
    debug_assert_eq!(slab.len(), extent * d1 * d2);
    match dim {
        1 => {
            // contiguous run of range.len() * d2 per leading index
            for i0 in 0..extent {
                let base = i0 * d1 * d2 + range.start * d2;
                out.extend_from_slice(&slab[base..base + kept_row]);
            }
        }
        2 => {
            for i0 in 0..extent {
                for i1 in 0..d1 {
                    let base = (i0 * d1 + i1) * d2 + range.start;
                    out.extend_from_slice(&slab[base..base + kept_row]);
                }
            }
        }
        _ => unreachable!("dim 0 is the pruned decode_rows path"),
    }
}

/// Decode a batch of owned chunk frames (each already carrying its decode
/// header — slab dims + per-chunk block size), in parallel when `pool` is
/// given.
fn decode_batch(
    batch: Vec<(Header, Vec<Section>)>,
    pool: Option<&ThreadPool>,
) -> Result<Vec<Vec<f32>>> {
    match pool {
        Some(pool) if batch.len() > 1 => {
            let shared = Arc::new(batch);
            let shared2 = Arc::clone(&shared);
            let results = pool.scatter_gather(shared.len(), move |i| {
                crate::failpoint::hit("chunk_decode")?;
                let (h, sections) = &shared2[i];
                decode_body(h, sections, 1)
            });
            results.into_iter().collect()
        }
        _ => batch
            .iter()
            .map(|(h, sections)| {
                crate::failpoint::hit("chunk_decode")?;
                decode_body(h, sections, 1)
            })
            .collect(),
    }
}

/// Decompress a v2/v3 chunked container from `input`, writing raw
/// little-endian f32 bytes to `out` in field order. Chunks are decoded
/// `threads` at a time via the pool; memory stays bounded by the batch,
/// never the whole field. Returns the stream header. (Pure-`Read` path: a
/// trailing v3 index footer is simply left unread — sequential decode does
/// not need it.)
pub fn decompress_stream<R: Read, W: Write>(
    input: R,
    mut out: W,
    threads: usize,
) -> Result<StreamHeader> {
    let mut dec = StreamDecompressor::new(input)?;
    let header = *dec.header();
    let threads = threads.max(1);
    let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
    loop {
        // gather up to `threads` frames, then decode them concurrently
        let mut batch: Vec<(Header, Vec<Section>)> = Vec::with_capacity(threads);
        while batch.len() < threads {
            match dec.next_frame()? {
                Some(frame) => batch.push(frame),
                None => break,
            }
        }
        if batch.is_empty() {
            break;
        }
        for data in decode_batch(batch, pool.as_ref())? {
            out.write_all(f32_as_bytes(&data))?;
        }
    }
    out.flush()?;
    Ok(header)
}

/// Decompress an in-memory v2/v3 chunked container, decoding chunks
/// concurrently (`threads`) — byte-identical to serial decode because
/// slabs are assembled by offset. For v3 the index footer is required and
/// cross-checked against the frames actually walked.
pub fn decompress_chunked(bytes: &[u8], threads: usize) -> Result<Field> {
    if bytes.len() < format::STREAM_HEADER_LEN {
        return Err(VszError::format("truncated stream header"));
    }
    let header = format::read_stream_header(&bytes[..format::STREAM_HEADER_LEN])?;
    let dims = header.header.dims;
    let span = header.chunk_span as usize;

    // index all frames up front (cheap: payloads are borrowed then owned
    // per section; the heavy work is the decode below)
    let mut c = crate::bitio::Cursor::new(&bytes[format::STREAM_HEADER_LEN..]);
    let mut chunks: Vec<(Header, Vec<Section>)> = Vec::new();
    let mut observed: Vec<ChunkIndexEntry> = Vec::new();
    let mut observed_parity: Vec<format::ParityIndexEntry> = Vec::new();
    let mut lead_done = 0usize;
    loop {
        let frame_start = format::STREAM_HEADER_LEN + c.pos();
        match format::read_frame(&mut c, header.version)? {
            // sequential decode skips the parity layer (CRC already
            // checked by read_frame); position is recorded so the footer
            // cross-check below still covers the parity table
            Frame::Parity { .. } => {
                observed_parity.push(format::ParityIndexEntry {
                    offset: frame_start as u64,
                    frame_len: (format::STREAM_HEADER_LEN + c.pos() - frame_start) as u64,
                });
            }
            Frame::Chunk { index, lead_extent, meta, sections } => {
                if index as usize != chunks.len() {
                    return Err(VszError::format(format!(
                        "chunk out of order: got {index}, expected {}",
                        chunks.len()
                    )));
                }
                let remaining = dims.shape[0] - lead_done;
                let extent = lead_extent as usize;
                if extent > remaining || (extent != span && extent != remaining) {
                    return Err(VszError::format(format!("bad chunk extent {extent}")));
                }
                lead_done += extent;
                let mut h = header.header;
                h.dims.shape[0] = extent;
                if let Some(m) = meta {
                    h.block_size = m.block_size;
                    // only v3 has a footer to cross-check against
                    observed.push(ChunkIndexEntry {
                        offset: frame_start as u64,
                        frame_len: (format::STREAM_HEADER_LEN + c.pos() - frame_start) as u64,
                        lead_extent,
                        meta: m,
                    });
                }
                chunks.push((h, sections));
            }
            Frame::End { n_chunks } => {
                if n_chunks as usize != chunks.len() {
                    return Err(VszError::format(format!(
                        "trailer says {n_chunks} chunks, read {}",
                        chunks.len()
                    )));
                }
                break;
            }
        }
    }
    if lead_done != dims.shape[0] {
        return Err(VszError::format("stream ended before the field was complete"));
    }
    if header.version >= format::VERSION3 {
        // the remaining bytes must be exactly the index footer, and its
        // entries must describe exactly the frames we just walked
        let rest = c.remaining();
        if rest < 10 {
            return Err(VszError::format("missing index footer"));
        }
        let footer = c.take(rest).unwrap();
        let len = u32::from_le_bytes(footer[rest - 4..].try_into().unwrap()) as usize;
        if len + 4 != rest {
            return Err(VszError::format("index footer length does not match the container"));
        }
        let (entries, parity) = format::read_index_footer_any(&footer[..rest - 4])?;
        if entries != observed {
            return Err(VszError::format("index footer disagrees with the chunk frames"));
        }
        let footer_parity = parity.map(|p| p.entries).unwrap_or_default();
        if footer_parity != observed_parity {
            return Err(VszError::format("index footer disagrees with the parity frames"));
        }
    } else if c.remaining() != 0 {
        return Err(VszError::format("trailing garbage after stream trailer"));
    }

    let threads = threads.max(1);
    let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
    let slabs = decode_batch(chunks, pool.as_ref())?;
    let row_elems = dims.shape[1] * dims.shape[2];
    let mut data = Vec::with_capacity(dims.len());
    for slab in &slabs {
        data.extend_from_slice(slab);
    }
    debug_assert_eq!(data.len(), dims.shape[0] * row_elems);
    Ok(Field::new("decompressed", dims, data))
}

// ------------------------------------------- crash recovery: salvage

/// One quarantined span of a damaged container: the chunks that could not
/// be reconstructed between two recovered (or terminal) positions.
#[derive(Clone, Debug)]
pub struct SalvageHole {
    /// First missing chunk index.
    pub chunk_index: u64,
    /// Number of consecutive missing chunks.
    pub n_chunks: u64,
    /// Leading-dim rows the hole covers.
    pub rows: Range<usize>,
    /// Byte offset where the damage was first observed.
    pub byte_offset: u64,
    /// What went wrong (CRC mismatch, truncation, decode failure, …).
    pub reason: String,
}

/// Outcome of a [`StreamDecompressor::salvage`] walk.
#[derive(Clone, Debug, Default)]
pub struct SalvageReport {
    /// Chunks the container should hold (from header dims / chunk span).
    pub total_chunks: u64,
    /// Leading-dim rows the full field holds.
    pub total_rows: usize,
    /// Indices of the chunks reconstructed bit-exactly (CRC-verified).
    pub recovered: Vec<u64>,
    /// Quarantined spans, in file order.
    pub holes: Vec<SalvageHole>,
    /// Rows covered by recovered chunks.
    pub rows_recovered: usize,
    /// Whether the v3 index footer loaded and validated.
    pub footer_ok: bool,
    /// Whether a CRC-valid END trailer was seen.
    pub trailer_found: bool,
}

impl SalvageReport {
    /// Fully intact: every chunk recovered and the terminal records agree.
    pub fn is_complete(&self) -> bool {
        self.holes.is_empty() && self.recovered.len() as u64 == self.total_chunks
    }

    /// Hole report as JSON (the `vsz stream salvage` output).
    pub fn to_json(&self) -> String {
        let holes: Vec<String> = self
            .holes
            .iter()
            .map(|h| {
                format!(
                    "{{\"chunk\":{},\"n_chunks\":{},\"rows\":[{},{}],\"byte_offset\":{},\
                     \"reason\":\"{}\"}}",
                    h.chunk_index,
                    h.n_chunks,
                    h.rows.start,
                    h.rows.end,
                    h.byte_offset,
                    crate::util::json::escape(&h.reason)
                )
            })
            .collect();
        format!(
            "{{\"total_chunks\":{},\"recovered_chunks\":{},\"rows_recovered\":{},\
             \"total_rows\":{},\"footer_ok\":{},\"trailer_found\":{},\"complete\":{},\
             \"holes\":[{}]}}",
            self.total_chunks,
            self.recovered.len(),
            self.rows_recovered,
            self.total_rows,
            self.footer_ok,
            self.trailer_found,
            self.is_complete(),
            holes.join(",")
        )
    }
}

impl<R: Read + Seek> StreamDecompressor<R> {
    /// Best-effort reconstruction of a damaged container.
    ///
    /// When the v3 index footer loads and validates, every entry is tried
    /// independently: a chunk whose frame fails its CRC (or decode) is
    /// quarantined and the walk continues at the next entry. When the
    /// footer is missing or corrupt (torn tail, truncation, v2 input), the
    /// file is walked front-to-back instead: frames parse sequentially,
    /// and after a corrupt region the scan resynchronizes on the next
    /// byte-offset whose frame parses CRC-clean with a plausible chunk
    /// index and extent. Either way the result is every reconstructable
    /// chunk (bit-exact — nothing CRC-failed is ever returned) plus a
    /// [`SalvageReport`] naming the holes.
    ///
    /// The stream header itself must be intact — without its dims, error
    /// bound and chunk span nothing can be reconstructed or validated.
    pub fn salvage(&mut self) -> Result<(Vec<DecodedChunk>, SalvageReport)> {
        let dims = self.header.header.dims;
        let span = self.header.chunk_span as usize;
        if span == 0 {
            return Err(VszError::format("salvage: header declares a zero chunk span"));
        }
        let total_rows = dims.shape[0];
        let total_chunks = total_rows.div_ceil(span) as u64;
        let mut report = SalvageReport {
            total_chunks,
            total_rows,
            ..SalvageReport::default()
        };
        // extent chunk `k` must have under the header geometry
        let extent_of =
            |k: u64| -> usize { (total_rows - (k as usize * span).min(total_rows)).min(span) };
        let rows_of = |k: u64| -> Range<usize> {
            let lo = (k as usize * span).min(total_rows);
            lo..(lo + extent_of(k)).min(total_rows)
        };

        let mut out: Vec<DecodedChunk> = Vec::new();
        if self.header.version >= format::VERSION3 {
            if let Ok(idx) = self.read_index() {
                // footer-guided: every frame's byte range is known, so a
                // corrupt chunk quarantines alone and costs no resync
                report.footer_ok = true;
                report.trailer_found = true; // validate_index bounds the trailer
                let has_parity = idx.parity.is_some();
                self.index = Some(idx.clone());
                for k in 0..idx.n_chunks() {
                    let e = idx.entries[k];
                    let mut parsed = self.parse_indexed_frame(k);
                    if parsed.is_err() && has_parity {
                        // one lost frame per group is reconstructable; the
                        // rebuilt bytes pass the same CRC acceptance gate
                        if let Ok(rebuilt) = self.rebuild_indexed_frame(k) {
                            parsed = Ok(rebuilt);
                        }
                    }
                    match parsed.and_then(|(h, sections)| {
                        let extent = h.dims.shape[0];
                        decode_body(&h, &sections, 1).map(|d| (extent, d))
                    }) {
                        Ok((extent, data)) => {
                            out.push(DecodedChunk {
                                index: k as u64,
                                lead_offset: idx.lead_offsets[k],
                                lead_extent: extent,
                                data,
                            });
                            report.recovered.push(k as u64);
                            report.rows_recovered += extent;
                        }
                        Err(err) => report.holes.push(SalvageHole {
                            chunk_index: k as u64,
                            n_chunks: 1,
                            rows: rows_of(k as u64),
                            byte_offset: e.offset,
                            reason: err.to_string(),
                        }),
                    }
                }
                return Ok((out, report));
            }
        }

        // sequential walk with resynchronization
        let file_len = self.input.seek(SeekFrom::End(0))?;
        let mut pos = format::STREAM_HEADER_LEN as u64;
        let mut expected: u64 = 0;
        let mut pending_hole: Option<(u64, u64, String)> = None; // (first chunk, byte, reason)
        let mut close_hole =
            |report: &mut SalvageReport, pending: &mut Option<(u64, u64, String)>, upto: u64| {
                if let Some((first, byte, reason)) = pending.take() {
                    if upto > first {
                        report.holes.push(SalvageHole {
                            chunk_index: first,
                            n_chunks: upto - first,
                            rows: (first as usize * span).min(total_rows)
                                ..(upto as usize * span).min(total_rows),
                            byte_offset: byte,
                            reason,
                        });
                    }
                }
            };
        while expected < total_chunks && pos < file_len {
            self.input.seek(SeekFrom::Start(pos))?;
            match read_frame_io(&mut self.input, self.header.version) {
                Ok(Frame::Chunk { index, lead_extent, meta, sections }) => {
                    let end = self.input.stream_position()?;
                    let plausible = index >= expected
                        && index < total_chunks
                        && lead_extent as usize == extent_of(index);
                    if !plausible {
                        // CRC-clean but geometrically wrong (e.g. a stale
                        // frame after truncation+rewrite): treat as damage
                        if pending_hole.is_none() {
                            pending_hole =
                                Some((expected, pos, format!("implausible frame at {pos}")));
                        }
                        match self.resync(pos + 1, file_len, expected, total_chunks)? {
                            Some(next) => pos = next,
                            None => break,
                        }
                        continue;
                    }
                    if index > expected && pending_hole.is_none() {
                        pending_hole = Some((expected, pos, "frames skipped".into()));
                    }
                    close_hole(&mut report, &mut pending_hole, index);
                    let h = self.chunk_header(lead_extent as usize, meta);
                    match decode_body(&h, &sections, 1) {
                        Ok(data) => {
                            out.push(DecodedChunk {
                                index,
                                lead_offset: (index as usize) * span,
                                lead_extent: lead_extent as usize,
                                data,
                            });
                            report.recovered.push(index);
                            report.rows_recovered += lead_extent as usize;
                        }
                        Err(err) => report.holes.push(SalvageHole {
                            chunk_index: index,
                            n_chunks: 1,
                            rows: rows_of(index),
                            byte_offset: pos,
                            reason: format!("decode failed: {err}"),
                        }),
                    }
                    expected = index + 1;
                    pos = end;
                }
                // parity frames carry no field data: step over them
                Ok(Frame::Parity { .. }) => {
                    pos = self.input.stream_position()?;
                }
                Ok(Frame::End { .. }) => {
                    report.trailer_found = true;
                    break;
                }
                Err(err) => {
                    if pending_hole.is_none() {
                        pending_hole = Some((expected, pos, err.to_string()));
                    }
                    match self.resync(pos + 1, file_len, expected, total_chunks)? {
                        Some(next) => pos = next,
                        None => break,
                    }
                }
            }
        }
        // all chunks recovered: the loop exits before touching the
        // trailer, so probe for it separately (report completeness only),
        // stepping over any parity frames between the data and the trailer
        if !report.trailer_found && expected == total_chunks && pos < file_len {
            self.input.seek(SeekFrom::Start(pos))?;
            loop {
                match read_frame_io(&mut self.input, self.header.version) {
                    Ok(Frame::Parity { .. }) => continue,
                    Ok(Frame::End { .. }) => {
                        report.trailer_found = true;
                        break;
                    }
                    _ => break,
                }
            }
        }
        close_hole(&mut report, &mut pending_hole, total_chunks);
        if expected < total_chunks && report.holes.last().map(|h| h.chunk_index + h.n_chunks)
            != Some(total_chunks)
        {
            report.holes.push(SalvageHole {
                chunk_index: expected,
                n_chunks: total_chunks - expected,
                rows: (expected as usize * span).min(total_rows)..total_rows,
                byte_offset: file_len,
                reason: "container ends early".into(),
            });
        }
        Ok((out, report))
    }

    /// Scan forward from `from` for the next byte offset whose frame
    /// parses CRC-clean with a plausible index/extent (or a valid END
    /// trailer). Returns the offset to resume the walk at, or `None` when
    /// the rest of the file yields nothing.
    fn resync(
        &mut self,
        from: u64,
        file_len: u64,
        expected: u64,
        total_chunks: u64,
    ) -> Result<Option<u64>> {
        let span = self.header.chunk_span as usize;
        let total_rows = self.header.header.dims.shape[0];
        let extent_of =
            |k: u64| -> usize { (total_rows - (k as usize * span).min(total_rows)).min(span) };
        let mut window = vec![0u8; 64 * 1024];
        let mut base = from;
        while base < file_len {
            let take = window.len().min((file_len - base) as usize);
            self.input.seek(SeekFrom::Start(base))?;
            self.input.read_exact(&mut window[..take])?;
            for i in 0..take {
                let marker = window[i];
                if marker != format::CHUNK_TAG && marker != format::END_TAG {
                    continue;
                }
                let cand = base + i as u64;
                self.input.seek(SeekFrom::Start(cand))?;
                match read_frame_io(&mut self.input, self.header.version) {
                    Ok(Frame::Chunk { index, lead_extent, .. })
                        if index >= expected
                            && index < total_chunks
                            && lead_extent as usize == extent_of(index) =>
                    {
                        return Ok(Some(cand));
                    }
                    Ok(Frame::End { .. }) => return Ok(Some(cand)),
                    _ => {}
                }
            }
            base += take as u64;
        }
        Ok(None)
    }
}

// ------------------------------------------ integrity: scrub & repair

/// Outcome of a [`scrub_container`] walk: what was checked, what was
/// damaged, and (in repair mode) what was fixed in place.
#[derive(Clone, Debug, Default)]
pub struct ScrubReport {
    /// Data chunks the footer indexes.
    pub n_chunks: u64,
    /// Parity groups (0 for a parity-less container).
    pub n_parity: u64,
    /// Parity group size from the footer (0 = no parity layer).
    pub group_size: u64,
    /// Data chunks whose frame failed its CRC / parse / index cross-check.
    pub bad_chunks: Vec<u64>,
    /// Parity groups whose parity frame failed the same checks.
    pub bad_parity: Vec<u64>,
    /// The END trailer matched its expected bytes at its expected offset.
    pub trailer_ok: bool,
    /// Chunks rebuilt in place from parity (repair mode).
    pub repaired_chunks: Vec<u64>,
    /// Parity frames regenerated in place from intact data (repair mode).
    pub repaired_parity: Vec<u64>,
    /// The trailer was rewritten in place (repair mode).
    pub repaired_trailer: bool,
    /// Groups with two or more damaged frames — beyond single-XOR parity.
    pub unrepairable_groups: Vec<u64>,
}

impl ScrubReport {
    /// Fully intact after this walk: every damaged frame was repaired (or
    /// none was damaged) and no group is beyond repair.
    pub fn is_clean(&self) -> bool {
        self.unrepairable_groups.is_empty()
            && (self.trailer_ok || self.repaired_trailer)
            && self.bad_chunks.iter().all(|k| self.repaired_chunks.contains(k))
            && self.bad_parity.iter().all(|g| self.repaired_parity.contains(g))
    }

    /// Integrity report as JSON (the `vsz stream scrub` output).
    pub fn to_json(&self) -> String {
        fn arr(v: &[u64]) -> String {
            let items: Vec<String> = v.iter().map(|x| x.to_string()).collect();
            format!("[{}]", items.join(","))
        }
        format!(
            "{{\"n_chunks\":{},\"n_parity\":{},\"group_size\":{},\"trailer_ok\":{},\
             \"bad_chunks\":{},\"bad_parity\":{},\"repaired_chunks\":{},\
             \"repaired_parity\":{},\"repaired_trailer\":{},\
             \"unrepairable_groups\":{},\"clean\":{}}}",
            self.n_chunks,
            self.n_parity,
            self.group_size,
            self.trailer_ok,
            arr(&self.bad_chunks),
            arr(&self.bad_parity),
            arr(&self.repaired_chunks),
            arr(&self.repaired_parity),
            self.repaired_trailer,
            arr(&self.unrepairable_groups),
            self.is_clean(),
        )
    }
}

/// Does `buf` parse as chunk `k`'s complete, CRC-clean frame matching its
/// index entry?
fn chunk_frame_bytes_ok(buf: &[u8], version: u16, k: u64, e: &ChunkIndexEntry) -> bool {
    let mut c = crate::bitio::Cursor::new(buf);
    match format::read_frame(&mut c, version) {
        Ok(Frame::Chunk { index, lead_extent, meta, .. }) => {
            index == k
                && lead_extent == e.lead_extent
                && meta.map(|m| m.block_size) == Some(e.meta.block_size)
                && c.remaining() == 0
        }
        _ => false,
    }
}

/// Parse `buf` as group `g`'s complete, CRC-clean parity frame with the
/// expected member count, returning its payload.
fn parity_frame_payload(buf: &[u8], version: u16, g: u64, members: u64) -> Option<Vec<u8>> {
    let mut c = crate::bitio::Cursor::new(buf);
    match format::read_frame(&mut c, version) {
        Ok(Frame::Parity { group, members: m, payload })
            if group == g && m == members && c.remaining() == 0 =>
        {
            Some(payload)
        }
        _ => None,
    }
}

/// Walk every data frame, parity frame and the trailer of an in-memory v3
/// container against its (intact) header and index footer, reporting every
/// CRC/parse/cross-check failure. With `repair` set, damage is fixed in
/// place wherever the parity layer allows it: a single lost data frame per
/// group is rebuilt from the XOR of the survivors (and accepted only once
/// the rebuilt bytes pass their own CRCs), a lost parity frame is
/// regenerated byte-identically from its intact members, and a damaged
/// trailer is rewritten. Groups with two or more losses are reported as
/// unrepairable — never patched, never a panic. The container length never
/// changes, so callers can rewrite the file atomically from `bytes`.
///
/// The stream header and the index footer must be intact: they are the
/// CRC-protected ground truth every frame is checked against.
pub fn scrub_container(bytes: &mut [u8], repair: bool) -> Result<ScrubReport> {
    if bytes.len() < format::STREAM_HEADER_LEN {
        return Err(VszError::format("truncated stream header"));
    }
    let header = format::read_stream_header(&bytes[..format::STREAM_HEADER_LEN])?;
    if header.version < format::VERSION3 {
        return Err(VszError::format(
            "scrub needs a v3 indexed container (v2 carries no index to check against)",
        ));
    }
    let file_len = bytes.len() as u64;
    let min = format::STREAM_HEADER_LEN as u64;
    if file_len < min + 4 {
        return Err(VszError::format("truncated container: no index footer"));
    }
    let flen = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap()) as u64;
    if flen < 6 || flen > file_len - min - 4 {
        return Err(VszError::format(format!("implausible index footer length {flen}")));
    }
    let footer_start = (file_len - 4 - flen) as usize;
    let (entries, parity) =
        format::read_index_footer_any(&bytes[footer_start..bytes.len() - 4])?;
    let idx = validate_index(&header, entries, parity, footer_start as u64)?;
    let version = header.version;
    let n = idx.entries.len();
    let g_size = idx.parity.as_ref().map(|p| p.group_size as usize).unwrap_or(0);

    let mut report = ScrubReport {
        n_chunks: n as u64,
        n_parity: idx.parity.as_ref().map(|p| p.entries.len() as u64).unwrap_or(0),
        group_size: g_size as u64,
        ..ScrubReport::default()
    };

    let span = |off: u64, len: u64| off as usize..(off + len) as usize;
    for (k, e) in idx.entries.iter().enumerate() {
        if !chunk_frame_bytes_ok(&bytes[span(e.offset, e.frame_len)], version, k as u64, e) {
            report.bad_chunks.push(k as u64);
        }
    }
    let mut frames_end = idx
        .entries
        .last()
        .map(|e| e.offset + e.frame_len)
        .unwrap_or(format::STREAM_HEADER_LEN as u64);
    if let Some(p) = &idx.parity {
        for (g, pe) in p.entries.iter().enumerate() {
            let lo = g * g_size;
            let members = (n - lo).min(g_size) as u64;
            let buf = &bytes[span(pe.offset, pe.frame_len)];
            if parity_frame_payload(buf, version, g as u64, members).is_none() {
                report.bad_parity.push(g as u64);
            }
        }
        if let Some(pe) = p.entries.last() {
            frames_end = pe.offset + pe.frame_len;
        }
    }

    // the END trailer is fully determined by the (CRC'd) footer, so check
    // it byte-for-byte and regenerate it outright in repair mode
    let mut expect_trailer = Vec::new();
    format::write_trailer(&mut expect_trailer, n as u64);
    let trailer_span = frames_end as usize..footer_start;
    let trailer_len_ok = trailer_span.len() == expect_trailer.len();
    report.trailer_ok = trailer_len_ok && bytes[trailer_span.clone()] == expect_trailer[..];
    if repair && !report.trailer_ok && trailer_len_ok {
        bytes[trailer_span].copy_from_slice(&expect_trailer);
        report.repaired_trailer = true;
    }

    // classify each group's losses; repair where exactly one frame is lost
    if let Some(p) = idx.parity.clone() {
        for (g, pe) in p.entries.iter().enumerate() {
            let lo = g * g_size;
            let hi = (lo + g_size).min(n);
            let bad_members: Vec<usize> = (lo..hi)
                .filter(|j| report.bad_chunks.contains(&(*j as u64)))
                .collect();
            let parity_bad = report.bad_parity.contains(&(g as u64));
            let losses = bad_members.len() + parity_bad as usize;
            if losses >= 2 {
                report.unrepairable_groups.push(g as u64);
                continue;
            }
            if losses == 0 || !repair {
                continue;
            }
            if parity_bad {
                // every member is intact: regenerate the parity frame
                let mut payload = Vec::new();
                for j in lo..hi {
                    let e = idx.entries[j];
                    xor_into(&mut payload, &bytes[span(e.offset, e.frame_len)]);
                }
                let mut frame = Vec::new();
                format::write_parity_frame(&mut frame, g as u64, (hi - lo) as u64, &payload);
                if frame.len() as u64 == pe.frame_len {
                    bytes[span(pe.offset, pe.frame_len)].copy_from_slice(&frame);
                    report.repaired_parity.push(g as u64);
                } else {
                    // geometry disagrees with the footer: not safe to patch
                    report.unrepairable_groups.push(g as u64);
                }
            } else {
                // one data frame lost: XOR the parity payload with every
                // surviving member, truncate to the lost frame's length,
                // and accept only if the rebuilt bytes check out fully
                let k = bad_members[0];
                let e = idx.entries[k];
                let members = (hi - lo) as u64;
                let pbuf = &bytes[span(pe.offset, pe.frame_len)];
                let Some(mut acc) = parity_frame_payload(pbuf, version, g as u64, members)
                else {
                    report.unrepairable_groups.push(g as u64);
                    continue;
                };
                for j in lo..hi {
                    if j == k {
                        continue;
                    }
                    let ej = idx.entries[j];
                    xor_into(&mut acc, &bytes[span(ej.offset, ej.frame_len)]);
                }
                if acc.len() < e.frame_len as usize {
                    report.unrepairable_groups.push(g as u64);
                    continue;
                }
                acc.truncate(e.frame_len as usize);
                if chunk_frame_bytes_ok(&acc, version, k as u64, &e) {
                    bytes[span(e.offset, e.frame_len)].copy_from_slice(&acc);
                    report.repaired_chunks.push(k as u64);
                } else {
                    report.unrepairable_groups.push(g as u64);
                }
            }
        }
    }
    Ok(report)
}

// -------------------------------------------- crash recovery: resume

/// What a scan of a partial container found: everything needed to truncate
/// after the last CRC-valid chunk and continue the run.
#[derive(Clone, Debug)]
pub struct ResumeState {
    /// The partial container's stream header.
    pub header: StreamHeader,
    /// CRC-valid chunks on disk, contiguous from chunk 0.
    pub n_chunks_done: u64,
    /// Leading-dim rows those chunks cover.
    pub rows_done: usize,
    /// Byte offset just past the last valid chunk frame — truncate the
    /// file here before resuming.
    pub truncate_at: u64,
    /// Index entries of the valid chunks (seeds the v3 footer).
    pub index: Vec<ChunkIndexEntry>,
    /// The container already ends in a valid trailer: nothing to resume.
    pub complete: bool,
    /// Parity group size the scan accumulated under (0 = no parity).
    pub parity_group: usize,
    /// XOR payloads of the parity groups the valid prefix completed.
    pub parity_payloads: Vec<Vec<u8>>,
    /// XOR accumulator of the trailing partial group.
    pub parity_acc: Vec<u8>,
    /// Valid frames folded into `parity_acc`.
    pub parity_members: usize,
}

/// Scan a partial container for the longest CRC-valid chunk prefix.
///
/// Walks frames from the header forward; the walk stops at the first torn
/// frame, CRC mismatch, out-of-order index or EOF. Chunks after a damaged
/// one are ignored even if intact — resume rewrites everything past the
/// truncation point, which is what makes the resumed output byte-identical
/// to an uninterrupted run.
pub fn scan_resumable<R: Read + Seek>(input: R) -> Result<ResumeState> {
    scan_resumable_with(input, 0)
}

/// [`scan_resumable`] for a run that writes parity: re-accumulates the XOR
/// parity state of the valid prefix under groups of `parity_group`, so the
/// resumed compressor emits the same parity frames an uninterrupted run
/// would. `parity_group` must match the interrupted run's `--parity` (the
/// partial file records no footer to recover it from); 0 skips parity.
pub fn scan_resumable_with<R: Read + Seek>(
    mut input: R,
    parity_group: usize,
) -> Result<ResumeState> {
    input.seek(SeekFrom::Start(0))?;
    let mut hdr = [0u8; format::STREAM_HEADER_LEN];
    input.read_exact(&mut hdr)?;
    let header = format::read_stream_header(&hdr)?;
    let dims = header.header.dims;
    let span = header.chunk_span as usize;
    if span == 0 {
        return Err(VszError::format("resume: header declares a zero chunk span"));
    }
    let total_rows = dims.shape[0];
    let mut state = ResumeState {
        header,
        n_chunks_done: 0,
        rows_done: 0,
        truncate_at: format::STREAM_HEADER_LEN as u64,
        index: Vec::new(),
        complete: false,
        parity_group,
        parity_payloads: Vec::new(),
        parity_acc: Vec::new(),
        parity_members: 0,
    };
    loop {
        let frame_start = input.stream_position()?;
        match read_frame_io(&mut input, header.version) {
            Ok(Frame::Chunk { index, lead_extent, meta, sections: _ }) => {
                let remaining = total_rows - state.rows_done;
                let extent = lead_extent as usize;
                let good = index == state.n_chunks_done
                    && extent <= remaining
                    && (extent == span || extent == remaining);
                if !good {
                    break;
                }
                let end = input.stream_position()?;
                state.index.push(ChunkIndexEntry {
                    offset: frame_start,
                    frame_len: end - frame_start,
                    lead_extent,
                    meta: meta.unwrap_or(ChunkMeta {
                        block_size: header.header.block_size,
                        width: 0,
                    }),
                });
                if parity_group > 0 {
                    // re-read the raw frame bytes to fold into the group
                    // accumulator (the CRC checks above already passed)
                    let mut raw = vec![0u8; (end - frame_start) as usize];
                    input.seek(SeekFrom::Start(frame_start))?;
                    input.read_exact(&mut raw)?;
                    xor_into(&mut state.parity_acc, &raw);
                    state.parity_members += 1;
                    if state.parity_members == parity_group {
                        state.parity_payloads.push(std::mem::take(&mut state.parity_acc));
                        state.parity_members = 0;
                    }
                }
                state.n_chunks_done += 1;
                state.rows_done += extent;
                state.truncate_at = end;
            }
            // parity frames follow the last data frame: nothing to resume
            // past them, and `truncate_at` must not advance over them —
            // `finish` rewrites the whole parity layer
            Ok(Frame::Parity { .. }) => continue,
            Ok(Frame::End { n_chunks }) => {
                state.complete =
                    n_chunks == state.n_chunks_done && state.rows_done == total_rows;
                break;
            }
            Err(_) => break,
        }
    }
    Ok(state)
}

impl<W: Write> StreamCompressor<W> {
    /// Continue an interrupted run. `out` must already be truncated to
    /// [`ResumeState::truncate_at`] and positioned there; the compressor
    /// seeds its chunk counter, leading-dim position, byte offset and
    /// index entries from `state` and does **not** rewrite the header.
    ///
    /// The request's dims/config/span must reproduce the partial file's
    /// header exactly — chunk geometry is what makes the resumed container
    /// byte-identical to an uninterrupted run — otherwise this errors
    /// before touching the output. Feed only the samples from
    /// [`ResumeState::rows_done`] onward ([`resume_stream_with`] handles
    /// the skip), then [`finish`](Self::finish) as usual; the trailer and
    /// footer cover the pre-crash chunks too.
    pub fn resume(
        out: W,
        dims: Dims,
        cfg: &Config,
        chunk_span: usize,
        opts: StreamOptions,
        state: &ResumeState,
    ) -> Result<Self> {
        let plan = plan_chunks(dims, cfg, chunk_span, opts)?;
        let expect = format::write_stream_header(&state.header)?;
        if plan.header != expect {
            return Err(VszError::config(
                "resume: dims/config/chunk-span do not reproduce the partial \
                 container's header — resuming would not be byte-identical",
            ));
        }
        if state.complete {
            return Err(VszError::config("resume: container is already complete"));
        }
        if opts.parity_group != state.parity_group {
            return Err(VszError::config(format!(
                "resume: parity group {} does not match the scan's {} — \
                 rescan the partial container with the run's --parity",
                opts.parity_group, state.parity_group
            )));
        }
        let ChunkPlan { cfg, span, header: _ } = plan;
        let threads = cfg.threads.max(1);
        let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
        let (tx, rx) = channel();
        let row_elems = dims.shape[1] * dims.shape[2];
        Ok(Self {
            out,
            cfg,
            opts,
            dims,
            chunk_span: span,
            row_elems,
            total_elems: dims.len(),
            received: state.rows_done * row_elems,
            lead_done: state.rows_done,
            buf: Vec::new(),
            chunk_index: state.n_chunks_done,
            stats: StreamStats {
                raw_bytes: dims.len() * 4,
                n_elements: dims.len(),
                // byte offset on disk: header + valid frames — index
                // entries for new chunks continue from here
                compressed_bytes: state.truncate_at as usize,
                n_chunks: state.n_chunks_done as usize,
                ..StreamStats::default()
            },
            index: if opts.version >= format::VERSION3 {
                state.index.clone()
            } else {
                Vec::new()
            },
            parity_payloads: state.parity_payloads.clone(),
            parity_acc: state.parity_acc.clone(),
            parity_members: state.parity_members,
            pool,
            tx,
            rx,
            window: threads,
            in_flight: 0,
            next_write: state.n_chunks_done,
            ready: BTreeMap::new(),
        })
    }
}

/// [`compress_stream_with`] for a resumed run: skips the raw samples the
/// partial container already covers, then continues chunk-for-chunk. The
/// final container is byte-identical to an uninterrupted
/// [`compress_stream_with`] of the same input.
pub fn resume_stream_with<R: Read, W: Write>(
    mut input: R,
    out: W,
    dims: Dims,
    cfg: &Config,
    chunk_span: usize,
    opts: StreamOptions,
    state: &ResumeState,
) -> Result<StreamStats> {
    let sc = StreamCompressor::resume(out, dims, cfg, chunk_span, opts, state)?;
    // discard the bytes of the rows already on disk (plain reads, so
    // non-seekable inputs — pipes — resume too)
    let mut skip = state.rows_done as u64 * sc.row_elems as u64 * 4;
    let mut scratch = vec![0u8; 64 * 1024];
    while skip > 0 {
        let take = scratch.len().min(skip as usize);
        let n = input.read(&mut scratch[..take])?;
        if n == 0 {
            return Err(VszError::format(
                "resume: input ended before the already-compressed prefix",
            ));
        }
        skip -= n as u64;
    }
    drive_stream(input, sc)
}

#[cfg(test)]
mod tests {
    // The deprecated decode_* wrappers stay covered on purpose: they must
    // remain bit-identical to the Dataset region reads that replaced them.
    #![allow(deprecated)]

    use super::*;
    use crate::compressor::{compress, decompress, BackendChoice, Config};
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};
    use crate::util::bytes_to_f32;
    use crate::util::prng::Pcg32;

    fn smooth_field(dims: Dims, seed: u64) -> Field {
        let mut rng = Pcg32::seeded(seed);
        let mut x = 1.0f32;
        let data: Vec<f32> = (0..dims.len())
            .map(|_| {
                x += (rng.next_f32() - 0.5) * 0.1;
                x
            })
            .collect();
        Field::new("t", dims, data)
    }

    fn max_err(a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| (*x as f64 - *y as f64).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn chunked_roundtrip_all_dims_within_bound() {
        for dims in [Dims::d1(3000), Dims::d2(70, 40), Dims::d3(40, 12, 10)] {
            let field = smooth_field(dims, 41);
            let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
            let bs = default_block_size(dims.ndim);
            let (bytes, stats) = compress_chunked(&field, &cfg, bs).unwrap();
            assert!(stats.n_chunks >= 4, "want >=4 chunks, got {} for {dims:?}", stats.n_chunks);
            let rec = decompress_chunked(&bytes, 1).unwrap();
            assert_eq!(rec.dims, dims);
            assert!(max_err(&field.data, &rec.data) <= 1e-3 + 1e-6);
        }
    }

    #[test]
    fn chunk_parallel_decode_is_byte_identical_to_serial() {
        let field = smooth_field(Dims::d2(96, 50), 43);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert!(stats.n_chunks >= 4);
        let serial = decompress_chunked(&bytes, 1).unwrap();
        let parallel = decompress_chunked(&bytes, 4).unwrap();
        assert_eq!(serial.data, parallel.data, "thread count changed decode output");
        assert!(max_err(&field.data, &serial.data) <= 1e-3 + 1e-6);
    }

    #[test]
    fn pipelined_compress_bytes_match_serial() {
        let field = smooth_field(Dims::d2(80, 64), 47);
        let c1 = Config { eb: EbMode::Abs(1e-3), threads: 1, ..Config::default() };
        let c4 = Config { eb: EbMode::Abs(1e-3), threads: 4, ..Config::default() };
        let (b1, s1) = compress_chunked(&field, &c1, 16).unwrap();
        let (b4, s4) = compress_chunked(&field, &c4, 16).unwrap();
        assert_eq!(s1.n_chunks, s4.n_chunks);
        assert_eq!(b1, b4, "chunk pipelining must not change the bitstream");
    }

    #[test]
    fn push_granularity_does_not_change_bytes() {
        // stream the field one awkwardly-sized slice at a time
        let field = smooth_field(Dims::d2(48, 30), 53);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (whole, _) = compress_chunked(&field, &cfg, 16).unwrap();

        let mut sc = StreamCompressor::new(Vec::new(), field.dims, &cfg, 16).unwrap();
        let mut at = 0usize;
        let mut step = 7usize;
        while at < field.data.len() {
            let take = step.min(field.data.len() - at);
            sc.push(&field.data[at..at + take]).unwrap();
            at += take;
            step = step * 2 + 1;
        }
        let (drip, _) = sc.finish().unwrap();
        assert_eq!(whole, drip);
    }

    #[test]
    fn io_streaming_roundtrip() {
        // full Read -> compress -> Read -> decompress -> bytes pipeline
        let field = smooth_field(Dims::d2(64, 32), 59);
        let cfg = Config { eb: EbMode::Abs(1e-3), threads: 2, ..Config::default() };
        let raw: Vec<u8> = f32_as_bytes(&field.data).to_vec();
        let mut container = Vec::new();
        let stats =
            compress_stream(&raw[..], &mut container, field.dims, &cfg, 16).unwrap();
        assert!(stats.n_chunks >= 4);
        assert_eq!(stats.n_elements, field.data.len());

        let mut out = Vec::new();
        let header = decompress_stream(&container[..], &mut out, 3).unwrap();
        assert_eq!(header.header.dims, field.dims);
        let rec = bytes_to_f32(&out);
        assert!(max_err(&field.data, &rec) <= 1e-3 + 1e-6);
    }

    #[test]
    fn incremental_decoder_walks_chunks_in_order() {
        let field = smooth_field(Dims::d2(80, 16), 61);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        let mut dec = StreamDecompressor::new(&bytes[..]).unwrap();
        let mut n = 0usize;
        let mut offset = 0usize;
        while let Some(chunk) = dec.next_chunk().unwrap() {
            assert_eq!(chunk.index as usize, n);
            assert_eq!(chunk.lead_offset, offset);
            offset += chunk.lead_extent;
            n += 1;
        }
        assert_eq!(n, stats.n_chunks);
        assert_eq!(offset, 80);
        // after the trailer the decoder keeps returning None
        assert!(dec.next_chunk().unwrap().is_none());
    }

    #[test]
    fn generic_decompress_dispatches_on_magic() {
        let field = smooth_field(Dims::d2(48, 20), 67);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (v2, _) = compress_chunked(&field, &cfg, 16).unwrap();
        let rec = decompress(&v2, 2).unwrap(); // compressor::decompress
        assert!(max_err(&field.data, &rec.data) <= 1e-3 + 1e-6);
        // and v1 still works through the same entry point
        let (v1, _) = compress(&field, &cfg).unwrap();
        let rec1 = decompress(&v1, 2).unwrap();
        assert_eq!(rec1.dims, field.dims);
    }

    #[test]
    fn rel_eb_rejected_for_streaming() {
        let cfg = Config { eb: EbMode::Rel(1e-3), ..Config::default() };
        let err = StreamCompressor::new(Vec::new(), Dims::d1(100), &cfg, 0).unwrap_err();
        assert!(err.to_string().contains("absolute"), "{err}");
    }

    #[test]
    fn wrong_sample_counts_are_rejected() {
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        // too many
        let mut sc = StreamCompressor::new(Vec::new(), Dims::d1(256), &cfg, 0).unwrap();
        assert!(sc.push(&vec![0.0f32; 300]).is_err());
        // too few
        let mut sc = StreamCompressor::new(Vec::new(), Dims::d1(512), &cfg, 256).unwrap();
        sc.push(&vec![0.0f32; 100]).unwrap();
        assert!(sc.finish().is_err());
    }

    #[test]
    fn sz14_backend_streams_too() {
        let field = smooth_field(Dims::d2(64, 24), 71);
        let cfg = Config {
            eb: EbMode::Abs(1e-3),
            backend: BackendChoice::Sz14,
            ..Config::default()
        };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert!(stats.n_chunks >= 4);
        let rec = decompress_chunked(&bytes, 2).unwrap();
        assert!(max_err(&field.data, &rec.data) <= 1e-3 + 1e-6);
    }

    #[test]
    fn padding_policies_stream_roundtrip() {
        let field = smooth_field(Dims::d2(64, 24), 73);
        for (value, gran) in [
            (PadValue::Avg, PadGranularity::Global),
            (PadValue::Avg, PadGranularity::Block),
            (PadValue::Min, PadGranularity::Edge),
        ] {
            let cfg = Config {
                eb: EbMode::Abs(1e-3),
                padding: PaddingPolicy::new(value, gran),
                ..Config::default()
            };
            let (bytes, _) = compress_chunked(&field, &cfg, 16).unwrap();
            let rec = decompress_chunked(&bytes, 2).unwrap();
            assert!(
                max_err(&field.data, &rec.data) <= 1e-3 + 1e-6,
                "padding {value:?}/{gran:?}"
            );
        }
    }

    #[test]
    fn chunked_corruption_and_truncation_rejected() {
        let field = smooth_field(Dims::d2(64, 24), 79);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, _) = compress_chunked(&field, &cfg, 16).unwrap();
        assert!(decompress_chunked(&bytes, 1).is_ok());
        // flip a byte every 97 positions across the whole container
        for at in (4..bytes.len()).step_by(97) {
            let mut bad = bytes.clone();
            bad[at] ^= 0x5A;
            match decompress_chunked(&bad, 1) {
                Err(_) => {}
                Ok(rec) => assert_eq!(
                    rec.data.len(),
                    field.data.len(),
                    "flip at {at} silently changed the field shape"
                ),
            }
        }
        // truncations: header, mid-chunk, before trailer, inside trailer
        for cut in [0, 10, format::STREAM_HEADER_LEN, bytes.len() / 2, bytes.len() - 1] {
            assert!(decompress_chunked(&bytes[..cut], 1).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn default_chunk_span_is_block_aligned() {
        for dims in [Dims::d1(1 << 22), Dims::d2(4000, 500), Dims::d3(300, 100, 100)] {
            let bs = default_block_size(dims.ndim);
            let span = default_chunk_span(dims, 0);
            assert_eq!(span % bs, 0);
            assert!(span >= bs);
        }
    }

    // ------------------------------------------------ v3 random access

    /// Footer size in bytes (length word included), read from the tail.
    fn footer_total(container: &[u8]) -> usize {
        let n = container.len();
        u32::from_le_bytes(container[n - 4..].try_into().unwrap()) as usize + 4
    }

    #[test]
    fn default_output_is_v3_with_index() {
        let field = smooth_field(Dims::d2(80, 32), 83);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert_eq!(&bytes[..4], format::MAGIC3);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        assert_eq!(dec.header().version, format::VERSION3);
        let idx = dec.load_index().unwrap();
        assert_eq!(idx.n_chunks(), stats.n_chunks);
        // entries tile the leading dimension and point at contiguous frames
        assert_eq!(idx.lead_offsets[0], 0);
        assert_eq!(
            idx.entries.iter().map(|e| e.lead_extent as usize).sum::<usize>(),
            field.dims.shape[0]
        );
        assert_eq!(idx.entries[0].offset as usize, format::STREAM_HEADER_LEN);
    }

    #[test]
    fn decode_chunk_matches_full_decode_slabs() {
        let field = smooth_field(Dims::d2(96, 40), 89);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert!(stats.n_chunks >= 4);
        let full = decompress_chunked(&bytes, 1).unwrap();
        let row_elems = 40;
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        for k in 0..stats.n_chunks {
            let c = dec.decode_chunk(k).unwrap();
            let lo = c.lead_offset * row_elems;
            let hi = lo + c.lead_extent * row_elems;
            assert_eq!(c.data, &full.data[lo..hi], "chunk {k}");
        }
        assert!(dec.decode_chunk(stats.n_chunks).is_err(), "out-of-range chunk accepted");
    }

    #[test]
    fn decode_range_and_rows_thread_invariant() {
        let field = smooth_field(Dims::d2(112, 24), 97);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert!(stats.n_chunks >= 7);
        let full = decompress_chunked(&bytes, 1).unwrap();
        let row_elems = 24;
        for threads in [1usize, 2, 7] {
            let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
            let r = dec.decode_range(1..4, threads).unwrap();
            assert_eq!(r, &full.data[16 * row_elems..64 * row_elems], "{threads} threads");
            let rows = dec.decode_rows(13..50, threads).unwrap();
            assert_eq!(rows, &full.data[13 * row_elems..50 * row_elems], "{threads} threads");
            // whole field through decode_rows == full decode
            let all = dec.decode_rows(0..112, threads).unwrap();
            assert_eq!(all, full.data);
        }
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        assert!(dec.decode_range(2..2, 1).is_err());
        assert!(dec.decode_rows(40..30, 1).is_err());
        assert!(dec.decode_rows(0..113, 1).is_err());
    }

    #[test]
    fn decode_cols_matches_full_decode_2d() {
        let field = smooth_field(Dims::d2(96, 40), 211);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert!(stats.n_chunks >= 6);
        let full = decompress_chunked(&bytes, 1).unwrap();
        let (lo, hi) = (7usize, 29usize);
        let expect: Vec<f32> =
            (0..96).flat_map(|r| full.data[r * 40 + lo..r * 40 + hi].to_vec()).collect();
        for threads in [1usize, 2, 7] {
            let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
            let cols = dec.decode_cols(lo..hi, threads).unwrap();
            assert_eq!(cols, expect, "{threads} threads");
            // decode_dim(1) is the same axis on a 2D field
            let via_dim = dec.decode_dim(1, lo..hi, threads).unwrap();
            assert_eq!(via_dim, expect);
        }
        // full-width column range == full decode
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        assert_eq!(dec.decode_cols(0..40, 2).unwrap(), full.data);
    }

    #[test]
    fn decode_dim_matches_full_decode_3d_all_axes() {
        let field = smooth_field(Dims::d3(24, 10, 12), 223);
        let cfg = Config { eb: EbMode::Abs(1e-3), block_size: 4, ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 4).unwrap();
        assert!(stats.n_chunks >= 6);
        let full = decompress_chunked(&bytes, 1).unwrap();
        let at = |k: usize, i: usize, j: usize| full.data[(k * 10 + i) * 12 + j];
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        // dim 0 (pruned chunks) via decode_dim == decode_rows
        assert_eq!(
            dec.decode_dim(0, 5..17, 2).unwrap(),
            dec.decode_rows(5..17, 2).unwrap()
        );
        // dim 1: middle-axis plane range [3, 8)
        let mut expect = Vec::new();
        for k in 0..24 {
            for i in 3..8 {
                for j in 0..12 {
                    expect.push(at(k, i, j));
                }
            }
        }
        for threads in [1usize, 3] {
            assert_eq!(dec.decode_dim(1, 3..8, threads).unwrap(), expect, "{threads}T");
        }
        // dim 2: column range [2, 9) via decode_cols
        let mut expect = Vec::new();
        for k in 0..24 {
            for i in 0..10 {
                for j in 2..9 {
                    expect.push(at(k, i, j));
                }
            }
        }
        assert_eq!(dec.decode_cols(2..9, 2).unwrap(), expect);
    }

    #[test]
    fn decode_dim_rejects_bad_inputs() {
        let field = smooth_field(Dims::d2(48, 20), 227);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, _) = compress_chunked(&field, &cfg, 16).unwrap();
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        assert!(dec.decode_dim(2, 0..1, 1).is_err(), "dim beyond ndim accepted");
        assert!(dec.decode_dim(1, 5..5, 1).is_err(), "empty range accepted");
        assert!(dec.decode_dim(1, 0..21, 1).is_err(), "overlong range accepted");
        assert!(dec.decode_cols(19..21, 1).is_err());
        // v2 containers carry no index: column access reports that cleanly
        let opts = StreamOptions { version: format::VERSION2, ..StreamOptions::default() };
        let (v2, _) = compress_chunked_with(&field, &cfg, 16, opts).unwrap();
        let mut dec2 = StreamDecompressor::new(std::io::Cursor::new(&v2)).unwrap();
        let err = dec2.decode_cols(0..5, 1).unwrap_err();
        assert!(err.to_string().contains("no chunk index"), "{err}");
    }

    #[test]
    fn footer_corruption_and_truncation_sweep_rejected() {
        let field = smooth_field(Dims::d2(64, 24), 101);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, _) = compress_chunked(&field, &cfg, 16).unwrap();
        let ft = footer_total(&bytes);
        let start = bytes.len() - ft;
        // every byte of the footer (entries, crc, trailing length word)
        for at in start..bytes.len() {
            let mut bad = bytes.clone();
            bad[at] ^= 0x3C;
            let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bad)).unwrap();
            assert!(dec.load_index().is_err(), "footer flip at {at} accepted");
            // the full decoder cross-checks the footer too
            assert!(decompress_chunked(&bad, 1).is_err(), "full decode accepted flip at {at}");
            // salvage must fall back to the sequential walk and still
            // recover every chunk — the frames and trailer are intact
            let mut sdec = StreamDecompressor::new(std::io::Cursor::new(&bad)).unwrap();
            let (_, report) = sdec.salvage().unwrap();
            assert!(!report.footer_ok, "flip at {at}: footer accepted by salvage");
            assert!(report.is_complete(), "flip at {at}: salvage lost chunks");
            assert!(report.trailer_found, "flip at {at}: trailer missed");
        }
        // footer truncations: random access must fail cleanly, salvage
        // must recover everything (only footer bytes are missing)
        for cut in [bytes.len() - 1, bytes.len() - 4, bytes.len() - ft + 2, start] {
            let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes[..cut])).unwrap();
            assert!(dec.load_index().is_err(), "cut at {cut} accepted");
            assert!(decompress_chunked(&bytes[..cut], 1).is_err());
            let mut sdec = StreamDecompressor::new(std::io::Cursor::new(&bytes[..cut])).unwrap();
            let (_, report) = sdec.salvage().unwrap();
            assert!(report.is_complete(), "cut at {cut}: salvage lost chunks");
        }
    }

    #[test]
    fn salvage_quarantines_a_corrupt_chunk_and_recovers_the_rest() {
        let field = smooth_field(Dims::d2(64, 24), 211);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert_eq!(stats.n_chunks, 4);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        let entries = dec.load_index().unwrap().entries.clone();
        let reference: Vec<DecodedChunk> = (0..4).map(|k| dec.decode_chunk(k).unwrap()).collect();

        // flip a payload byte inside chunk 1's frame: the footer is still
        // valid, so the footer-guided path quarantines exactly that chunk
        let mut bad = bytes.clone();
        let mid = (entries[1].offset + entries[1].frame_len * 3 / 4) as usize;
        bad[mid] ^= 0x5A;
        let mut sdec = StreamDecompressor::new(std::io::Cursor::new(&bad)).unwrap();
        let (chunks, report) = sdec.salvage().unwrap();
        assert!(report.footer_ok);
        assert_eq!(report.recovered, vec![0, 2, 3]);
        assert_eq!(report.rows_recovered, 48);
        assert_eq!(report.holes.len(), 1, "{:?}", report.holes);
        assert_eq!(report.holes[0].chunk_index, 1);
        assert_eq!(report.holes[0].n_chunks, 1);
        assert_eq!(report.holes[0].rows, 16..32);
        assert!(!report.is_complete());
        for c in &chunks {
            let r = &reference[c.index as usize];
            assert_eq!(c.lead_offset, r.lead_offset);
            assert_eq!(c.data, r.data, "salvaged chunk {} not bit-exact", c.index);
        }
        let json = report.to_json();
        assert!(json.contains("\"complete\":false"), "{json}");
        assert!(json.contains("\"rows\":[16,32]"), "{json}");

        // damage the footer too: the sequential walk must resynchronize
        // past the bad frame and recover the same three chunks
        let flen = footer_total(&bad);
        let blen = bad.len();
        bad[blen - flen] ^= 0xFF;
        let mut sdec = StreamDecompressor::new(std::io::Cursor::new(&bad)).unwrap();
        let (chunks2, report2) = sdec.salvage().unwrap();
        assert!(!report2.footer_ok);
        assert!(report2.trailer_found, "sequential walk must still find the END trailer");
        assert_eq!(report2.recovered, vec![0, 2, 3]);
        assert_eq!(report2.holes.len(), 1);
        assert_eq!(report2.holes[0].chunk_index, 1);
        assert_eq!(chunks2.len(), chunks.len());
        for (a, b) in chunks2.iter().zip(chunks.iter()) {
            assert_eq!(a.data, b.data, "footer-guided and sequential salvage disagree");
        }
    }

    #[test]
    fn resume_completes_truncated_containers_byte_identically() {
        let field = smooth_field(Dims::d2(64, 24), 223);
        let cfg = Config { eb: EbMode::Abs(1e-3), threads: 1, ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert_eq!(stats.n_chunks, 4);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        let entries = dec.load_index().unwrap().entries.clone();
        let raw: Vec<u8> = field.data.iter().flat_map(|x| x.to_le_bytes()).collect();

        // interrupt right after the header, at every clean frame boundary,
        // and torn mid-frame: resume must complete each to the exact bytes
        let mut cuts = vec![format::STREAM_HEADER_LEN as u64];
        for e in &entries {
            cuts.push(e.offset + e.frame_len);
            cuts.push(e.offset + e.frame_len / 2);
        }
        for cut in cuts {
            let prefix = &bytes[..cut as usize];
            let state = scan_resumable(std::io::Cursor::new(prefix)).unwrap();
            assert!(!state.complete, "cut {cut} cannot be complete");
            assert!(state.truncate_at <= cut, "cut {cut}");
            assert_eq!(state.rows_done, state.n_chunks_done as usize * 16);
            let mut out = bytes[..state.truncate_at as usize].to_vec();
            resume_stream_with(
                std::io::Cursor::new(&raw[..]),
                &mut out,
                field.dims,
                &cfg,
                16,
                StreamOptions::default(),
                &state,
            )
            .unwrap();
            assert_eq!(out, bytes, "cut {cut}: resumed container is not byte-identical");
        }

        // a complete container reports complete and refuses to resume
        let state = scan_resumable(std::io::Cursor::new(&bytes[..])).unwrap();
        assert!(state.complete);
        assert_eq!(state.n_chunks_done, 4);
        let err = StreamCompressor::resume(
            Vec::new(),
            field.dims,
            &cfg,
            16,
            StreamOptions::default(),
            &state,
        )
        .unwrap_err();
        assert!(err.to_string().contains("complete"), "{err}");

        // mismatched settings are rejected before touching the output
        let wrong = Config { eb: EbMode::Abs(2e-3), threads: 1, ..Config::default() };
        let partial =
            scan_resumable(std::io::Cursor::new(&bytes[..entries[1].offset as usize + 4])).unwrap();
        assert_eq!(partial.n_chunks_done, 1);
        let err = StreamCompressor::resume(
            Vec::new(),
            field.dims,
            &wrong,
            16,
            StreamOptions::default(),
            &partial,
        )
        .unwrap_err();
        assert!(err.to_string().contains("byte-identical"), "{err}");
    }

    #[test]
    fn random_access_does_not_derail_sequential_decode() {
        // load_index + decode_chunk seek around; the sequential walk over
        // the same decoder must still see every frame in order
        let field = smooth_field(Dims::d2(64, 24), 137);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        let full = decompress_chunked(&bytes, 1).unwrap();
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        assert_eq!(dec.load_index().unwrap().n_chunks(), stats.n_chunks);
        let probe = dec.decode_chunk(stats.n_chunks - 1).unwrap();
        assert_eq!(probe.lead_offset, 48);
        let mut n = 0usize;
        while let Some(c) = dec.next_chunk().unwrap() {
            assert_eq!(c.index as usize, n, "sequential walk derailed after random access");
            assert_eq!(c.data, &full.data[c.lead_offset * 24..(c.lead_offset + 16) * 24]);
            n += 1;
        }
        assert_eq!(n, stats.n_chunks);
    }

    #[test]
    fn forged_huge_frame_len_in_footer_rejected_without_allocating() {
        // a CRC-consistent footer whose entry claims a near-u64::MAX (or
        // merely file-exceeding) frame_len must fail validation — never
        // reach the frame allocation
        let field = smooth_field(Dims::d2(64, 24), 139);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, _) = compress_chunked(&field, &cfg, 16).unwrap();
        let ft = footer_total(&bytes);
        let body = bytes[..bytes.len() - ft].to_vec();
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        let good = dec.load_index().unwrap().entries.clone();
        for forged_len in [u64::MAX - 60, u64::MAX - 4, 1u64 << 40, bytes.len() as u64] {
            let mut entries = good.clone();
            entries[0].frame_len = forged_len;
            let mut forged = body.clone();
            format::write_index_footer(&mut forged, &entries);
            let mut dec = StreamDecompressor::new(std::io::Cursor::new(&forged)).unwrap();
            assert!(dec.load_index().is_err(), "forged frame_len {forged_len} accepted");
        }
    }

    #[test]
    fn v2_option_still_writes_legacy_containers() {
        let field = smooth_field(Dims::d2(64, 24), 103);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let opts = StreamOptions { version: format::VERSION2, ..StreamOptions::default() };
        let (v2, stats) = compress_chunked_with(&field, &cfg, 16, opts).unwrap();
        assert_eq!(&v2[..4], format::MAGIC2);
        assert!(stats.n_chunks >= 4);
        let rec = decompress_chunked(&v2, 2).unwrap();
        assert!(max_err(&field.data, &rec.data) <= 1e-3 + 1e-6);
        // no index footer on v2: random access reports that cleanly
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&v2)).unwrap();
        let err = dec.load_index().unwrap_err();
        assert!(err.to_string().contains("no chunk index"), "{err}");
        // and the generic entry point still dispatches
        let rec2 = decompress(&v2, 2).unwrap();
        assert_eq!(rec.data, rec2.data);
    }

    #[test]
    fn v2_and_v3_frames_differ_only_by_config_and_footer() {
        // same field, both versions: v3 adds 2 bytes of per-chunk config
        // per frame plus the footer; the section payloads are identical
        let field = smooth_field(Dims::d2(48, 30), 107);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (v3, s3) = compress_chunked(&field, &cfg, 16).unwrap();
        let opts = StreamOptions { version: format::VERSION2, ..StreamOptions::default() };
        let (v2, s2) = compress_chunked_with(&field, &cfg, 16, opts).unwrap();
        assert_eq!(s2.n_chunks, s3.n_chunks);
        let overhead = v3.len() - v2.len();
        assert_eq!(overhead, 2 * s3.n_chunks + footer_total(&v3));
        let a = decompress_chunked(&v2, 1).unwrap();
        let b = decompress_chunked(&v3, 1).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn chunk_autotune_requires_v3() {
        let opts = StreamOptions {
            version: format::VERSION2,
            chunk_autotune: Some(TuneSettings::default()),
            ..StreamOptions::default()
        };
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let err =
            StreamCompressor::with_options(Vec::new(), Dims::d1(512), &cfg, 0, opts).unwrap_err();
        assert!(err.to_string().contains("v3"), "{err}");
    }

    #[test]
    fn per_chunk_autotune_roundtrips_and_records_grid_configs() {
        // chunks of 64 x 256 = 16384 elems == CHUNK_AUTOTUNE_MIN_ELEMS, so
        // the tuner actually runs on every chunk
        let field = smooth_field(Dims::d2(256, 256), 109);
        let cfg = Config { eb: EbMode::Abs(1e-3), threads: 2, ..Config::default() };
        let opts = StreamOptions {
            chunk_autotune: Some(TuneSettings { sample_pct: 20.0, iterations: 1, seed: 5 }),
            ..StreamOptions::default()
        };
        let (bytes, stats) = compress_chunked_with(&field, &cfg, 64, opts).unwrap();
        assert_eq!(stats.n_chunks, 4);
        // whichever configs the heuristic picked, the container decodes
        // within the bound through every path
        let rec = decompress_chunked(&bytes, 3).unwrap();
        assert!(max_err(&field.data, &rec.data) <= 1e-3 + 1e-6);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        for k in 0..4 {
            let c = dec.decode_chunk(k).unwrap();
            assert_eq!(c.data, &rec.data[c.lead_offset * 256..(c.lead_offset + 64) * 256]);
        }
        // the recorded configs come from the §III-E candidate grid; the
        // width byte's high bit flags the simd backend and the low bits
        // must still be a grid width either way
        let idx = dec.load_index().unwrap();
        for e in &idx.entries {
            assert!([8, 16, 32, 64].contains(&e.meta.block_size), "bs {}", e.meta.block_size);
            assert!([8u8, 16].contains(&e.meta.lane_width()), "width {}", e.meta.width);
            let label = e.meta.backend_label();
            assert!(["vec8", "vec16", "simd8", "simd16"].contains(&label.as_str()), "{label}");
        }
    }

    #[test]
    fn tiny_chunks_skip_the_tuner() {
        // 480-elem chunks are far below the gate: configs stay at the base
        let field = smooth_field(Dims::d2(64, 30), 113);
        let cfg = Config { eb: EbMode::Abs(1e-3), block_size: 16, ..Config::default() };
        let opts = StreamOptions {
            chunk_autotune: Some(TuneSettings::default()),
            ..StreamOptions::default()
        };
        let (bytes, _) = compress_chunked_with(&field, &cfg, 16, opts).unwrap();
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        let idx = dec.load_index().unwrap();
        assert!(idx.entries.iter().all(|e| e.meta.block_size == 16));
    }

    #[test]
    fn mixed_block_size_container_decodes_everywhere() {
        // Build a v3 container by hand with a different block size per
        // chunk — the shape a non-stationary field produces under
        // per-chunk autotuning, but deterministic (no timing involved).
        let field = smooth_field(Dims::d2(96, 32), 127);
        let eb = 1e-3;
        let span = 32usize;
        let block_sizes = [8usize, 16, 32];
        let base = Config { eb: EbMode::Abs(eb), block_size: 16, ..Config::default() };

        let header = StreamHeader {
            header: Header {
                dims: field.dims,
                codes_kind: crate::quant::CodesKind::DualQuant,
                eb,
                radius: base.radius,
                block_size: 16,
                padding: base.padding.normalized(),
            },
            chunk_span: span as u64,
            version: format::VERSION3,
        };
        let mut bytes = format::write_stream_header(&header).unwrap();
        let mut index = Vec::new();
        for (k, &bs) in block_sizes.iter().enumerate() {
            let slab = Field::new(
                format!("c{k}"),
                Dims::d2(span, 32),
                field.data[k * span * 32..(k + 1) * span * 32].to_vec(),
            );
            let cfg = Config { block_size: bs, ..base };
            let backend = cfg.backend.instantiate();
            let body = encode_body(&slab, &cfg, backend.as_ref(), 1, false).unwrap();
            let meta = ChunkMeta { block_size: bs as u32, width: 8 };
            let offset = bytes.len() as u64;
            format::write_chunk_frame(
                &mut bytes,
                k as u64,
                span as u64,
                Some(meta),
                &body.sections,
            );
            index.push(ChunkIndexEntry {
                offset,
                frame_len: bytes.len() as u64 - offset,
                lead_extent: span as u64,
                meta,
            });
        }
        format::write_trailer(&mut bytes, 3);
        format::write_index_footer(&mut bytes, &index);

        // full decode, chunk-parallel decode, and random access all agree
        // and respect the bound despite three different block geometries
        let serial = decompress_chunked(&bytes, 1).unwrap();
        let parallel = decompress_chunked(&bytes, 3).unwrap();
        assert_eq!(serial.data, parallel.data);
        assert!(max_err(&field.data, &serial.data) <= eb + 1e-6);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bytes)).unwrap();
        for k in 0..3 {
            let c = dec.decode_chunk(k).unwrap();
            assert_eq!(c.data, &serial.data[k * span * 32..(k + 1) * span * 32], "chunk {k}");
        }
        // the sequential Read-only walker handles mixed configs too
        let mut walker = StreamDecompressor::new(&bytes[..]).unwrap();
        let mut n = 0;
        while let Some(c) = walker.next_chunk().unwrap() {
            assert_eq!(c.data, &serial.data[c.lead_offset * 32..(c.lead_offset + span) * 32]);
            n += 1;
        }
        assert_eq!(n, 3);
    }

    #[test]
    fn short_reads_and_odd_lengths_on_the_io_path() {
        // a reader that dribbles 7 bytes at a time still produces the same
        // container (the fill loop assembles whole slabs)
        struct Dribble<'a>(&'a [u8]);
        impl Read for Dribble<'_> {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                let n = buf.len().min(7).min(self.0.len());
                buf[..n].copy_from_slice(&self.0[..n]);
                self.0 = &self.0[n..];
                Ok(n)
            }
        }
        let field = smooth_field(Dims::d2(48, 30), 131);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let raw = f32_as_bytes(&field.data).to_vec();
        let mut a = Vec::new();
        compress_stream(Dribble(&raw), &mut a, field.dims, &cfg, 16).unwrap();
        let mut b = Vec::new();
        compress_stream(&raw[..], &mut b, field.dims, &cfg, 16).unwrap();
        assert_eq!(a, b, "read granularity changed the container bytes");
        // an input that is not a whole number of f32s errors cleanly
        let mut out = Vec::new();
        let err = compress_stream(&raw[..raw.len() - 3], &mut out, field.dims, &cfg, 16);
        assert!(err.is_err());
    }

    #[test]
    fn stream_options_builder_matches_struct_literal() {
        let d = StreamOptions::builder().build();
        let lit = StreamOptions::default();
        assert_eq!(d.version, lit.version);
        assert!(d.chunk_autotune.is_none());
        assert_eq!(d.tune_widths, lit.tune_widths);

        let b = StreamOptions::builder()
            .version(format::VERSION2)
            .chunk_autotune(true)
            .tune_widths([4, 8])
            .build();
        assert_eq!(b.version, format::VERSION2);
        assert!(b.chunk_autotune.is_some());
        assert_eq!(b.tune_widths, [4, 8]);

        // chunk_autotune(false) clears explicit settings again
        let cleared = StreamOptions::builder()
            .chunk_autotune_with(TuneSettings::default())
            .chunk_autotune(false);
        assert!(cleared.build().chunk_autotune.is_none());

        // the struct-literal path still composes with the builder output
        let mixed = StreamOptions { version: format::VERSION3, ..b };
        assert_eq!(mixed.version, format::VERSION3);
        assert_eq!(mixed.tune_widths, [4, 8]);
    }

    #[test]
    fn deprecated_wrappers_match_dataset_reads() {
        let field = smooth_field(Dims::d2(96, 40), 77);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (container, stats) = compress_chunked(&field, &cfg, 24).unwrap();
        assert!(stats.n_chunks > 1);

        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&container)).unwrap();
        let n = dec.load_index().unwrap().n_chunks();
        assert!(n > 1);
        let ds = Dataset::open(std::io::Cursor::new(&container)).unwrap();
        assert_eq!(ds.n_chunks(), n);
        assert_eq!(ds.chunk_rows(0).unwrap().start, 0);
        assert_eq!(ds.chunk_rows(n), None);

        assert_eq!(ds.read(Region::Chunk(1)).unwrap(), dec.decode_chunk(1).unwrap().data);
        assert_eq!(
            ds.read(Region::Chunks(0..n)).unwrap(),
            dec.decode_range(0..n, 2).unwrap()
        );
        assert_eq!(ds.read(Region::Rows(7..61)).unwrap(), dec.decode_rows(7..61, 2).unwrap());
        assert_eq!(
            ds.read(Region::Dim { dim: 1, range: 3..17 }).unwrap(),
            dec.decode_cols(3..17, 2).unwrap()
        );
        assert_eq!(ds.read(Region::All).unwrap(), dec.decode_rows(0..96, 1).unwrap());

        // invalid selections fail the same way through both paths
        assert!(ds.read(Region::Chunk(n)).is_err());
        assert!(ds.read(Region::Rows(50..40)).is_err());
        assert!(ds.read(Region::Dim { dim: 2, range: 0..1 }).is_err());
        assert!(dec.decode_dim(2, 0..1, 1).is_err());
    }

    // ------------------------------------------------ v3 parity layer

    /// 96x24 field in 6 chunks of 16 rows; parity groups of 4 give one
    /// full group and one partial (2-member) group.
    fn parity_container(seed: u64) -> (Field, Vec<u8>, Vec<u8>) {
        let field = smooth_field(Dims::d2(96, 24), seed);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (plain, s0) = compress_chunked(&field, &cfg, 16).unwrap();
        let opts = StreamOptions::builder().parity(4).build();
        let (par, s1) = compress_chunked_with(&field, &cfg, 16, opts).unwrap();
        assert_eq!(s0.n_chunks, 6);
        assert_eq!(s1.n_chunks, 6);
        (field, plain, par)
    }

    #[test]
    fn parity_layer_is_strictly_additive() {
        let (field, plain, par) = parity_container(301);
        // the data frames are byte-identical: parity only appends frames
        // after them and swaps the footer tag
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&par)).unwrap();
        let idx = dec.load_index().unwrap().clone();
        let p = idx.parity.as_ref().expect("parity footer missing");
        assert_eq!(p.group_size, 4);
        assert_eq!(p.entries.len(), 2);
        let data_end = {
            let e = idx.entries.last().unwrap();
            (e.offset + e.frame_len) as usize
        };
        assert_eq!(par[..data_end], plain[..data_end], "data frames diverged");
        assert!(par.len() > plain.len());
        // a parity-less container keeps the legacy footer byte-for-byte
        let mut dec0 = StreamDecompressor::new(std::io::Cursor::new(&plain)).unwrap();
        assert!(dec0.load_index().unwrap().parity.is_none());

        // every read path decodes the parity container identically
        let a = decompress_chunked(&plain, 1).unwrap();
        let b = decompress_chunked(&par, 2).unwrap();
        assert_eq!(a.data, b.data);
        assert!(max_err(&field.data, &b.data) <= 1e-3 + 1e-6);
        let ds = Dataset::open(std::io::Cursor::new(&par)).unwrap();
        assert_eq!(ds.read(Region::All).unwrap(), b.data);
        assert_eq!(ds.cache_stats().repaired_reads, 0, "intact container repaired nothing");
        // the sequential walker skips parity frames transparently
        let mut walker = StreamDecompressor::new(&par[..]).unwrap();
        let mut n = 0;
        while walker.next_chunk().unwrap().is_some() {
            n += 1;
        }
        assert_eq!(n, 6);
        // scrub agrees the container is pristine
        let mut copy = par.clone();
        let report = scrub_container(&mut copy, false).unwrap();
        assert!(report.is_clean(), "{}", report.to_json());
        assert_eq!(report.n_chunks, 6);
        assert_eq!(report.n_parity, 2);
        assert!(report.trailer_ok);
        assert_eq!(copy, par, "report-only scrub must not write");
    }

    #[test]
    fn single_data_frame_loss_heals_through_every_path() {
        let (_, _, par) = parity_container(307);
        let reference = decompress_chunked(&par, 1).unwrap();
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&par)).unwrap();
        let entries = dec.load_index().unwrap().entries.clone();
        for (k, e) in entries.iter().enumerate() {
            // three payload positions per frame (past the tiny preamble,
            // inside the CRC-covered sections)
            for frac in [4u64, 2, 1] {
                let at = (e.offset + e.frame_len - e.frame_len / (frac + 1) - 1) as usize;
                let mut bad = par.clone();
                bad[at] ^= 0x5A;
                // scrub --repair restores the exact original bytes
                let mut healed = bad.clone();
                let report = scrub_container(&mut healed, true).unwrap();
                assert!(report.is_clean(), "chunk {k} at {at}: {}", report.to_json());
                assert_eq!(report.repaired_chunks, vec![k as u64]);
                assert_eq!(healed, par, "chunk {k} at {at}: repair not byte-identical");
                // report-only scrub sees the damage but exits dirty
                let mut looked = bad.clone();
                let dry = scrub_container(&mut looked, false).unwrap();
                assert!(!dry.is_clean());
                assert_eq!(dry.bad_chunks, vec![k as u64]);
                assert_eq!(looked, bad);
                // Dataset::read rebuilds transparently and counts it
                let ds = Dataset::open(std::io::Cursor::new(&bad)).unwrap();
                assert_eq!(
                    ds.read(Region::All).unwrap(),
                    reference.data,
                    "chunk {k} at {at}: healed read not bit-identical"
                );
                assert!(ds.cache_stats().repaired_reads > 0, "chunk {k} at {at}");
                // salvage rebuilds from parity instead of quarantining
                let mut sdec = StreamDecompressor::new(std::io::Cursor::new(&bad)).unwrap();
                let (chunks, sreport) = sdec.salvage().unwrap();
                assert!(sreport.is_complete(), "chunk {k} at {at}: salvage left holes");
                assert_eq!(chunks.len(), 6);
            }
        }
    }

    #[test]
    fn parity_frame_corruption_is_detected_and_regenerated() {
        let (_, _, par) = parity_container(311);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&par)).unwrap();
        let pentries = dec.load_index().unwrap().parity.as_ref().unwrap().entries.clone();
        for (g, pe) in pentries.iter().enumerate() {
            // sweep every byte of the parity frame: tag, geometry, length,
            // CRC and payload are all covered (geometry mismatches fail the
            // footer cross-check even where the CRC cannot see them)
            for off in 0..pe.frame_len {
                let at = (pe.offset + off) as usize;
                let mut bad = par.clone();
                bad[at] ^= 0xA5;
                let mut healed = bad.clone();
                let report = scrub_container(&mut healed, true).unwrap();
                assert!(report.is_clean(), "group {g} at {at}: {}", report.to_json());
                assert_eq!(report.repaired_parity, vec![g as u64]);
                assert!(report.bad_chunks.is_empty());
                assert_eq!(healed, par, "group {g} at {at}: repair not byte-identical");
            }
        }
        // a corrupt parity frame never disturbs plain decodes of the data
        let mut bad = par.clone();
        bad[(pentries[0].offset + 3) as usize] ^= 0xFF;
        // ... though the strict full decoder rejects the inconsistency
        assert!(decompress_chunked(&bad, 1).is_err());
        // while Dataset reads (which only consult parity on demand) succeed
        let ds = Dataset::open(std::io::Cursor::new(&bad)).unwrap();
        assert_eq!(ds.read(Region::All).unwrap().len(), 96 * 24);
    }

    #[test]
    fn two_losses_in_one_group_error_cleanly() {
        let (_, _, par) = parity_container(313);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&par)).unwrap();
        let entries = dec.load_index().unwrap().entries.clone();
        // chunks 0 and 1 share parity group 0 (group size 4)
        let mut bad = par.clone();
        for k in [0usize, 1] {
            let e = &entries[k];
            bad[(e.offset + e.frame_len / 2) as usize] ^= 0x5A;
        }
        let mut looked = bad.clone();
        let report = scrub_container(&mut looked, true).unwrap();
        assert!(!report.is_clean());
        assert_eq!(report.unrepairable_groups, vec![0]);
        assert_eq!(report.bad_chunks, vec![0, 1]);
        assert!(report.repaired_chunks.is_empty(), "must not patch a 2-loss group");
        assert_eq!(looked, bad, "2-loss group must stay untouched");
        // the read paths surface an error instead of wrong data (or a hang)
        assert!(decompress_chunked(&bad, 1).is_err());
        let ds = Dataset::open(std::io::Cursor::new(&bad)).unwrap();
        assert!(ds.read(Region::All).is_err());
        // salvage still recovers the other group's chunks
        let mut sdec = StreamDecompressor::new(std::io::Cursor::new(&bad)).unwrap();
        let (chunks, sreport) = sdec.salvage().unwrap();
        assert!(!sreport.is_complete());
        assert_eq!(chunks.len(), 4);
        // a loss in each of two DIFFERENT groups still heals completely
        let mut split = par.clone();
        for k in [1usize, 5] {
            let e = &entries[k];
            split[(e.offset + e.frame_len / 2) as usize] ^= 0x5A;
        }
        let report = scrub_container(&mut split, true).unwrap();
        assert!(report.is_clean(), "{}", report.to_json());
        assert_eq!(split, par);
    }

    #[test]
    fn scrub_rewrites_a_damaged_trailer_and_rejects_v2() {
        let (field, _, par) = parity_container(317);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&par)).unwrap();
        let idx = dec.load_index().unwrap().clone();
        let pe = *idx.parity.as_ref().unwrap().entries.last().unwrap();
        // the END trailer sits between the last parity frame and the footer
        let at = (pe.offset + pe.frame_len) as usize + 2;
        let mut bad = par.clone();
        bad[at] ^= 0x77;
        let mut healed = bad.clone();
        let report = scrub_container(&mut healed, true).unwrap();
        assert!(report.repaired_trailer);
        assert!(report.is_clean());
        assert_eq!(healed, par);
        // v2 containers carry no footer to check against
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let opts = StreamOptions { version: format::VERSION2, ..StreamOptions::default() };
        let (mut v2, _) = compress_chunked_with(&field, &cfg, 16, opts).unwrap();
        let err = scrub_container(&mut v2, false).unwrap_err();
        assert!(err.to_string().contains("v3"), "{err}");
    }

    #[test]
    fn parity_requires_v3() {
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let opts = StreamOptions {
            version: format::VERSION2,
            parity_group: 8,
            ..StreamOptions::default()
        };
        let err =
            StreamCompressor::with_options(Vec::new(), Dims::d1(512), &cfg, 0, opts).unwrap_err();
        assert!(err.to_string().contains("v3"), "{err}");
    }

    #[test]
    fn resume_with_parity_is_byte_identical() {
        let (field, _, par) = parity_container(331);
        let cfg = Config { eb: EbMode::Abs(1e-3), threads: 1, ..Config::default() };
        let opts = StreamOptions::builder().parity(4).build();
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&par)).unwrap();
        let idx = dec.load_index().unwrap().clone();
        let raw: Vec<u8> = field.data.iter().flat_map(|x| x.to_le_bytes()).collect();

        let mut cuts = vec![format::STREAM_HEADER_LEN as u64];
        for e in &idx.entries {
            cuts.push(e.offset + e.frame_len);
            cuts.push(e.offset + e.frame_len / 2);
        }
        // cuts inside the parity region: the scan must not advance
        // truncate_at past the data frames (finish rewrites the layer)
        for pe in &idx.parity.as_ref().unwrap().entries {
            cuts.push(pe.offset + pe.frame_len / 2);
            cuts.push(pe.offset + pe.frame_len);
        }
        for cut in cuts {
            let prefix = &par[..cut as usize];
            let state = scan_resumable_with(std::io::Cursor::new(prefix), 4).unwrap();
            assert!(!state.complete, "cut {cut}");
            assert_eq!(state.parity_group, 4);
            let mut out = par[..state.truncate_at as usize].to_vec();
            resume_stream_with(
                std::io::Cursor::new(&raw[..]),
                &mut out,
                field.dims,
                &cfg,
                16,
                opts,
                &state,
            )
            .unwrap();
            assert_eq!(out, par, "cut {cut}: resumed parity container differs");
        }
        // a finished parity container scans as complete
        let state = scan_resumable_with(std::io::Cursor::new(&par[..]), 4).unwrap();
        assert!(state.complete);
        // a parity-group mismatch between scan and run is rejected
        let cutoff = (idx.entries[2].offset + idx.entries[2].frame_len) as usize;
        let plain_scan =
            scan_resumable(std::io::Cursor::new(&par[..cutoff])).unwrap();
        let err = StreamCompressor::resume(
            Vec::new(),
            field.dims,
            &cfg,
            16,
            opts,
            &plain_scan,
        )
        .unwrap_err();
        assert!(err.to_string().contains("parity group"), "{err}");
    }

    #[test]
    fn salvage_report_json_escapes_control_characters() {
        let report = SalvageReport {
            total_chunks: 2,
            total_rows: 32,
            recovered: vec![0],
            holes: vec![SalvageHole {
                chunk_index: 1,
                n_chunks: 1,
                rows: 16..32,
                byte_offset: 99,
                reason: "l1\nl2\rtab\there \"q\" back\\slash \u{0}nul \u{1b}esc \u{1f}us"
                    .into(),
            }],
            rows_recovered: 16,
            footer_ok: true,
            trailer_found: true,
        };
        let json = report.to_json();
        assert!(
            json.chars().all(|c| c as u32 >= 0x20),
            "raw control characters leaked into the report: {json:?}"
        );
        let parsed = crate::util::json::parse(&json).unwrap();
        let holes = parsed.get("holes").unwrap().as_array().unwrap();
        assert_eq!(
            holes[0].get("reason").unwrap().as_str(),
            Some("l1\nl2\rtab\there \"q\" back\\slash \u{0}nul \u{1b}esc \u{1f}us"),
            "reason must round-trip through the JSON parser"
        );
    }
}

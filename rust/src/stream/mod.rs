//! Streaming chunked-container engine — compress/decompress fields larger
//! than RAM in bounded memory over `std::io::Read`/`Write`.
//!
//! The v2 container (see [`crate::format`]) frames a field as a sequence of
//! independently-decodable **chunks**: contiguous slabs along the leading
//! dimension, each a whole number of block rows, each carrying its own
//! CODES / OUTLIER_POS / OUTLIER_VAL / PAD_SCALARS sections with per-section
//! CRCs. Because row-major slabs are contiguous in memory, a chunk is
//! exactly a sub-field and reuses the whole-field encode/decode cores
//! ([`crate::compressor`]): same backends, same bitstreams, same error
//! bound per element.
//!
//! * [`StreamCompressor`] accepts samples incrementally (`push`) and emits
//!   one frame per completed slab. Memory is bounded by
//!   `chunk_elems × in-flight window`, never the whole field, and never a
//!   full-field codes buffer.
//! * With `threads > 1` the compressor pipelines **across chunks** through
//!   the [`ThreadPool`]: chunk N compresses on a worker while chunk N+1
//!   gathers on the caller's thread (cuSZ-style coarse-grained
//!   parallelism). Frames are re-ordered before writing, so the output
//!   bytes are identical for every thread count.
//! * [`StreamDecompressor`] reads frames one at a time;
//!   [`decompress_stream`]/[`decompress_chunked`] decode batches of chunks
//!   concurrently via [`ThreadPool::scatter_gather`] — byte-identical to
//!   serial decode because slabs are assembled by offset.
//!
//! Streaming requires an **absolute** error bound: a range-relative bound
//! needs the whole field before the first byte can be emitted.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

use crate::blocks::Dims;
use crate::compressor::{decode_body, default_block_size, encode_body, Config, EbMode};
use crate::coordinator::pool::ThreadPool;
use crate::data::Field;
use crate::error::{Result, VszError};
use crate::format::{self, Frame, Header, Section, StreamHeader};
use crate::quant::CodesKind;
use crate::util::crc32;
use crate::util::{bytes_to_f32, f32_as_bytes};

/// Upper bound on a single section payload accepted from a stream (guards
/// allocations against forged lengths).
const MAX_SECTION_LEN: u64 = 1 << 30;

/// Aggregate statistics of one streaming compression run.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    pub n_chunks: usize,
    pub n_elements: usize,
    pub n_outliers: usize,
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
    /// Summed P&Q stage seconds across chunks (worker wall time, not
    /// end-to-end wall time when pipelined).
    pub pq_seconds: f64,
}

impl StreamStats {
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes.max(1) as f64
    }
}

/// Pick a chunk span (leading-dim extent) targeting ~4 MiB of raw samples
/// per chunk, rounded up to a whole number of block rows.
pub fn default_chunk_span(dims: Dims, block_size: usize) -> usize {
    let bs = if block_size == 0 { default_block_size(dims.ndim) } else { block_size };
    let row_elems: usize = dims.shape[1] * dims.shape[2];
    let target_rows = ((1usize << 20) / row_elems.max(1)).max(1); // 4 MiB / 4 B
    let span = target_rows.div_ceil(bs) * bs;
    span.max(bs)
}

/// Per-chunk numbers sent back from encode workers.
struct ChunkOut {
    n_outliers: usize,
    pq_seconds: f64,
}

/// Encode one slab sub-field into a framed chunk (free function so the
/// thread-pool job owns everything it needs).
fn encode_chunk(
    index: u64,
    field: Field,
    cfg: Config,
    overlap_aux: bool,
) -> Result<(Vec<u8>, ChunkOut)> {
    let backend = cfg.backend.instantiate();
    // entropy_threads = 1: streaming parallelism is across chunks, not
    // within one. Pipelined runs (threads > 1) still overlap each chunk's
    // lossless streams with its Huffman pass on scoped helper threads;
    // serial runs (threads = 1) stay strictly single-threaded.
    let body = encode_body(&field, &cfg, backend.as_ref(), 1, overlap_aux)?;
    let mut frame = Vec::new();
    format::write_chunk_frame(&mut frame, index, field.dims.shape[0] as u64, &body.sections);
    Ok((frame, ChunkOut { n_outliers: body.n_outliers, pq_seconds: body.pq_seconds }))
}

type ChunkResult = (u64, Result<(Vec<u8>, ChunkOut)>);

/// Incremental compressor writing a v2 chunked container to `W`.
///
/// Feed samples in row-major order with [`push`](Self::push) (any slice
/// granularity), then call [`finish`](Self::finish). The compressor holds
/// at most one gathering slab plus `threads` in-flight slabs.
pub struct StreamCompressor<W: Write> {
    out: W,
    cfg: Config,
    dims: Dims,
    chunk_span: usize,
    row_elems: usize,
    total_elems: usize,
    received: usize,
    lead_done: usize,
    buf: Vec<f32>,
    chunk_index: u64,
    stats: StreamStats,
    // chunk-pipeline state (threads > 1)
    pool: Option<ThreadPool>,
    tx: Sender<ChunkResult>,
    rx: Receiver<ChunkResult>,
    window: usize,
    in_flight: usize,
    next_write: u64,
    ready: BTreeMap<u64, Vec<u8>>,
}

impl<W: Write> StreamCompressor<W> {
    /// Create a compressor and write the stream header.
    ///
    /// `chunk_span` is the leading-dim extent per chunk (rounded up to a
    /// whole number of block rows); 0 picks [`default_chunk_span`]. The
    /// error bound must be [`EbMode::Abs`].
    pub fn new(mut out: W, dims: Dims, cfg: &Config, chunk_span: usize) -> Result<Self> {
        let eb = match cfg.eb {
            EbMode::Abs(e) if e > 0.0 && e.is_finite() => e,
            EbMode::Abs(_) => return Err(VszError::config("invalid absolute error bound")),
            EbMode::Rel(_) => {
                return Err(VszError::config(
                    "streaming requires an absolute error bound (--eb), not a relative one",
                ))
            }
        };
        if dims.is_empty() {
            return Err(VszError::config("empty field"));
        }
        let bs = if cfg.block_size == 0 { default_block_size(dims.ndim) } else { cfg.block_size };
        let mut cfg = *cfg;
        cfg.block_size = bs;
        let span = if chunk_span == 0 { default_chunk_span(dims, bs) } else { chunk_span };
        let span = span.div_ceil(bs) * bs;
        let codes_kind = match cfg.backend {
            crate::compressor::BackendChoice::Sz14 => CodesKind::Sz14,
            _ => CodesKind::DualQuant,
        };
        let header = StreamHeader {
            header: Header {
                dims,
                codes_kind,
                eb,
                radius: cfg.radius,
                block_size: bs as u32,
                padding: cfg.padding.normalized(),
            },
            chunk_span: span as u64,
        };
        let hdr = format::write_stream_header(&header);
        out.write_all(&hdr)?;

        let threads = cfg.threads.max(1);
        let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
        let (tx, rx) = channel();
        Ok(Self {
            out,
            cfg,
            dims,
            chunk_span: span,
            row_elems: dims.shape[1] * dims.shape[2],
            total_elems: dims.len(),
            received: 0,
            lead_done: 0,
            buf: Vec::new(),
            chunk_index: 0,
            stats: StreamStats {
                raw_bytes: dims.len() * 4,
                n_elements: dims.len(),
                compressed_bytes: hdr.len(),
                ..StreamStats::default()
            },
            pool,
            tx,
            rx,
            window: threads,
            in_flight: 0,
            next_write: 0,
            ready: BTreeMap::new(),
        })
    }

    fn next_chunk_extent(&self) -> usize {
        (self.dims.shape[0] - self.lead_done).min(self.chunk_span)
    }

    fn chunk_dims(&self, extent: usize) -> Dims {
        let mut shape = self.dims.shape;
        shape[0] = extent;
        Dims { shape, ndim: self.dims.ndim }
    }

    /// Write every frame that is next in line.
    fn write_ready(&mut self) -> Result<()> {
        while let Some(frame) = self.ready.remove(&self.next_write) {
            self.out.write_all(&frame)?;
            self.stats.compressed_bytes += frame.len();
            self.next_write += 1;
        }
        Ok(())
    }

    /// Receive one worker result; `blocking` waits (with a generous
    /// timeout so a crashed worker cannot deadlock the writer — the
    /// compressor keeps a master `Sender`, so the channel never reports
    /// disconnection on its own), otherwise returns Ok(false) when nothing
    /// is pending.
    fn recv_one(&mut self, blocking: bool) -> Result<bool> {
        let msg = if blocking {
            self.rx
                .recv_timeout(std::time::Duration::from_secs(300))
                .map_err(|_| VszError::runtime("stream worker stalled or died"))?
        } else {
            match self.rx.try_recv() {
                Ok(m) => m,
                Err(TryRecvError::Empty) => return Ok(false),
                Err(TryRecvError::Disconnected) => {
                    return Err(VszError::runtime("stream worker disconnected"))
                }
            }
        };
        self.in_flight -= 1;
        let (index, res) = msg;
        let (frame, info) = res?;
        self.stats.n_outliers += info.n_outliers;
        self.stats.pq_seconds += info.pq_seconds;
        self.ready.insert(index, frame);
        Ok(true)
    }

    fn emit_chunk(&mut self, data: Vec<f32>, extent: usize) -> Result<()> {
        let index = self.chunk_index;
        self.chunk_index += 1;
        self.stats.n_chunks += 1;
        let field = Field::new(format!("chunk{index}"), self.chunk_dims(extent), data);
        if self.pool.is_some() {
            // pipelined: bound in-flight chunks, then hand off to a worker
            while self.in_flight >= self.window {
                self.recv_one(true)?;
                self.write_ready()?;
            }
            let mut job_cfg = self.cfg;
            job_cfg.threads = 1; // parallelism is across chunks here
            let tx = self.tx.clone();
            self.pool.as_ref().unwrap().submit(move || {
                let res = encode_chunk(index, field, job_cfg, true);
                let _ = tx.send((index, res));
            });
            self.in_flight += 1;
            // opportunistically drain finished workers
            while self.recv_one(false)? {}
            self.write_ready()?;
        } else {
            let (frame, info) = encode_chunk(index, field, self.cfg, false)?;
            self.stats.n_outliers += info.n_outliers;
            self.stats.pq_seconds += info.pq_seconds;
            self.out.write_all(&frame)?;
            self.stats.compressed_bytes += frame.len();
            self.next_write += 1;
        }
        Ok(())
    }

    /// Feed the next samples (row-major order, any slice size).
    pub fn push(&mut self, mut samples: &[f32]) -> Result<()> {
        if self.received + samples.len() > self.total_elems {
            return Err(VszError::config(format!(
                "more samples than dims describe ({} > {})",
                self.received + samples.len(),
                self.total_elems
            )));
        }
        self.received += samples.len();
        while !samples.is_empty() {
            let extent = self.next_chunk_extent();
            let chunk_elems = extent * self.row_elems;
            let need = chunk_elems - self.buf.len();
            let take = need.min(samples.len());
            if self.buf.is_empty() && take == chunk_elems {
                // whole chunk available in the caller's slice: skip the copy
                self.emit_chunk(samples[..take].to_vec(), extent)?;
                self.lead_done += extent;
            } else {
                self.buf.extend_from_slice(&samples[..take]);
                if self.buf.len() == chunk_elems {
                    let data = std::mem::take(&mut self.buf);
                    self.emit_chunk(data, extent)?;
                    self.lead_done += extent;
                }
            }
            samples = &samples[take..];
        }
        Ok(())
    }

    /// Drain in-flight chunks, write the trailer and return the writer plus
    /// run statistics. Errors if fewer samples than `dims` describe were
    /// pushed.
    pub fn finish(mut self) -> Result<(W, StreamStats)> {
        if self.received != self.total_elems {
            return Err(VszError::config(format!(
                "incomplete field: got {} of {} samples",
                self.received, self.total_elems
            )));
        }
        while self.in_flight > 0 {
            self.recv_one(true)?;
            self.write_ready()?;
        }
        self.write_ready()?;
        debug_assert!(self.ready.is_empty());
        debug_assert_eq!(self.next_write, self.chunk_index);
        let mut trailer = Vec::new();
        format::write_trailer(&mut trailer, self.chunk_index);
        self.out.write_all(&trailer)?;
        self.stats.compressed_bytes += trailer.len();
        self.out.flush()?;
        Ok((self.out, self.stats))
    }
}

/// Compress a raw little-endian f32 stream (e.g. an `.f32` file) to a v2
/// chunked container in bounded memory.
pub fn compress_stream<R: Read, W: Write>(
    mut input: R,
    out: W,
    dims: Dims,
    cfg: &Config,
    chunk_span: usize,
) -> Result<StreamStats> {
    let mut sc = StreamCompressor::new(out, dims, cfg, chunk_span)?;
    let mut buf = vec![0u8; 1 << 20];
    let mut carry = [0u8; 4];
    let mut carry_len = 0usize;
    loop {
        let n = input.read(&mut buf)?;
        if n == 0 {
            break;
        }
        let mut bytes = &buf[..n];
        if carry_len > 0 {
            let need = 4 - carry_len;
            let take = need.min(bytes.len());
            carry[carry_len..carry_len + take].copy_from_slice(&bytes[..take]);
            carry_len += take;
            bytes = &bytes[take..];
            if carry_len == 4 {
                sc.push(&[f32::from_le_bytes(carry)])?;
                carry_len = 0;
            }
        }
        let whole = bytes.len() / 4 * 4;
        if whole > 0 {
            sc.push(&bytes_to_f32(&bytes[..whole]))?;
        }
        let rem = &bytes[whole..];
        if !rem.is_empty() {
            // `bytes` is only non-empty here when the carry was flushed (a
            // partial top-up exhausts the read), so this never clobbers a
            // pending carry
            carry[..rem.len()].copy_from_slice(rem);
            carry_len = rem.len();
        }
    }
    if carry_len != 0 {
        return Err(VszError::format("input length is not a multiple of 4 bytes"));
    }
    let (_, stats) = sc.finish()?;
    Ok(stats)
}

/// Compress an in-memory field to a v2 chunked container.
pub fn compress_chunked(
    field: &Field,
    cfg: &Config,
    chunk_span: usize,
) -> Result<(Vec<u8>, StreamStats)> {
    let mut sc = StreamCompressor::new(Vec::new(), field.dims, cfg, chunk_span)?;
    sc.push(&field.data)?;
    sc.finish()
}

// ------------------------------------------------------------------ decode

fn read_u8_io<R: Read>(r: &mut R) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u32_io<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_uvarint_io<R: Read>(r: &mut R) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        if shift >= 64 {
            return Err(VszError::format("varint overflow"));
        }
        let b = read_u8_io(r)?;
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn read_section_io<R: Read>(r: &mut R) -> Result<Section> {
    let tag = read_u8_io(r)?;
    let raw_len = read_uvarint_io(r)?;
    let enc_len = read_uvarint_io(r)?;
    if enc_len > MAX_SECTION_LEN {
        return Err(VszError::format(format!("section {tag}: implausible length {enc_len}")));
    }
    let crc = read_u32_io(r)?;
    let mut payload = vec![0u8; enc_len as usize];
    r.read_exact(&mut payload)?;
    if crc32(&payload) != crc {
        return Err(VszError::Integrity(format!("section {tag}: crc mismatch")));
    }
    Ok(Section { tag, raw_len, payload })
}

fn read_frame_io<R: Read>(r: &mut R) -> Result<Frame> {
    let marker = read_u8_io(r)?;
    match marker {
        format::CHUNK_TAG => {
            let index = read_uvarint_io(r)?;
            let lead_extent = read_uvarint_io(r)?;
            if lead_extent == 0 {
                return Err(VszError::format("empty chunk"));
            }
            let n_sections = read_u8_io(r)? as usize;
            let mut sections = Vec::with_capacity(n_sections);
            for _ in 0..n_sections {
                sections.push(read_section_io(r)?);
            }
            Ok(Frame::Chunk { index, lead_extent, sections })
        }
        format::END_TAG => {
            let n_chunks = read_uvarint_io(r)?;
            let crc = read_u32_io(r)?;
            if crc32(&n_chunks.to_le_bytes()) != crc {
                return Err(VszError::Integrity("trailer crc mismatch".into()));
            }
            Ok(Frame::End { n_chunks })
        }
        other => Err(VszError::format(format!("unknown frame marker {other:#x}"))),
    }
}

/// One decoded slab handed out by [`StreamDecompressor::next_chunk`].
pub struct DecodedChunk {
    pub index: u64,
    /// Leading-dim offset of this slab within the full field.
    pub lead_offset: usize,
    /// Leading-dim extent of this slab.
    pub lead_extent: usize,
    pub data: Vec<f32>,
}

/// Incremental decoder for v2 chunked containers over any `Read`.
pub struct StreamDecompressor<R: Read> {
    input: R,
    header: StreamHeader,
    next_index: u64,
    lead_done: usize,
    finished: bool,
}

impl<R: Read> StreamDecompressor<R> {
    pub fn new(mut input: R) -> Result<Self> {
        let mut hdr = [0u8; format::STREAM_HEADER_LEN];
        input.read_exact(&mut hdr)?;
        let header = format::read_stream_header(&hdr)?;
        Ok(Self { input, header, next_index: 0, lead_done: 0, finished: false })
    }

    pub fn header(&self) -> &StreamHeader {
        &self.header
    }

    fn chunk_header(&self, extent: usize) -> Header {
        let mut h = self.header.header;
        h.dims.shape[0] = extent;
        h
    }

    /// Validate one frame's geometry against the running position.
    fn check_chunk(&self, index: u64, extent: u64) -> Result<usize> {
        if index != self.next_index {
            return Err(VszError::format(format!(
                "chunk out of order: got {index}, expected {}",
                self.next_index
            )));
        }
        let remaining = self.header.header.dims.shape[0] - self.lead_done;
        let extent = extent as usize;
        if extent > remaining || (extent != self.header.chunk_span as usize && extent != remaining)
        {
            return Err(VszError::format(format!("bad chunk extent {extent}")));
        }
        Ok(extent)
    }

    /// Read and validate the next frame without decoding it, advancing the
    /// running position. Returns `None` once the trailer has been consumed
    /// and verified. Shared by [`Self::next_chunk`] and
    /// [`decompress_stream`] so the trailer checks live in one place.
    fn next_frame(&mut self) -> Result<Option<(usize, Vec<Section>)>> {
        if self.finished {
            return Ok(None);
        }
        match read_frame_io(&mut self.input)? {
            Frame::Chunk { index, lead_extent, sections } => {
                let extent = self.check_chunk(index, lead_extent)?;
                self.lead_done += extent;
                self.next_index += 1;
                Ok(Some((extent, sections)))
            }
            Frame::End { n_chunks } => {
                if n_chunks != self.next_index {
                    return Err(VszError::format(format!(
                        "trailer says {n_chunks} chunks, read {}",
                        self.next_index
                    )));
                }
                if self.lead_done != self.header.header.dims.shape[0] {
                    return Err(VszError::format("stream ended before the field was complete"));
                }
                self.finished = true;
                Ok(None)
            }
        }
    }

    /// Decode the next chunk, or `None` after the trailer.
    pub fn next_chunk(&mut self) -> Result<Option<DecodedChunk>> {
        match self.next_frame()? {
            None => Ok(None),
            Some((extent, sections)) => {
                let h = self.chunk_header(extent);
                let data = decode_body(&h, &sections, 1)?;
                Ok(Some(DecodedChunk {
                    index: self.next_index - 1,
                    lead_offset: self.lead_done - extent,
                    lead_extent: extent,
                    data,
                }))
            }
        }
    }
}

/// Decode a batch of owned chunk frames, in parallel when `pool` is given.
fn decode_batch(
    header: &StreamHeader,
    batch: Vec<(usize, Vec<Section>)>,
    pool: Option<&ThreadPool>,
) -> Result<Vec<Vec<f32>>> {
    let base = header.header;
    let decode_one = move |extent: usize, sections: &[Section]| -> Result<Vec<f32>> {
        let mut h = base;
        h.dims.shape[0] = extent;
        decode_body(&h, sections, 1)
    };
    match pool {
        Some(pool) if batch.len() > 1 => {
            let shared = Arc::new(batch);
            let shared2 = Arc::clone(&shared);
            let results = pool.scatter_gather(shared.len(), move |i| {
                let (extent, sections) = &shared2[i];
                decode_one(*extent, sections)
            });
            results.into_iter().collect()
        }
        _ => batch
            .iter()
            .map(|(extent, sections)| decode_one(*extent, sections))
            .collect(),
    }
}

/// Decompress a v2 chunked container from `input`, writing raw little-endian
/// f32 bytes to `out` in field order. Chunks are decoded `threads` at a time
/// via the pool; memory stays bounded by the batch, never the whole field.
/// Returns the stream header.
pub fn decompress_stream<R: Read, W: Write>(
    input: R,
    mut out: W,
    threads: usize,
) -> Result<StreamHeader> {
    let mut dec = StreamDecompressor::new(input)?;
    let header = *dec.header();
    let threads = threads.max(1);
    let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
    loop {
        // gather up to `threads` frames, then decode them concurrently
        let mut batch: Vec<(usize, Vec<Section>)> = Vec::with_capacity(threads);
        while batch.len() < threads {
            match dec.next_frame()? {
                Some(frame) => batch.push(frame),
                None => break,
            }
        }
        if batch.is_empty() {
            break;
        }
        for data in decode_batch(&header, batch, pool.as_ref())? {
            out.write_all(f32_as_bytes(&data))?;
        }
    }
    out.flush()?;
    Ok(header)
}

/// Decompress an in-memory v2 chunked container, decoding chunks
/// concurrently (`threads`) — byte-identical to serial decode because
/// slabs are assembled by offset.
pub fn decompress_chunked(bytes: &[u8], threads: usize) -> Result<Field> {
    if bytes.len() < format::STREAM_HEADER_LEN {
        return Err(VszError::format("truncated stream header"));
    }
    let header = format::read_stream_header(&bytes[..format::STREAM_HEADER_LEN])?;
    let dims = header.header.dims;
    let span = header.chunk_span as usize;

    // index all frames up front (cheap: payloads are borrowed then owned
    // per section; the heavy work is the decode below)
    let mut c = crate::bitio::Cursor::new(&bytes[format::STREAM_HEADER_LEN..]);
    let mut chunks: Vec<(usize, Vec<Section>)> = Vec::new();
    let mut lead_done = 0usize;
    loop {
        match format::read_frame(&mut c)? {
            Frame::Chunk { index, lead_extent, sections } => {
                if index as usize != chunks.len() {
                    return Err(VszError::format(format!(
                        "chunk out of order: got {index}, expected {}",
                        chunks.len()
                    )));
                }
                let remaining = dims.shape[0] - lead_done;
                let extent = lead_extent as usize;
                if extent > remaining || (extent != span && extent != remaining) {
                    return Err(VszError::format(format!("bad chunk extent {extent}")));
                }
                lead_done += extent;
                chunks.push((extent, sections));
            }
            Frame::End { n_chunks } => {
                if n_chunks as usize != chunks.len() {
                    return Err(VszError::format(format!(
                        "trailer says {n_chunks} chunks, read {}",
                        chunks.len()
                    )));
                }
                break;
            }
        }
    }
    if c.remaining() != 0 {
        return Err(VszError::format("trailing garbage after stream trailer"));
    }
    if lead_done != dims.shape[0] {
        return Err(VszError::format("stream ended before the field was complete"));
    }

    let threads = threads.max(1);
    let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
    let slabs = decode_batch(&header, chunks, pool.as_ref())?;
    let row_elems = dims.shape[1] * dims.shape[2];
    let mut data = Vec::with_capacity(dims.len());
    for slab in &slabs {
        data.extend_from_slice(slab);
    }
    debug_assert_eq!(data.len(), dims.shape[0] * row_elems);
    Ok(Field::new("decompressed", dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{compress, decompress, BackendChoice, Config};
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};
    use crate::util::prng::Pcg32;

    fn smooth_field(dims: Dims, seed: u64) -> Field {
        let mut rng = Pcg32::seeded(seed);
        let mut x = 1.0f32;
        let data: Vec<f32> = (0..dims.len())
            .map(|_| {
                x += (rng.next_f32() - 0.5) * 0.1;
                x
            })
            .collect();
        Field::new("t", dims, data)
    }

    fn max_err(a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| (*x as f64 - *y as f64).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn chunked_roundtrip_all_dims_within_bound() {
        for dims in [Dims::d1(3000), Dims::d2(70, 40), Dims::d3(40, 12, 10)] {
            let field = smooth_field(dims, 41);
            let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
            let bs = default_block_size(dims.ndim);
            let (bytes, stats) = compress_chunked(&field, &cfg, bs).unwrap();
            assert!(stats.n_chunks >= 4, "want >=4 chunks, got {} for {dims:?}", stats.n_chunks);
            let rec = decompress_chunked(&bytes, 1).unwrap();
            assert_eq!(rec.dims, dims);
            assert!(max_err(&field.data, &rec.data) <= 1e-3 + 1e-6);
        }
    }

    #[test]
    fn chunk_parallel_decode_is_byte_identical_to_serial() {
        let field = smooth_field(Dims::d2(96, 50), 43);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert!(stats.n_chunks >= 4);
        let serial = decompress_chunked(&bytes, 1).unwrap();
        let parallel = decompress_chunked(&bytes, 4).unwrap();
        assert_eq!(serial.data, parallel.data, "thread count changed decode output");
        assert!(max_err(&field.data, &serial.data) <= 1e-3 + 1e-6);
    }

    #[test]
    fn pipelined_compress_bytes_match_serial() {
        let field = smooth_field(Dims::d2(80, 64), 47);
        let c1 = Config { eb: EbMode::Abs(1e-3), threads: 1, ..Config::default() };
        let c4 = Config { eb: EbMode::Abs(1e-3), threads: 4, ..Config::default() };
        let (b1, s1) = compress_chunked(&field, &c1, 16).unwrap();
        let (b4, s4) = compress_chunked(&field, &c4, 16).unwrap();
        assert_eq!(s1.n_chunks, s4.n_chunks);
        assert_eq!(b1, b4, "chunk pipelining must not change the bitstream");
    }

    #[test]
    fn push_granularity_does_not_change_bytes() {
        // stream the field one awkwardly-sized slice at a time
        let field = smooth_field(Dims::d2(48, 30), 53);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (whole, _) = compress_chunked(&field, &cfg, 16).unwrap();

        let mut sc = StreamCompressor::new(Vec::new(), field.dims, &cfg, 16).unwrap();
        let mut at = 0usize;
        let mut step = 7usize;
        while at < field.data.len() {
            let take = step.min(field.data.len() - at);
            sc.push(&field.data[at..at + take]).unwrap();
            at += take;
            step = step * 2 + 1;
        }
        let (drip, _) = sc.finish().unwrap();
        assert_eq!(whole, drip);
    }

    #[test]
    fn io_streaming_roundtrip() {
        // full Read -> compress -> Read -> decompress -> bytes pipeline
        let field = smooth_field(Dims::d2(64, 32), 59);
        let cfg = Config { eb: EbMode::Abs(1e-3), threads: 2, ..Config::default() };
        let raw: Vec<u8> = f32_as_bytes(&field.data).to_vec();
        let mut container = Vec::new();
        let stats =
            compress_stream(&raw[..], &mut container, field.dims, &cfg, 16).unwrap();
        assert!(stats.n_chunks >= 4);
        assert_eq!(stats.n_elements, field.data.len());

        let mut out = Vec::new();
        let header = decompress_stream(&container[..], &mut out, 3).unwrap();
        assert_eq!(header.header.dims, field.dims);
        let rec = bytes_to_f32(&out);
        assert!(max_err(&field.data, &rec) <= 1e-3 + 1e-6);
    }

    #[test]
    fn incremental_decoder_walks_chunks_in_order() {
        let field = smooth_field(Dims::d2(80, 16), 61);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        let mut dec = StreamDecompressor::new(&bytes[..]).unwrap();
        let mut n = 0usize;
        let mut offset = 0usize;
        while let Some(chunk) = dec.next_chunk().unwrap() {
            assert_eq!(chunk.index as usize, n);
            assert_eq!(chunk.lead_offset, offset);
            offset += chunk.lead_extent;
            n += 1;
        }
        assert_eq!(n, stats.n_chunks);
        assert_eq!(offset, 80);
        // after the trailer the decoder keeps returning None
        assert!(dec.next_chunk().unwrap().is_none());
    }

    #[test]
    fn generic_decompress_dispatches_on_magic() {
        let field = smooth_field(Dims::d2(48, 20), 67);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (v2, _) = compress_chunked(&field, &cfg, 16).unwrap();
        let rec = decompress(&v2, 2).unwrap(); // compressor::decompress
        assert!(max_err(&field.data, &rec.data) <= 1e-3 + 1e-6);
        // and v1 still works through the same entry point
        let (v1, _) = compress(&field, &cfg).unwrap();
        let rec1 = decompress(&v1, 2).unwrap();
        assert_eq!(rec1.dims, field.dims);
    }

    #[test]
    fn rel_eb_rejected_for_streaming() {
        let cfg = Config { eb: EbMode::Rel(1e-3), ..Config::default() };
        let err = StreamCompressor::new(Vec::new(), Dims::d1(100), &cfg, 0).unwrap_err();
        assert!(err.to_string().contains("absolute"), "{err}");
    }

    #[test]
    fn wrong_sample_counts_are_rejected() {
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        // too many
        let mut sc = StreamCompressor::new(Vec::new(), Dims::d1(256), &cfg, 0).unwrap();
        assert!(sc.push(&vec![0.0f32; 300]).is_err());
        // too few
        let mut sc = StreamCompressor::new(Vec::new(), Dims::d1(512), &cfg, 256).unwrap();
        sc.push(&vec![0.0f32; 100]).unwrap();
        assert!(sc.finish().is_err());
    }

    #[test]
    fn sz14_backend_streams_too() {
        let field = smooth_field(Dims::d2(64, 24), 71);
        let cfg = Config {
            eb: EbMode::Abs(1e-3),
            backend: BackendChoice::Sz14,
            ..Config::default()
        };
        let (bytes, stats) = compress_chunked(&field, &cfg, 16).unwrap();
        assert!(stats.n_chunks >= 4);
        let rec = decompress_chunked(&bytes, 2).unwrap();
        assert!(max_err(&field.data, &rec.data) <= 1e-3 + 1e-6);
    }

    #[test]
    fn padding_policies_stream_roundtrip() {
        let field = smooth_field(Dims::d2(64, 24), 73);
        for (value, gran) in [
            (PadValue::Avg, PadGranularity::Global),
            (PadValue::Avg, PadGranularity::Block),
            (PadValue::Min, PadGranularity::Edge),
        ] {
            let cfg = Config {
                eb: EbMode::Abs(1e-3),
                padding: PaddingPolicy::new(value, gran),
                ..Config::default()
            };
            let (bytes, _) = compress_chunked(&field, &cfg, 16).unwrap();
            let rec = decompress_chunked(&bytes, 2).unwrap();
            assert!(
                max_err(&field.data, &rec.data) <= 1e-3 + 1e-6,
                "padding {value:?}/{gran:?}"
            );
        }
    }

    #[test]
    fn chunked_corruption_and_truncation_rejected() {
        let field = smooth_field(Dims::d2(64, 24), 79);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, _) = compress_chunked(&field, &cfg, 16).unwrap();
        assert!(decompress_chunked(&bytes, 1).is_ok());
        // flip a byte every 97 positions across the whole container
        for at in (4..bytes.len()).step_by(97) {
            let mut bad = bytes.clone();
            bad[at] ^= 0x5A;
            match decompress_chunked(&bad, 1) {
                Err(_) => {}
                Ok(rec) => assert_eq!(
                    rec.data.len(),
                    field.data.len(),
                    "flip at {at} silently changed the field shape"
                ),
            }
        }
        // truncations: header, mid-chunk, before trailer, inside trailer
        for cut in [0, 10, format::STREAM_HEADER_LEN, bytes.len() / 2, bytes.len() - 1] {
            assert!(decompress_chunked(&bytes[..cut], 1).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn default_chunk_span_is_block_aligned() {
        for dims in [Dims::d1(1 << 22), Dims::d2(4000, 500), Dims::d3(300, 100, 100)] {
            let bs = default_block_size(dims.ndim);
            let span = default_chunk_span(dims, 0);
            assert_eq!(span % bs, 0);
            assert!(span >= bs);
        }
    }
}

//! vecSZ command-line launcher.
//!
//! Subcommands:
//!   compress    raw f32 file or synthetic suite -> .vsz container(s)
//!   decompress  .vsz -> raw f32 file (v1 and chunked v2 containers)
//!   stream      chunked streaming compress/decompress in bounded memory
//!   batch       push a whole dataset suite through the thread pool
//!   verify      compress + decompress + check the error bound
//!   bench       P&Q bandwidth of one configuration
//!   autotune    pick best (block size x lane width) for an input
//!   roofline    machine ceilings + dual-quant OI model
//!   figure      regenerate a paper table/figure (see `figure list`)
//!   gen-data    write a synthetic suite to raw f32 files
//!   serve       long-running framed-TCP compression service
//!   pipeline    streaming time-series compression demo
//!   info        artifact manifest + host summary

use std::io::{BufReader, BufWriter};
use std::path::Path;
use std::process::ExitCode;

use vecsz::autotune::{autotune, TuneSettings};
use vecsz::bench::{bench, BenchOpts};
use vecsz::cli::Args;
use vecsz::compressor::{
    compress, decompress, pq_stage, verify_roundtrip, BackendChoice, Config, EbMode,
};
use vecsz::data::{io as dio, suite, Field, Scale};
use vecsz::error::{Result, VszError};
use vecsz::padding::PaddingPolicy;
use vecsz::roofline;
use vecsz::util::human_bytes;

const USAGE: &str = "vecsz — SIMD lossy compression for scientific data (paper reproduction)

USAGE: vecsz <command> [flags]

COMMANDS
  compress   --input F --dims NxM [--out F.vsz] | --suite NAME [--out-dir D]
             flags: --eb 1e-4 | --rel-eb 1e-4, --block N, --backend
             sz14|psz|vec4|vec8|vec16|simd4|simd8|simd16, --padding
             zero|avg-global|..., --threads N, --isa scalar|neon|avx2|avx512
             (--isa pins the simd backend's runtime ISA dispatch; also
             settable via the VECSZ_FORCE_ISA environment variable)
  decompress --input F.vsz --out F.f32 [--threads N] [--isa ...]
             (accepts every container version: monolithic v1, chunked
             v2 and indexed v3; --isa/VECSZ_FORCE_ISA govern the SIMD
             reverse-Lorenzo decode kernel too)
  stream     compress   --input F.f32 --dims NxM --out F.vsz
                        [--chunk-rows N] [--threads N] [--resume]
                        [--parity G]
                        [--tune-chunks [--sample-pct P] [--iterations N]]
                        + compress flags
                        (absolute --eb required; bounded memory; chunk
                        pipeline across --threads workers; --tune-chunks
                        re-runs the block/lane autotuner per chunk;
                        --resume scans a partial --out for its last
                        CRC-valid chunk, truncates after it and continues
                        — the finished container is byte-identical to an
                        uninterrupted run; --parity G emits one XOR
                        parity frame per G chunk frames (0 = off), so any
                        single lost/corrupt frame per group is
                        reconstructable by scrub/repair and the read
                        paths)
             decompress --input F.vsz --out F.f32 [--threads N]
                        (chunk-parallel decode via the thread pool)
             inspect    --input F.vsz
                        (print the header and the per-chunk index of a
                        VSZ3 container: offsets, sizes, rows, config —
                        plus each chunk's entropy framing: legacy/huf2/
                        huf3, local-table count and gap-array segments)
             extract    --input F.vsz --out F.f32 [--threads N]
                        (--chunk K | --rows LO:HI | --cols LO:HI |
                         --planes LO:HI)
                        (random access through a Dataset handle: one chunk
                        or a row range read only the footer + the frames
                        they cover; --cols slices the last axis and
                        --planes the middle axis of a 3D field — every
                        chunk overlaps those, so all chunks decode
                        chunk-parallel and the extent is gathered)
             salvage    --input F.vsz [--out F.f32]
                        (best-effort recovery of a damaged container:
                        walks the file front to back, reconstructs every
                        CRC-valid chunk, quarantines the rest and prints a
                        JSON hole report; --out writes the recovered field
                        with holes zero-filled. Needs an intact stream
                        header. On parity-protected containers a chunk
                        whose frame fails its CRC is rebuilt from parity
                        instead of quarantined)
             scrub      --input F.vsz [--repair]
                        (walk every chunk and parity frame of an indexed
                        container, CRC-check each one and print a JSON
                        integrity report; exits nonzero when damage is
                        found. --repair additionally rebuilds any single
                        lost frame per parity group from the XOR of the
                        survivors and rewrites the container via temp
                        file + atomic rename)
             repair     --input F.vsz
                        (shorthand for scrub --repair: heal every
                        single-loss parity group in place; exits nonzero
                        when a group lost >= 2 frames)
  batch      --suite NAME|all [--out-dir D] [--threads N]
             [--stream [--chunk-rows N]] + compress flags
             (whole dataset suite through the pool, one field per worker)
  verify     same flags as compress; checks the error bound end to end
  bench      --suite NAME [--backend ...] [--block N] [--threads N]
  autotune   --suite NAME [--sample-pct P] [--iterations N]
  roofline   [--quick]
  figure     <table1|table2|fig1|fig3|fig4|fig5|fig6_7|fig8|fig9|fig10|
              padding|table3|stability|all> [--out-dir results] [--quick]
  gen-data   --suite NAME --out-dir D [--full]
  serve      [--addr HOST:PORT] [--threads N] [--max-inflight-mb MB]
             [--max-conns N] [--chunk-rows N] [--request-timeout-ms MS]
             [--cache-mb MB] | --status [--addr HOST:PORT]
             (long-running framed-TCP compression service: compress /
             decompress / extract / stats requests over one shared chunk
             pool; requests past the in-flight byte cap are rejected with
             a busy frame; --request-timeout-ms sets a per-request
             deadline — an expired or disconnected request cancels its
             queued chunk jobs and replies busy, so callers can retry;
             --cache-mb bounds the server-wide decoded-chunk cache —
             repeated extract/decompress of the same container hit warm
             slabs instead of re-decoding (0 disables); --status queries
             a running server's lifetime CompressionStats plus the cache
             hit/miss/eviction/resident gauges)
  pipeline   --suite NAME --steps N [--out-dir D]
             [--stream [--chunk-rows N] [--tune-chunks]] [--verify-steps]
             (--stream writes each step as an indexed VSZ3 container;
             --tune-chunks tunes per chunk instead of per step;
             --verify-steps decodes each step back through the decode
             engine and checks the bound before the sink sees it)
  info       [--artifacts DIR]
";

fn parse_common(a: &Args) -> Result<Config> {
    let mut cfg = Config::default();
    if let Some(e) = a.get("eb") {
        cfg.eb = EbMode::Abs(e.parse().map_err(|_| VszError::config("bad --eb"))?);
    }
    if let Some(e) = a.get("rel-eb") {
        cfg.eb = EbMode::Rel(e.parse().map_err(|_| VszError::config("bad --rel-eb"))?);
    }
    cfg.block_size = a.usize_or("block", 0)?;
    cfg.radius = a.usize_or("radius", 512)? as u16;
    cfg.threads = a.usize_or("threads", 1)?;
    let be = a.str_or("backend", "vec16");
    cfg.backend =
        BackendChoice::parse(be).ok_or_else(|| VszError::config(format!("bad --backend {be}")))?;
    let pad = a.str_or("padding", "zero");
    cfg.padding = PaddingPolicy::parse(pad)
        .ok_or_else(|| VszError::config(format!("bad --padding {pad}")))?;
    apply_isa_flag(a)?;
    Ok(cfg)
}

/// Honour `--isa`: pins the runtime dispatch of BOTH simd kernels — the
/// fused forward pass and the reverse-Lorenzo decode wavefront (same
/// effect as VECSZ_FORCE_ISA; unavailable ISAs are clamped).
fn apply_isa_flag(a: &Args) -> Result<()> {
    if let Some(s) = a.get("isa") {
        let isa = vecsz::simd::Isa::parse(s)
            .ok_or_else(|| VszError::config(format!("bad --isa {s} (scalar|neon|avx2|avx512)")))?;
        let active = vecsz::simd::force_isa(Some(isa));
        if active != isa {
            eprintln!("--isa {s}: not available on this host; dispatching to {}", active.name());
        }
    }
    Ok(())
}

fn load_inputs(a: &Args) -> Result<Vec<Field>> {
    if let Some(name) = a.get("suite") {
        let scale = if a.has("full") { Scale::Full } else { Scale::Small };
        let ds = suite(name, scale, a.usize_or("seed", 0xDA7A)? as u64)
            .ok_or_else(|| VszError::config(format!("unknown suite '{name}'")))?;
        Ok(ds.fields)
    } else if let Some(path) = a.get("input") {
        let dims = dio::parse_dims(
            a.get("dims").ok_or_else(|| VszError::config("--dims required with --input"))?,
        )?;
        let name = Path::new(path)
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_else(|| "field".into());
        Ok(vec![dio::read_f32_file(Path::new(path), dims, &name)?])
    } else {
        Err(VszError::config("need --suite NAME or --input FILE --dims NxM"))
    }
}

fn cmd_compress(a: &Args) -> Result<()> {
    let cfg = parse_common(a)?;
    let fields = load_inputs(a)?;
    let out_dir = a.str_or("out-dir", ".");
    let single_out = a.get("out").map(|s| s.to_string());
    for f in &fields {
        let (bytes, stats) = compress(f, &cfg)?;
        let path = match (&single_out, fields.len()) {
            (Some(p), 1) => p.clone(),
            _ => format!("{out_dir}/{}.vsz", f.name),
        };
        std::fs::create_dir_all(Path::new(&path).parent().unwrap_or(Path::new(".")))?;
        std::fs::write(&path, &bytes)?;
        println!(
            "{:<16} {:>10} -> {:>10}  CR {:>6.2}x  rate {:>5.2} b/val  P&Q {:>8.0} MB/s  outliers {:>6.3}%  -> {path}",
            f.name,
            human_bytes(stats.size.raw_bytes as u64),
            human_bytes(stats.size.compressed_bytes as u64),
            stats.size.ratio(),
            stats.size.bit_rate(),
            stats.pq_bandwidth_mbs(),
            stats.outlier_pct(),
        );
    }
    Ok(())
}

fn cmd_decompress(a: &Args) -> Result<()> {
    let input = a.get("input").ok_or_else(|| VszError::config("--input required"))?;
    let out = a.get("out").ok_or_else(|| VszError::config("--out required"))?;
    let threads = a.usize_or("threads", 1)?;
    apply_isa_flag(a)?;
    let bytes = std::fs::read(input)?;
    let field = decompress(&bytes, threads)?;
    dio::write_f32_file(Path::new(out), &field.data)?;
    println!(
        "decompressed {} -> {} ({} values, dims {:?})",
        input,
        out,
        field.data.len(),
        &field.dims.shape[..field.dims.ndim]
    );
    Ok(())
}

fn require_out(a: &Args) -> Result<String> {
    Ok(a.get("out").ok_or_else(|| VszError::config("--out required"))?.to_string())
}

fn parse_lo_hi(s: &str, flag: &str) -> Result<(usize, usize)> {
    s.split_once(':')
        .and_then(|(lo, hi)| Some((lo.parse().ok()?, hi.parse().ok()?)))
        .ok_or_else(|| VszError::config(format!("--{flag}: expected LO:HI")))
}

fn cmd_stream(a: &Args) -> Result<()> {
    let mode = a.positional.first().map(|s| s.as_str()).unwrap_or("");
    let input = a.get("input").ok_or_else(|| VszError::config("--input required"))?.to_string();
    let threads = a.usize_or("threads", 1)?;
    apply_isa_flag(a)?;
    match mode {
        "compress" => {
            let out = require_out(a)?;
            let cfg = parse_common(a)?;
            let dims = dio::parse_dims(
                a.get("dims").ok_or_else(|| VszError::config("--dims required"))?,
            )?;
            let chunk_rows = a.usize_or("chunk-rows", 0)?;
            let parity = a.usize_or("parity", 0)?;
            let tune = TuneSettings {
                sample_pct: a.f64_or("sample-pct", 5.0)?,
                iterations: a.usize_or("iterations", 1)?,
                ..TuneSettings::default()
            };
            let mut builder = vecsz::stream::StreamOptions::builder().parity(parity);
            if a.has("tune-chunks") {
                builder = builder.chunk_autotune_with(tune);
            }
            let opts = builder.build();
            let fin = std::fs::File::open(&input)?;
            let expect = dims.len() as u64 * 4;
            let got = fin.metadata()?.len();
            if got != expect {
                return Err(VszError::format(format!(
                    "{input}: {got} bytes, dims {:?} need {expect}",
                    &dims.shape[..dims.ndim]
                )));
            }
            std::fs::create_dir_all(Path::new(&out).parent().unwrap_or(Path::new(".")))?;
            if a.has("resume") {
                if let Some(state) = scan_partial(&out, parity) {
                    if state.complete {
                        println!("{out}: container already complete; nothing to resume");
                        return Ok(());
                    }
                    let mut fout =
                        std::fs::OpenOptions::new().read(true).write(true).open(&out)?;
                    fout.set_len(state.truncate_at)?;
                    std::io::Seek::seek(&mut fout, std::io::SeekFrom::End(0))?;
                    let stats = vecsz::stream::resume_stream_with(
                        fin,
                        BufWriter::new(fout),
                        dims,
                        &cfg,
                        chunk_rows,
                        opts,
                        &state,
                    )?;
                    println!(
                        "resumed {input} -> {out} at chunk {} (row {}): {} -> {} in {} chunks  CR {:.2}x",
                        state.n_chunks_done,
                        state.rows_done,
                        human_bytes(stats.raw_bytes as u64),
                        human_bytes(stats.compressed_bytes as u64),
                        stats.n_chunks,
                        stats.ratio(),
                    );
                    return Ok(());
                }
                // no usable prefix (missing file or torn header): start over
            }
            let fout = std::fs::File::create(&out)?;
            // compress_stream_with reads whole chunk-span slabs, so memory
            // stays bounded by one slab regardless of file size
            let stats = vecsz::stream::compress_stream_with(
                fin,
                BufWriter::new(fout),
                dims,
                &cfg,
                chunk_rows,
                opts,
            )?;
            println!(
                "{input} -> {out}: {} -> {} in {} chunks  CR {:.2}x  P&Q {:.0} MB/s  outliers {}",
                human_bytes(stats.raw_bytes as u64),
                human_bytes(stats.compressed_bytes as u64),
                stats.n_chunks,
                stats.ratio(),
                vecsz::util::timer::mb_per_s(stats.n_elements * 4, stats.pq_seconds),
                stats.n_outliers,
            );
            if a.has("tune-chunks") {
                // per-chunk tuning report, entropy side: how often the
                // HUF3 local-table size gate actually paid off, and how
                // many gap-array segments decode can fan out over
                let fin = std::fs::File::open(&out)?;
                let mut raw = vecsz::stream::StreamDecompressor::new(BufReader::new(fin))?;
                let (mut locals, mut hchunks, mut segments) = (0usize, 0usize, 0usize);
                while let Some((_, sections)) = raw.next_raw_chunk()? {
                    let codes = sections.iter().find(|s| s.tag == vecsz::format::tag::CODES);
                    let info = codes.map(|s| vecsz::huffman::inspect_payload(&s.payload));
                    if let Some(Ok(info)) = info {
                        locals += info.local_tables;
                        hchunks += info.n_chunks;
                        segments += info.segments;
                    }
                }
                println!(
                    "entropy: {locals}/{hchunks} Huffman chunks took a local code table, \
                     {segments} gap-array decode segments"
                );
            }
            Ok(())
        }
        "decompress" => {
            let out = require_out(a)?;
            let fin = std::fs::File::open(&input)?;
            std::fs::create_dir_all(Path::new(&out).parent().unwrap_or(Path::new(".")))?;
            let fout = std::fs::File::create(&out)?;
            let header = vecsz::stream::decompress_stream(
                BufReader::new(fin),
                BufWriter::new(fout),
                threads,
            )?;
            let d = header.header.dims;
            println!(
                "{input} -> {out}: {} values, dims {:?}, chunk span {}",
                d.len(),
                &d.shape[..d.ndim],
                header.chunk_span
            );
            Ok(())
        }
        "inspect" => {
            let fin = std::fs::File::open(&input)?;
            let mut dec = vecsz::stream::StreamDecompressor::new(BufReader::new(fin))?;
            let h = *dec.header();
            let d = h.header.dims;
            println!(
                "{input}: VSZ{} container, dims {:?}, eb {:.3e}, base block {}, chunk span {}",
                h.version,
                &d.shape[..d.ndim],
                h.header.eb,
                h.header.block_size,
                h.chunk_span,
            );
            match dec.load_index() {
                Ok(idx) => {
                    println!("{} chunks indexed:", idx.n_chunks());
                    println!("{:>6} {:>12} {:>12} {:>8} {:>8} {:>6} {:>8}",
                        "chunk", "offset", "bytes", "row0", "rows", "block", "kernel");
                    for (k, e) in idx.entries.iter().enumerate() {
                        println!(
                            "{k:>6} {:>12} {:>12} {:>8} {:>8} {:>6} {:>8}",
                            e.offset, e.frame_len, idx.lead_offsets[k], e.lead_extent,
                            e.meta.block_size, e.meta.backend_label(),
                        );
                    }
                }
                Err(e) => println!("no random-access index: {e}"),
            }
            // entropy framing per chunk: a header-only walk of each chunk's
            // CODES payload (no decode) reporting the table mode — how many
            // Huffman chunks carry their own code table — and the gap-array
            // segment count the decoder can fan out over
            let fin = std::fs::File::open(&input)?;
            let mut raw = vecsz::stream::StreamDecompressor::new(BufReader::new(fin))?;
            println!("entropy (CODES section):");
            println!(
                "{:>6} {:>8} {:>8} {:>12} {:>9} {:>10}",
                "chunk", "framing", "hchunks", "local-tables", "segments", "symbols"
            );
            let mut k = 0usize;
            while let Some((_, sections)) = raw.next_raw_chunk()? {
                let codes = sections.iter().find(|s| s.tag == vecsz::format::tag::CODES);
                match codes.map(|s| vecsz::huffman::inspect_payload(&s.payload)) {
                    Some(Ok(info)) => println!(
                        "{k:>6} {:>8} {:>8} {:>12} {:>9} {:>10}",
                        info.framing, info.n_chunks, info.local_tables, info.segments,
                        info.total_syms,
                    ),
                    Some(Err(e)) => println!("{k:>6} unreadable CODES payload: {e}"),
                    None => println!("{k:>6} no CODES section"),
                }
                k += 1;
            }
            Ok(())
        }
        "extract" => {
            use vecsz::stream::{Dataset, DatasetOptions, Region};
            let out = require_out(a)?;
            let fin = std::fs::File::open(&input)?;
            let ds = Dataset::open_with(
                BufReader::new(fin),
                DatasetOptions { threads, ..DatasetOptions::default() },
            )?;
            let ndim = ds.header().header.dims.ndim;
            let chunk = a.get("chunk").map(|s| s.to_string());
            let rows = a.get("rows").map(|s| s.to_string());
            let cols = a.get("cols").map(|s| s.to_string());
            let planes = a.get("planes").map(|s| s.to_string());
            let selectors =
                [&chunk, &rows, &cols, &planes].iter().filter(|s| s.is_some()).count();
            if selectors != 1 {
                return Err(VszError::config(
                    "extract: exactly one of --chunk K, --rows LO:HI, --cols LO:HI \
                     or --planes LO:HI required",
                ));
            }
            let data = if let Some(k) = chunk {
                let k: usize =
                    k.parse().map_err(|_| VszError::config("--chunk: not an integer"))?;
                let data = ds.read(Region::Chunk(k))?;
                let r = ds.chunk_rows(k).expect("read validated the chunk index");
                println!(
                    "{input}: chunk {k} = rows {}..{} ({} values)",
                    r.start,
                    r.end,
                    data.len()
                );
                data
            } else if let Some(r) = rows {
                let (lo, hi) = parse_lo_hi(&r, "rows")?;
                let data = ds.read(Region::Rows(lo..hi))?;
                println!("{input}: rows {lo}..{hi} ({} values)", data.len());
                data
            } else if let Some(r) = cols {
                // the last (fastest-varying) axis: true columns in 2D & 3D
                let (lo, hi) = parse_lo_hi(&r, "cols")?;
                let data = ds.read(Region::Dim { dim: ndim - 1, range: lo..hi })?;
                println!("{input}: cols {lo}..{hi} ({} values)", data.len());
                data
            } else {
                // middle-axis range of a 3D field: the lateral plane set
                if ndim != 3 {
                    return Err(VszError::config(
                        "--planes needs a 3D field (use --rows / --cols otherwise)",
                    ));
                }
                let r = planes.unwrap();
                let (lo, hi) = parse_lo_hi(&r, "planes")?;
                let data = ds.read(Region::Dim { dim: 1, range: lo..hi })?;
                println!("{input}: planes {lo}..{hi} ({} values)", data.len());
                data
            };
            dio::write_f32_file(Path::new(&out), &data)?;
            println!("wrote {out}");
            Ok(())
        }
        "salvage" => {
            let fin = std::fs::File::open(&input)?;
            let mut dec = vecsz::stream::StreamDecompressor::new(BufReader::new(fin))?;
            let (chunks, report) = dec.salvage()?;
            // JSON hole report on stdout; prose on stderr so scripts can
            // pipe the report straight into a tool
            println!("{}", report.to_json());
            if let Some(out) = a.get("out") {
                let d = dec.header().header.dims;
                let row_elems = d.shape[1] * d.shape[2];
                let mut data = vec![0.0f32; d.len()];
                for c in &chunks {
                    let start = c.lead_offset * row_elems;
                    data[start..start + c.data.len()].copy_from_slice(&c.data);
                }
                dio::write_f32_file(Path::new(out), &data)?;
                eprintln!(
                    "wrote {out}: {} of {} rows recovered, {} hole(s) zero-filled",
                    report.rows_recovered,
                    report.total_rows,
                    report.holes.len(),
                );
            } else {
                eprintln!(
                    "{input}: recovered {}/{} chunks ({}/{} rows); pass --out F.f32 to \
                     write the reconstruction",
                    report.recovered.len(),
                    report.total_chunks,
                    report.rows_recovered,
                    report.total_rows,
                );
            }
            Ok(())
        }
        "scrub" | "repair" => {
            let repair = mode == "repair" || a.has("repair");
            let mut bytes = std::fs::read(&input)?;
            let report = vecsz::stream::scrub_container(&mut bytes, repair)?;
            // JSON report on stdout, prose on stderr (same split as salvage)
            println!("{}", report.to_json());
            if !report.is_clean() {
                // fsck-style exit: nonzero whenever the container is (still)
                // damaged — repairable-but-unrepaired in report-only mode,
                // or >= 2 losses in one parity group in either mode
                let why = if report.unrepairable_groups.is_empty() {
                    "damage found; run 'vsz stream repair' to rebuild from parity".to_string()
                } else {
                    format!(
                        "unrepairable damage (parity groups {:?} lost >= 2 frames)",
                        report.unrepairable_groups
                    )
                };
                return Err(VszError::format(format!("{input}: {why}")));
            }
            let n_repairs = report.repaired_chunks.len()
                + report.repaired_parity.len()
                + usize::from(report.repaired_trailer);
            if !repair || n_repairs == 0 {
                if n_repairs == 0 {
                    eprintln!("{input}: clean; nothing to repair");
                }
                return Ok(());
            }
            // temp file + atomic rename: a crash mid-rewrite leaves the
            // original container untouched
            let tmp = format!("{input}.tmp-repair");
            std::fs::write(&tmp, &bytes)?;
            std::fs::rename(&tmp, &input)?;
            eprintln!("{input}: repaired {n_repairs} frame(s) in place");
            Ok(())
        }
        other => Err(VszError::config(format!(
            "stream: expected 'compress', 'decompress', 'inspect', 'extract', 'salvage', \
             'scrub' or 'repair', got '{other}'"
        ))),
    }
}

/// `--resume` preflight: scan the partial output for its CRC-valid chunk
/// prefix. `None` (missing file, unreadable header) means nothing is
/// salvageable and the compression starts from scratch.
fn scan_partial(path: &str, parity_group: usize) -> Option<vecsz::stream::ResumeState> {
    let f = std::fs::File::open(path).ok()?;
    vecsz::stream::scan_resumable_with(BufReader::new(f), parity_group).ok()
}

fn cmd_batch(a: &Args) -> Result<()> {
    use vecsz::coordinator::pipeline::compress_batch;
    let cfg = parse_common(a)?;
    let name = a.get("suite").ok_or_else(|| VszError::config("--suite NAME|all required"))?;
    let scale = if a.has("full") { Scale::Full } else { Scale::Small };
    let seed = a.usize_or("seed", 0xDA7A)? as u64;
    let threads = a.usize_or("threads", 1)?;
    let chunked = if a.has("stream") || a.get("chunk-rows").is_some() {
        Some(a.usize_or("chunk-rows", 0)?)
    } else {
        None
    };
    let out_dir = a.get("out-dir").map(|s| s.to_string());

    let datasets = if name == "all" {
        vecsz::data::all_suites(scale, seed)
    } else {
        vec![suite(name, scale, seed)
            .ok_or_else(|| VszError::config(format!("unknown suite '{name}'")))?]
    };

    let t = vecsz::util::timer::Timer::start();
    let (mut raw, mut comp) = (0usize, 0usize);
    for ds in datasets {
        let items = compress_batch(ds.fields, &cfg, threads, chunked)?;
        for item in &items {
            raw += item.raw_bytes;
            comp += item.compressed_bytes;
            println!(
                "{:<11} {:<16} {:>10} -> {:>10}  CR {:>6.2}x  chunks {:>3}  outliers {}",
                ds.name,
                item.name,
                human_bytes(item.raw_bytes as u64),
                human_bytes(item.compressed_bytes as u64),
                item.ratio(),
                item.n_chunks,
                item.n_outliers,
            );
            if let Some(dir) = &out_dir {
                std::fs::create_dir_all(dir)?;
                std::fs::write(format!("{dir}/{}_{}.vsz", ds.name, item.name), &item.bytes)?;
            }
        }
    }
    println!(
        "batch: {} -> {} overall CR {:.2}x in {:.2}s ({} pool threads)",
        human_bytes(raw as u64),
        human_bytes(comp as u64),
        raw as f64 / comp.max(1) as f64,
        t.elapsed_s(),
        threads.max(1),
    );
    Ok(())
}

fn cmd_verify(a: &Args) -> Result<()> {
    let cfg = parse_common(a)?;
    for f in load_inputs(a)? {
        let (stats, max_err) = verify_roundtrip(&f, &cfg)?;
        println!(
            "{:<16} OK  eb {:.3e}  max err {:.3e}  CR {:.2}x  outliers {:.3}%",
            f.name,
            stats.eb,
            max_err,
            stats.size.ratio(),
            stats.outlier_pct()
        );
    }
    println!("error bound holds for all fields");
    Ok(())
}

fn cmd_bench(a: &Args) -> Result<()> {
    let cfg = parse_common(a)?;
    let opts = if a.has("quick") { BenchOpts::quick() } else { BenchOpts::from_env() };
    for f in load_inputs(a)? {
        let be = cfg.backend.instantiate();
        let stats = bench(
            &format!("{} [{}] pq", f.name, be.name()),
            f.data.len() * 4,
            opts,
            || {
                let _ = pq_stage(&f, &cfg, be.as_ref());
            },
        );
        println!("{}", stats.row());
    }
    Ok(())
}

fn cmd_autotune(a: &Args) -> Result<()> {
    let cfg = parse_common(a)?;
    let settings = TuneSettings {
        sample_pct: a.f64_or("sample-pct", 5.0)?,
        iterations: a.usize_or("iterations", 2)?,
        seed: a.usize_or("seed", 7)? as u64,
    };
    for f in load_inputs(a)? {
        let eb = cfg.eb.resolve(&f.data);
        let r = autotune(&f, eb, cfg.radius, cfg.padding, &[8, 16], settings);
        println!("{}: sampled {} blocks in {:.3}s", f.name, r.sampled_blocks, r.tune_seconds);
        for p in &r.table {
            let mark = if p.config == r.best { " <== best" } else { "" };
            println!(
                "   bs={:<3} {:<6} {:>9.0} MB/s{mark}",
                p.config.block_size,
                p.config.backend_label(),
                p.mb_per_s
            );
        }
    }
    Ok(())
}

fn cmd_roofline(a: &Args) -> Result<()> {
    let quick = a.has("quick");
    let h = roofline::host_info();
    println!("host: {} ({} cores, cache {} KB, avx2={} avx512={})",
        h.model, h.cores, h.cache_kb, h.has_avx2, h.has_avx512);
    println!("simd dispatch: {}", vecsz::simd::Isa::active().name());
    let c = roofline::measure_ceilings(quick);
    println!("stream triad : {:.2} GB/s", c.dram_gb_s);
    println!("peak f32 FMA : {:.2} GFLOP/s", c.peak_gflop_s);
    for ndim in 1..=3 {
        let m = roofline::oi_model(ndim);
        let p = roofline::evaluate(c, m.oi_conservative(), 0.0);
        println!(
            "dual-quant {ndim}D: OI [{:.2}, {:.2}] flop/B -> attainable {:.1} GFLOP/s ({})",
            m.oi_conservative(),
            m.oi_lenient(),
            p.attainable_gflop_s,
            if p.memory_bound { "memory-bound" } else { "compute-bound" }
        );
    }
    Ok(())
}

fn cmd_figure(a: &Args) -> Result<()> {
    let id = a.positional.first().map(|s| s.as_str()).unwrap_or("list");
    let out_dir = a.str_or("out-dir", "results").to_string();
    let quick = a.has("quick");
    if id == "list" {
        println!("available: {}", vecsz::figures::ALL_IDS.join(" "));
        return Ok(());
    }
    if !vecsz::figures::run(id, &out_dir, quick)? {
        return Err(VszError::config(format!(
            "unknown figure '{id}' (try: {})",
            vecsz::figures::ALL_IDS.join(" ")
        )));
    }
    println!("\ncsv written under {out_dir}/");
    Ok(())
}

fn cmd_gen_data(a: &Args) -> Result<()> {
    let name = a.get("suite").ok_or_else(|| VszError::config("--suite required"))?;
    let out_dir = a.str_or("out-dir", "data");
    let scale = if a.has("full") { Scale::Full } else { Scale::Small };
    let ds = suite(name, scale, a.usize_or("seed", 0xDA7A)? as u64)
        .ok_or_else(|| VszError::config(format!("unknown suite '{name}'")))?;
    std::fs::create_dir_all(out_dir)?;
    for f in &ds.fields {
        let dims_s: Vec<String> =
            f.dims.shape[..f.dims.ndim].iter().map(|d| d.to_string()).collect();
        let path = format!("{out_dir}/{}_{}_{}.f32", ds.name, f.name, dims_s.join("x"));
        dio::write_f32_file(Path::new(&path), &f.data)?;
        println!("wrote {path} ({})", human_bytes(f.size_bytes() as u64));
    }
    Ok(())
}

fn cmd_pipeline(a: &Args) -> Result<()> {
    use vecsz::coordinator::pipeline::{run_stream, PipelineConfig};
    let cfg = parse_common(a)?;
    let name = a.str_or("suite", "cesm").to_string();
    let steps = a.usize_or("steps", 8)?;
    let out_dir = a.str_or("out-dir", "").to_string();
    let seed = a.usize_or("seed", 42)? as u64;
    let chunked = if a.has("stream") || a.get("chunk-rows").is_some() {
        Some(a.usize_or("chunk-rows", 0)?)
    } else {
        None
    };
    let pcfg = PipelineConfig {
        base: cfg,
        retune_every: a.usize_or("retune-every", 16)?,
        tune: TuneSettings::default(),
        widths: [8, 16],
        queue_depth: 2,
        chunked,
        chunk_autotune: a.has("tune-chunks"),
        verify: a.has("verify-steps"),
    };
    let nm = name.clone();
    let report = run_stream(
        move |i| {
            if i >= steps {
                return None;
            }
            // time-step analog: re-seeded suite = evolved field
            suite(&nm, Scale::Small, seed + i as u64).map(|ds| {
                let mut f = ds.fields.into_iter().next().unwrap();
                f.name = format!("{}_t{:03}", f.name, i);
                f
            })
        },
        pcfg,
        |step, bytes| {
            if !out_dir.is_empty() {
                std::fs::create_dir_all(&out_dir)?;
                std::fs::write(format!("{out_dir}/step{step:03}.vsz"), &bytes)?;
            }
            Ok(())
        },
    )?;
    for s in &report.steps {
        let tune = s
            .tuned
            .map(|t| format!("tuned bs{} w{}", t.block_size, t.width))
            .unwrap_or_else(|| "-".into());
        println!(
            "step {:>3} {:<20} CR {:>6.2}x  P&Q {:>8.0} MB/s  stall {:>6.1} ms  {}",
            s.step,
            s.field_name,
            s.stats.size.ratio(),
            s.stats.pq_bandwidth_mbs(),
            s.stall_seconds * 1e3,
            tune
        );
    }
    println!(
        "pipeline: {} steps in {:.2}s, overall CR {:.2}x, mean P&Q {:.0} MB/s, tuning {:.1}% of wall",
        report.steps.len(),
        report.total_seconds,
        report.overall_ratio(),
        report.mean_pq_mbs(),
        report.tune_overhead_pct()
    );
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<()> {
    use vecsz::server::{Client, RetryPolicy, ServeConfig, Server};
    let addr = a.str_or("addr", "127.0.0.1:7227").to_string();
    if a.has("status") {
        // a briefly-busy server is not a reason for a status probe to
        // fail: retry with capped backoff like any other client
        let mut c = Client::connect(&addr)?;
        println!("{}", c.with_retry(&RetryPolicy::default(), |c| c.stats())?);
        return Ok(());
    }
    let cfg = ServeConfig {
        threads: a.usize_or("threads", 4)?,
        max_inflight_bytes: (a.usize_or("max-inflight-mb", 256)? as u64) << 20,
        max_conns: a.usize_or("max-conns", 32)?,
        chunk_rows: a.usize_or("chunk-rows", 0)?,
        request_timeout_ms: a.usize_or("request-timeout-ms", 0)? as u64,
        cache_bytes: (a.usize_or("cache-mb", 64)? as u64) << 20,
    };
    apply_isa_flag(a)?;
    let srv = Server::bind(&addr, cfg)?;
    println!(
        "vsz serve: listening on {} ({} pool threads, {} in-flight cap, {} conns, \
         {} chunk cache)",
        srv.local_addr()?,
        cfg.threads.max(1),
        human_bytes(cfg.max_inflight_bytes),
        cfg.max_conns,
        human_bytes(cfg.cache_bytes),
    );
    srv.run()
}

fn cmd_info(a: &Args) -> Result<()> {
    println!("vecsz {}", vecsz::version());
    let h = roofline::host_info();
    println!("host: {} ({} cores)", h.model, h.cores);
    let avail: Vec<&str> = vecsz::simd::Isa::available().iter().map(|i| i.name()).collect();
    println!(
        "simd dispatch: {} (available: {}; compiled: {})",
        vecsz::simd::Isa::active().name(),
        avail.join(","),
        vecsz::simd::compiled_target_features()
    );
    println!(
        "decode kernel: {}",
        vecsz::quant::decode::default_decode_backend().name()
    );
    let dir = a.str_or("artifacts", "artifacts");
    match vecsz::runtime::Manifest::load(Path::new(dir)) {
        Ok(m) => {
            println!("artifacts ({}):", dir);
            for art in &m.artifacts {
                println!(
                    "  {:<24} {}D bs={:<4} lanes={:<3} superbatch={:<6} [{}]",
                    art.name, art.ndim, art.block_size, art.lanes, art.superbatch, art.impl_kind
                );
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}

fn dispatch(a: &Args) -> Result<()> {
    match a.subcommand.as_str() {
        "compress" => cmd_compress(a),
        "decompress" => cmd_decompress(a),
        "stream" => cmd_stream(a),
        "batch" => cmd_batch(a),
        "verify" => cmd_verify(a),
        "bench" => cmd_bench(a),
        "autotune" => cmd_autotune(a),
        "roofline" => cmd_roofline(a),
        "figure" => cmd_figure(a),
        "gen-data" => cmd_gen_data(a),
        "serve" => cmd_serve(a),
        "pipeline" => cmd_pipeline(a),
        "info" => cmd_info(a),
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(VszError::config(format!("unknown command '{other}'\n{USAGE}"))),
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    if args.has("help") {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

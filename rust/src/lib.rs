//! # vecSZ — SIMD lossy compression for scientific data
//!
//! A three-layer reproduction of the vecSZ paper (CS.DC 2022): an
//! error-bounded lossy compression framework whose prediction/quantization
//! hot path uses the RAW-dependence-free *dual-quantization* algorithm,
//! executed either as a lane-chunked native Rust kernel (the paper's
//! CPU-SIMD contribution) or as an AOT-compiled XLA/Pallas artifact via
//! PJRT.
//!
//! Public entry points:
//! * [`compressor`] — `compress`/`decompress` over whole in-memory fields.
//! * [`stream`] — the chunked streaming engine (`StreamCompressor`/
//!   `StreamDecompressor` over `std::io::Read`/`Write`) for out-of-core
//!   fields, chunk-parallel decode and per-chunk autotuning. Index-driven
//!   random access lives behind [`stream::dataset`]: open a container
//!   once as a `Dataset`, then `read` any `Region` (chunk / chunk range /
//!   rows / axis range / all) through a memory-bounded decoded-chunk LRU
//!   cache with single-flight, chunk-parallel miss filling.
//! * [`data`] — synthetic SDRBench-like dataset suites.
//! * [`metrics`] — PSNR / rate-distortion evaluation.
//! * [`autotune`] — block-size/lane-width/backend autotuning.
//! * [`simd`] — explicit-intrinsics lane layer with runtime ISA dispatch
//!   (AVX2 / AVX-512F / NEON / scalar) behind `quant::simd::SimdBackend`
//!   (forward) and `quant::decode::SimdDecodeBackend` (the reverse-Lorenzo
//!   wavefront decode).
//! * [`coordinator`] — thread pool, job-graph executor and the two-level
//!   fields×chunks scheduler (plus the streaming/batch drivers on top).
//! * [`server`] — `vsz serve`: a framed-TCP compression service over the
//!   shared scheduler, with admission control, per-request deadlines +
//!   cancellation, and lifetime statistics.
//! * [`failpoint`] — deterministic, env-gated fault injection
//!   (`VECSZ_FAILPOINTS`) for crash/corruption testing.
//! * [`roofline`] — ERT-like machine characterization.

pub mod autotune;
pub mod bench;
pub mod bitio;
pub mod blocks;
pub mod cli;
pub mod compressor;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod failpoint;
pub mod figures;
pub mod format;
pub mod metrics;
pub mod roofline;
pub mod huffman;
pub mod lorenzo;
pub mod lossless;
pub mod padding;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod simd;
pub mod stream;
pub mod util;

pub use error::{Result, VszError};

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

//! SZ-1.4 baseline — Algorithm 1: predict-on-reconstructed values with
//! linear-scale quantization.
//!
//! The Lorenzo predictor reads *previously reconstructed* neighbours, so
//! iteration `l` cannot start until `l-1`'s reconstruction is written: the
//! loop-carried RAW dependence (line 14 of Algorithm 1) that makes this
//! algorithm unvectorizable and motivates the whole paper.
//!
//! Outliers store the original value verbatim (zero error) and reconstruct
//! as that value, exactly as SZ-1.4 does.

use super::{check_batch, CodesKind, DqConfig, PqBackend, OUTLIER_CODE};
use crate::blocks::HaloBlock;
use crate::lorenzo::{for_each_coord, predict_halo};
use crate::padding::PadScalars;

pub struct Sz14Backend;

impl PqBackend for Sz14Backend {
    fn name(&self) -> String {
        "sz14".to_string()
    }

    fn kind(&self) -> CodesKind {
        CodesKind::Sz14
    }

    fn lanes(&self) -> usize {
        1
    }

    fn run(
        &self,
        cfg: &DqConfig,
        blocks: &[f32],
        block_base: usize,
        pads: &PadScalars,
        codes: &mut [u16],
        outv: &mut [f32],
    ) {
        let shape = cfg.shape;
        let elems = shape.elems();
        let nb = check_batch(shape, blocks, codes, outv);
        let radius = cfg.radius;
        let radius_f = cfg.radius as f32;
        let eb = cfg.eb as f32;
        let half_inv_eb = cfg.half_inv_eb();
        let twice_eb = cfg.twice_eb();
        let mut halo = HaloBlock::new(shape);

        for b in 0..nb {
            let block = &blocks[b * elems..(b + 1) * elems];
            // halo in DATA units; interior starts as original values and is
            // overwritten by reconstructions as the scan proceeds (the RAW).
            halo.fill_halo(|axis| pads.edge_scalar(block_base + b, axis));
            halo.load_interior(block, |x| x);
            let ccodes = &mut codes[b * elems..(b + 1) * elems];
            let coutv = &mut outv[b * elems..(b + 1) * elems];
            for_each_coord(shape, |l, c| {
                let d = block[l];
                let pred = predict_halo(&halo.buf, shape, c);
                let err = d - pred;
                // linear-scale quantization of the prediction error
                let q = (err * half_inv_eb).round_ties_even();
                let hidx = halo.interior_index(c);
                if q.abs() < radius_f {
                    let recon = pred + q * twice_eb;
                    // WATCHDOG (Algorithm 1 line 9): guard quantization
                    // round-off; fall back to outlier if bound violated.
                    if (recon - d).abs() <= eb {
                        ccodes[l] = q as i32 as u16 + radius;
                        coutv[l] = 0.0;
                        halo.buf[hidx] = recon;
                        return;
                    }
                }
                ccodes[l] = OUTLIER_CODE;
                coutv[l] = d; // verbatim original
                halo.buf[hidx] = d;
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};

    fn zero_pads(ndim: usize) -> PadScalars {
        PadScalars {
            policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
            scalars: vec![0.0],
            ndim,
        }
    }

    #[test]
    fn prediction_uses_reconstructed_not_original() {
        // With eb=0.5 and data [0.4, 0.4]: first value quantizes to bin 0
        // (recon 0.0 from pad, err 0.4 -> q=0, recon=0.0 holds |0-0.4|<=0.5).
        // Second prediction uses RECON 0.0 (not 0.4): err 0.4 -> q=0 again.
        let shape = BlockShape::new(1, 2);
        let cfg = DqConfig::new(0.5, 512, shape);
        let blocks = vec![0.4f32, 0.4];
        let mut codes = vec![0u16; 2];
        let mut outv = vec![0.0f32; 2];
        Sz14Backend.run(&cfg, &blocks, 0, &zero_pads(1), &mut codes, &mut outv);
        assert_eq!(codes, vec![512, 512]);
    }

    #[test]
    fn error_bound_holds_via_reconstruction() {
        let shape = BlockShape::new(2, 8);
        let cfg = DqConfig::new(1e-3, 512, shape);
        let mut rng = crate::util::prng::Pcg32::seeded(5);
        let blocks: Vec<f32> = (0..shape.elems()).map(|_| rng.next_f32() * 4.0).collect();
        let mut codes = vec![0u16; blocks.len()];
        let mut outv = vec![0.0f32; blocks.len()];
        Sz14Backend.run(&cfg, &blocks, 0, &zero_pads(2), &mut codes, &mut outv);
        // decode and check bound (decode::decode_block_sz14 tested there;
        // here use a local replay to keep the module self-contained)
        let mut halo = HaloBlock::new(shape);
        halo.fill_halo(|_| 0.0);
        let mut rec = vec![0.0f32; blocks.len()];
        crate::lorenzo::for_each_coord(shape, |l, c| {
            let v = if codes[l] == OUTLIER_CODE {
                outv[l]
            } else {
                let pred = predict_halo(&halo.buf, shape, c);
                pred + (codes[l] as i32 - cfg.radius as i32) as f32 * cfg.twice_eb()
            };
            let hidx = halo.interior_index(c);
            halo.buf[hidx] = v;
            rec[l] = v;
        });
        for (r, d) in rec.iter().zip(&blocks) {
            assert!((r - d).abs() <= 1e-3 + 1e-6, "bound violated: {r} vs {d}");
        }
    }

    #[test]
    fn watchdog_catches_roundoff_at_cap_edge() {
        // large values + tiny eb force outliers through the q-cap path
        let shape = BlockShape::new(1, 4);
        let cfg = DqConfig::new(1e-6, 8, shape);
        let blocks = vec![5.0f32, -5.0, 5.0, -5.0];
        let mut codes = vec![0u16; 4];
        let mut outv = vec![0.0f32; 4];
        Sz14Backend.run(&cfg, &blocks, 0, &zero_pads(1), &mut codes, &mut outv);
        assert!(codes.iter().all(|&c| c == OUTLIER_CODE));
        assert_eq!(outv, blocks); // verbatim
    }
}

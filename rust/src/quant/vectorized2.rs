//! Halo-free lane-chunked dual-quant (perf iteration on `vectorized.rs`).
//!
//! The original backend copies every block into a `(bs+1)^d` halo buffer so
//! neighbour reads never branch. That copy is a full extra read+write pass
//! over the data — measurable on a memory-bound kernel. This version works
//! directly on a pre-quantized scratch block and *hoists* the border cases
//! to row level (the paper's §III-C: boundary checks at vector-register
//! granularity, not element granularity).
//!
//! Bit-exactness: border neighbours read the same padding scalars the halo
//! planes would hold (replicating the halo fill precedence — later axes
//! overwrite shared cells) and every prediction keeps `predict_halo`'s
//! operation order `(w+n+u)-(nw+nu+wu)+nwu`, so no f32 re-association can
//! diverge from `psz`/`vectorized` even when per-axis edge scalars differ.
//! Enforced by the equivalence tests below (including edge granularity).

use super::{check_batch, prequant, CodesKind, DqConfig, PqBackend, OUTLIER_CODE};
use crate::padding::PadScalars;

/// Halo-free lane-chunked dual-quant backend (the optimized hot path).
#[derive(Clone, Copy, Debug)]
pub struct VecBackend2 {
    pub width: usize,
}

impl VecBackend2 {
    pub fn new(width: usize) -> Self {
        assert!(matches!(width, 4 | 8 | 16), "supported lane widths: 4, 8, 16");
        Self { width }
    }
}

impl PqBackend for VecBackend2 {
    fn name(&self) -> String {
        format!("vec{}h", self.width)
    }

    fn kind(&self) -> CodesKind {
        CodesKind::DualQuant
    }

    fn lanes(&self) -> usize {
        self.width
    }

    fn run(
        &self,
        cfg: &DqConfig,
        blocks: &[f32],
        block_base: usize,
        pads: &PadScalars,
        codes: &mut [u16],
        outv: &mut [f32],
    ) {
        match self.width {
            4 => run_w::<4>(cfg, blocks, block_base, pads, codes, outv),
            8 => run_w::<8>(cfg, blocks, block_base, pads, codes, outv),
            16 => run_w::<16>(cfg, blocks, block_base, pads, codes, outv),
            _ => unreachable!(),
        }
    }
}

/// Branch form of the outlier split for single border elements.
#[inline(always)]
fn emit1(dq: f32, pred: f32, radius_f: f32, code: &mut u16, ov: &mut f32) {
    let delta = dq - pred;
    if delta.abs() < radius_f {
        *code = (delta + radius_f) as i32 as u16;
        *ov = 0.0;
    } else {
        *code = OUTLIER_CODE;
        *ov = dq;
    }
}

/// Lane loop over `cur[1..]` with a per-j prediction expression.
macro_rules! lane_loop {
    ($W:expr, $cur:expr, $codes:expr, $outv:expr, $radius_f:expr, |$j:ident| $pred:expr) => {{
        let n = $cur.len();
        let mut j = 1usize;
        while j + $W <= n {
            // fixed-width chunk: LLVM lowers to packed SIMD
            for t in 0..$W {
                let $j = j + t;
                let dqv = $cur[$j];
                let delta = dqv - $pred;
                let ic = (delta.abs() < $radius_f) as u32 as f32;
                $codes[$j] = (ic * (delta + $radius_f)) as i32 as u16;
                $outv[$j] = (1.0 - ic) * dqv;
            }
            j += $W;
        }
        while j < n {
            let $j = j;
            emit1($cur[$j], $pred, $radius_f, &mut $codes[$j], &mut $outv[$j]);
            j += 1;
        }
    }};
}

fn run_w<const W: usize>(
    cfg: &DqConfig,
    blocks: &[f32],
    block_base: usize,
    pads: &PadScalars,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    let shape = cfg.shape;
    let elems = shape.elems();
    let bs = shape.bs;
    let nb = check_batch(shape, blocks, codes, outv);
    let radius_f = cfg.radius as f32;
    let hie = cfg.half_inv_eb();
    let mut dq = vec![0.0f32; elems];

    for b in 0..nb {
        let block = &blocks[b * elems..(b + 1) * elems];
        // pre-quantization pass (vectorizable elementwise)
        for (d, &x) in dq.iter_mut().zip(block) {
            *d = prequant(x, hie);
        }
        let gb = block_base + b;
        let ccodes = &mut codes[b * elems..(b + 1) * elems];
        let coutv = &mut outv[b * elems..(b + 1) * elems];

        match shape.ndim {
            1 => {
                let p0 = prequant(pads.edge_scalar(gb, 0), hie);
                emit1(dq[0], p0, radius_f, &mut ccodes[0], &mut coutv[0]);
                let cur = &dq[..];
                lane_loop!(W, cur, ccodes, coutv, radius_f, |j| cur[j - 1]);
            }
            2 => {
                // halo precedence: axis-1 planes overwrite shared cells,
                // so row-0 body cells hold p0, the column (incl. corner) p1.
                let p0 = prequant(pads.edge_scalar(gb, 0), hie);
                let p1 = prequant(pads.edge_scalar(gb, 1), hie);
                for i in 0..bs {
                    let row = i * bs;
                    let (before, cur_on) = dq.split_at(row);
                    let cur = &cur_on[..bs];
                    let c = &mut ccodes[row..row + bs];
                    let v = &mut coutv[row..row + bs];
                    if i == 0 {
                        // (0,0): w=p1 n=p0 nw=p1 ; (0,j): n=nw=p0
                        emit1(cur[0], p1 + p0 - p1, radius_f, &mut c[0], &mut v[0]);
                        lane_loop!(W, cur, c, v, radius_f, |j| cur[j - 1] + p0 - p0);
                    } else {
                        let north = &before[row - bs..];
                        // (i,0): w=nw=p1
                        emit1(cur[0], p1 + north[0] - p1, radius_f, &mut c[0], &mut v[0]);
                        lane_loop!(W, cur, c, v, radius_f, |j| cur[j - 1] + north[j]
                            - north[j - 1]);
                    }
                }
            }
            3 => {
                // halo precedence (fill order axis0 -> axis1 -> axis2):
                //   cell with j-coord 0            -> p2
                //   else cell with i-coord 0       -> p1
                //   else cell with k-coord 0       -> p0
                let p0 = prequant(pads.edge_scalar(gb, 0), hie);
                let p1 = prequant(pads.edge_scalar(gb, 1), hie);
                let p2 = prequant(pads.edge_scalar(gb, 2), hie);
                let plane = bs * bs;
                for k in 0..bs {
                    for i in 0..bs {
                        let row = k * plane + i * bs;
                        let (before, cur_on) = dq.split_at(row);
                        let cur = &cur_on[..bs];
                        let c = &mut ccodes[row..row + bs];
                        let v = &mut coutv[row..row + bs];
                        // predict_halo order: (w+n+u)-(nw+nu+wu)+nwu
                        match (k > 0, i > 0) {
                            (true, true) => {
                                let north = &before[row - bs..row - bs + bs];
                                let up = &before[row - plane..row - plane + bs];
                                let nu = &before[row - plane - bs..row - plane - bs + bs];
                                // j=0: w=nw=wu=nwu=p2
                                emit1(
                                    cur[0],
                                    (p2 + north[0] + up[0]) - (p2 + nu[0] + p2) + p2,
                                    radius_f,
                                    &mut c[0],
                                    &mut v[0],
                                );
                                lane_loop!(W, cur, c, v, radius_f, |j| (cur[j - 1]
                                    + north[j]
                                    + up[j])
                                    - (north[j - 1] + nu[j] + up[j - 1])
                                    + nu[j - 1]);
                            }
                            (true, false) => {
                                // i == 0: n,nw,nu,nwu live in the i=0 halo
                                let up = &before[row - plane..row - plane + bs];
                                // j=0: w=p2 n=p1 nw=p2 nu=p1 wu=p2 nwu=p2
                                emit1(
                                    cur[0],
                                    (p2 + p1 + up[0]) - (p2 + p1 + p2) + p2,
                                    radius_f,
                                    &mut c[0],
                                    &mut v[0],
                                );
                                // j>=1: n=nw=nu=nwu=p1
                                lane_loop!(W, cur, c, v, radius_f, |j| (cur[j - 1] + p1 + up[j])
                                    - (p1 + p1 + up[j - 1])
                                    + p1);
                            }
                            (false, true) => {
                                // k == 0: u,wu,nu,nwu live in the k=0 halo
                                let north = &before[row - bs..row - bs + bs];
                                // j=0: w=p2 nw=p2 u=p0 nu=p0 wu=p2 nwu=p2
                                emit1(
                                    cur[0],
                                    (p2 + north[0] + p0) - (p2 + p0 + p2) + p2,
                                    radius_f,
                                    &mut c[0],
                                    &mut v[0],
                                );
                                // j>=1: u=wu=nu=nwu=p0
                                lane_loop!(W, cur, c, v, radius_f, |j| (cur[j - 1]
                                    + north[j]
                                    + p0)
                                    - (north[j - 1] + p0 + p0)
                                    + p0);
                            }
                            (false, false) => {
                                // k == i == 0
                                // j=0: w=p2 n=p1 u=p0 nw=p2 nu=p1 wu=p2 nwu=p2
                                emit1(
                                    cur[0],
                                    (p2 + p1 + p0) - (p2 + p1 + p2) + p2,
                                    radius_f,
                                    &mut c[0],
                                    &mut v[0],
                                );
                                // j>=1: n=nw=p1... careful: n = halo(1,0,j+1)
                                // -> i-coord 0 -> p1; nw same -> p1;
                                // u = halo(0,1,j+1) -> k-coord 0 -> p0; wu -> p0;
                                // nu = halo(0,0,j+1) -> i-coord 0 -> p1; nwu -> p1
                                lane_loop!(W, cur, c, v, radius_f, |j| (cur[j - 1] + p1 + p0)
                                    - (p1 + p1 + p0)
                                    + p1);
                            }
                        }
                    }
                }
            }
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};
    use crate::quant::psz::PszBackend;
    use crate::quant::test_support::random_batch;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;

    #[test]
    fn halo_free_matches_psz_bit_exact_all_dims() {
        let mut rng = Pcg32::seeded(77);
        for &(ndim, bs) in &[(1usize, 64usize), (1, 7), (2, 8), (2, 16), (2, 5), (3, 8), (3, 4)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-3, 512, shape);
            for smooth in [true, false] {
                let (blocks, pads) = random_batch(&mut rng, shape, 5, 4.0, smooth);
                let mut c0 = vec![0u16; blocks.len()];
                let mut v0 = vec![0.0f32; blocks.len()];
                PszBackend.run(&cfg, &blocks, 0, &pads, &mut c0, &mut v0);
                for w in [4usize, 8, 16] {
                    let mut c1 = vec![0u16; blocks.len()];
                    let mut v1 = vec![0.0f32; blocks.len()];
                    VecBackend2::new(w).run(&cfg, &blocks, 0, &pads, &mut c1, &mut v1);
                    assert_eq!(c0, c1, "codes: ndim={ndim} bs={bs} w={w} smooth={smooth}");
                    assert_eq!(v0, v1, "outv: ndim={ndim} bs={bs} w={w}");
                }
            }
        }
    }

    #[test]
    fn halo_free_matches_psz_with_distinct_edge_scalars() {
        // per-axis edge scalars of very different magnitudes stress the
        // f32-order-of-operations equivalence (no collapsed shortcuts!)
        let mut rng = Pcg32::seeded(99);
        for &(ndim, bs) in &[(1usize, 9usize), (2, 8), (3, 6)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-2, 512, shape);
            let (blocks, _) = random_batch(&mut rng, shape, 4, 2.0, true);
            let nb = 4;
            let scalars: Vec<f32> = (0..nb * ndim)
                .map(|q| [1000.0f32, -0.37, 12.5][q % 3] * (1.0 + q as f32))
                .collect();
            let pads = PadScalars {
                policy: PaddingPolicy::new(PadValue::Avg, PadGranularity::Edge),
                scalars,
                ndim,
            };
            let mut c0 = vec![0u16; blocks.len()];
            let mut v0 = vec![0.0f32; blocks.len()];
            PszBackend.run(&cfg, &blocks, 0, &pads, &mut c0, &mut v0);
            for w in [8usize, 16] {
                let mut c1 = vec![0u16; blocks.len()];
                let mut v1 = vec![0.0f32; blocks.len()];
                VecBackend2::new(w).run(&cfg, &blocks, 0, &pads, &mut c1, &mut v1);
                assert_eq!(c0, c1, "edge-pad codes: ndim={ndim} bs={bs} w={w}");
                assert_eq!(v0, v1, "edge-pad outv: ndim={ndim} bs={bs} w={w}");
            }
        }
    }

    #[test]
    fn prop_halo_free_equivalence() {
        check("vec2-equivalence", 50, |g| {
            let ndim = 1 + g.rng.bounded(3) as usize;
            let bs = *g.choose(&[3usize, 4, 8, 12]);
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(*g.choose(&[1e-2f64, 1e-3]), 512, shape);
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let (blocks, pads) = random_batch(&mut rng, shape, 3, 6.0, g.rng.next_f32() < 0.5);
            let mut c0 = vec![0u16; blocks.len()];
            let mut v0 = vec![0.0f32; blocks.len()];
            PszBackend.run(&cfg, &blocks, 0, &pads, &mut c0, &mut v0);
            let w = *g.choose(&[4usize, 8, 16]);
            let mut c1 = vec![0u16; blocks.len()];
            let mut v1 = vec![0.0f32; blocks.len()];
            VecBackend2::new(w).run(&cfg, &blocks, 0, &pads, &mut c1, &mut v1);
            if c0 == c1 && v0 == v1 {
                Ok(())
            } else {
                Err(format!("diverged ndim={ndim} bs={bs} w={w}"))
            }
        });
    }
}

//! pSZ — the serial dual-quantization baseline (Algorithm 2, scalar).
//!
//! A direct transcription of the paper's Algorithm 2: pre-quantize the
//! block, then for each element predict with Lorenzo on the pre-quantized
//! values and quantize the delta, with a data-dependent `if` on the outlier
//! path. The branch (and the per-element scalar structure) is exactly what
//! keeps this implementation off the SIMD units — it is the paper's `pSZ`
//! comparison point, compiled `-O3`.

use super::{check_batch, prep_halo_dq, DqConfig, PqBackend, CodesKind, OUTLIER_CODE};
use crate::blocks::HaloBlock;
use crate::lorenzo::{for_each_coord, predict_halo};
use crate::padding::PadScalars;

pub struct PszBackend;

impl PqBackend for PszBackend {
    fn name(&self) -> String {
        "psz".to_string()
    }

    fn kind(&self) -> CodesKind {
        CodesKind::DualQuant
    }

    fn lanes(&self) -> usize {
        1
    }

    fn run(
        &self,
        cfg: &DqConfig,
        blocks: &[f32],
        block_base: usize,
        pads: &PadScalars,
        codes: &mut [u16],
        outv: &mut [f32],
    ) {
        let shape = cfg.shape;
        let elems = shape.elems();
        let nb = check_batch(shape, blocks, codes, outv);
        let radius_f = cfg.radius as f32;
        let radius = cfg.radius;
        let mut halo = HaloBlock::new(shape);

        for b in 0..nb {
            let block = &blocks[b * elems..(b + 1) * elems];
            prep_halo_dq(&mut halo, block, cfg, pads, block_base + b);
            let ccodes = &mut codes[b * elems..(b + 1) * elems];
            let coutv = &mut outv[b * elems..(b + 1) * elems];
            for_each_coord(shape, |l, c| {
                let dq = halo.buf[halo.interior_index(c)];
                let pred = predict_halo(&halo.buf, shape, c);
                let delta = dq - pred;
                // Algorithm 2 lines 8-12: IN-CAP vs OUTLIER
                if delta.abs() < radius_f {
                    ccodes[l] = delta as i32 as u16 + radius;
                    coutv[l] = 0.0;
                } else {
                    ccodes[l] = OUTLIER_CODE;
                    coutv[l] = dq;
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};

    #[test]
    fn known_small_1d_case() {
        // eb = 0.5 -> prequant = round(x); pad 0
        // data [1, 2, 4, 4]: dq = [1,2,4,4]; preds = [0,1,2,4]
        // deltas = [1,1,2,0] -> codes = 513, 513, 514, 512
        let shape = BlockShape::new(1, 4);
        let cfg = DqConfig::new(0.5, 512, shape);
        let pads = PadScalars {
            policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
            scalars: vec![0.0],
            ndim: 1,
        };
        let blocks = vec![1.0f32, 2.0, 4.0, 4.0];
        let mut codes = vec![0u16; 4];
        let mut outv = vec![0.0f32; 4];
        PszBackend.run(&cfg, &blocks, 0, &pads, &mut codes, &mut outv);
        assert_eq!(codes, vec![513, 513, 514, 512]);
        assert_eq!(outv, vec![0.0; 4]);
    }

    #[test]
    fn negative_delta_encodes_below_radius() {
        let shape = BlockShape::new(1, 2);
        let cfg = DqConfig::new(0.5, 512, shape);
        let pads = PadScalars {
            policy: PaddingPolicy::ZERO,
            scalars: vec![0.0],
            ndim: 1,
        };
        // dq = [5, 2] -> deltas [5, -3] -> codes [517, 509]
        let blocks = vec![5.0f32, 2.0];
        let mut codes = vec![0u16; 2];
        let mut outv = vec![0.0f32; 2];
        PszBackend.run(&cfg, &blocks, 0, &pads, &mut codes, &mut outv);
        assert_eq!(codes, vec![517, 509]);
    }

    #[test]
    fn outlier_records_prequantized_value() {
        let shape = BlockShape::new(1, 2);
        let cfg = DqConfig::new(0.5, 4, shape); // tiny radius of 4
        let pads = PadScalars {
            policy: PaddingPolicy::ZERO,
            scalars: vec![0.0],
            ndim: 1,
        };
        let blocks = vec![100.0f32, 101.0];
        let mut codes = vec![0u16; 2];
        let mut outv = vec![0.0f32; 2];
        PszBackend.run(&cfg, &blocks, 0, &pads, &mut codes, &mut outv);
        assert_eq!(codes[0], OUTLIER_CODE); // delta 100 >= 4
        assert_eq!(outv[0], 100.0);
        assert_eq!(codes[1], 4 + 1); // delta 1 vs radius 4 -> code 5
    }
}

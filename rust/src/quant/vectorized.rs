//! vecSZ — the lane-chunked, branchless dual-quantization backend.
//!
//! The paper's contribution (§III-C): with the RAW dependence removed by
//! dual-quantization, the post-quantization loop is data-parallel. Here the
//! inner row loops are written as fixed-width lane chunks over `[f32; W]`
//! stack arrays with a branchless outlier select, which LLVM lowers to
//! packed SIMD (ymm for W=8, zmm for W=16 under `target-cpu=native`) —
//! the analog of the paper's hand-written AVX2/AVX-512 intrinsics, kept
//! ISA-portable exactly the way §III-C argues for.
//!
//! Boundary handling follows §III-C: out-of-field lanes are *computed
//! anyway* (blocks are gathered with padding fill), so no per-element
//! bounds branches survive in the hot loop.

use super::{check_batch, prep_halo_dq, CodesKind, DqConfig, PqBackend, OUTLIER_CODE};
use crate::blocks::HaloBlock;
use crate::padding::PadScalars;

/// Lane-chunked dual-quant backend; `width` ∈ {4, 8, 16} is the paper's
/// "vector length" knob (8 ≈ 256-bit, 16 ≈ 512-bit registers over f32).
///
/// `run` delegates to the halo-free implementation in [`super::vectorized2`]
/// (the §Perf iteration: +20-60% by skipping the halo copy); set
/// `halo: true` to use the original halo-buffer path — kept as the
/// reference implementation and for the ablation bench.
#[derive(Clone, Copy, Debug)]
pub struct VecBackend {
    pub width: usize,
    pub halo: bool,
}

impl VecBackend {
    pub fn new(width: usize) -> Self {
        assert!(matches!(width, 4 | 8 | 16), "supported lane widths: 4, 8, 16");
        Self { width, halo: false }
    }

    /// The original halo-buffer implementation (ablation reference).
    pub fn with_halo(width: usize) -> Self {
        Self { width, halo: true }
    }
}

impl PqBackend for VecBackend {
    fn name(&self) -> String {
        if self.halo {
            format!("vec{}-halo", self.width)
        } else {
            format!("vec{}", self.width)
        }
    }

    fn kind(&self) -> CodesKind {
        CodesKind::DualQuant
    }

    fn lanes(&self) -> usize {
        self.width
    }

    fn run(
        &self,
        cfg: &DqConfig,
        blocks: &[f32],
        block_base: usize,
        pads: &PadScalars,
        codes: &mut [u16],
        outv: &mut [f32],
    ) {
        if !self.halo {
            return super::vectorized2::VecBackend2::new(self.width)
                .run(cfg, blocks, block_base, pads, codes, outv);
        }
        match self.width {
            4 => run_w::<4>(cfg, blocks, block_base, pads, codes, outv),
            8 => run_w::<8>(cfg, blocks, block_base, pads, codes, outv),
            16 => run_w::<16>(cfg, blocks, block_base, pads, codes, outv),
            _ => unreachable!(),
        }
    }
}

/// Branchless post-quantization of one W-lane chunk.
/// `cur[t]` is the pre-quantized value, `pred[t]` its Lorenzo prediction.
#[inline(always)]
fn emit_lane<const W: usize>(
    cur: &[f32],
    pred: &[f32; W],
    radius_f: f32,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    for t in 0..W {
        let delta = cur[t] - pred[t];
        // in-cap mask as 0.0/1.0 — select without a branch
        let ic = (delta.abs() < radius_f) as u32 as f32;
        codes[t] = (ic * (delta + radius_f)) as i32 as u16;
        outv[t] = (1.0 - ic) * cur[t];
    }
}

/// Scalar tail for the last `n < W` elements of a row.
#[inline(always)]
fn emit_tail(cur: &[f32], pred: impl Fn(usize) -> f32, radius_f: f32, codes: &mut [u16], outv: &mut [f32]) {
    for t in 0..cur.len() {
        let delta = cur[t] - pred(t);
        if delta.abs() < radius_f {
            codes[t] = (delta + radius_f) as i32 as u16;
            outv[t] = 0.0;
        } else {
            codes[t] = OUTLIER_CODE;
            outv[t] = cur[t];
        }
    }
}

/// 1D row: pred = W (west) — `west` is `cur` shifted one left in the halo.
#[inline(always)]
fn row_1d<const W: usize>(cur: &[f32], west: &[f32], radius_f: f32, codes: &mut [u16], outv: &mut [f32]) {
    let n = cur.len();
    let mut j = 0;
    while j + W <= n {
        let mut pred = [0.0f32; W];
        for t in 0..W {
            pred[t] = west[j + t];
        }
        emit_lane::<W>(&cur[j..j + W], &pred, radius_f, &mut codes[j..j + W], &mut outv[j..j + W]);
        j += W;
    }
    emit_tail(&cur[j..], |t| west[j + t], radius_f, &mut codes[j..], &mut outv[j..]);
}

/// 2D row: pred = W + N − NW.
#[inline(always)]
fn row_2d<const W: usize>(
    cur: &[f32],
    west: &[f32],
    north: &[f32],
    northwest: &[f32],
    radius_f: f32,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    let n = cur.len();
    let mut j = 0;
    while j + W <= n {
        let mut pred = [0.0f32; W];
        for t in 0..W {
            pred[t] = west[j + t] + north[j + t] - northwest[j + t];
        }
        emit_lane::<W>(&cur[j..j + W], &pred, radius_f, &mut codes[j..j + W], &mut outv[j..j + W]);
        j += W;
    }
    emit_tail(
        &cur[j..],
        |t| west[j + t] + north[j + t] - northwest[j + t],
        radius_f,
        &mut codes[j..],
        &mut outv[j..],
    );
}

/// 3D row: pred = (W+N+U) − (NW+NU+WU) + NWU.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn row_3d<const W: usize>(
    cur: &[f32],
    west: &[f32],
    north: &[f32],
    northwest: &[f32],
    up: &[f32],
    west_up: &[f32],
    north_up: &[f32],
    northwest_up: &[f32],
    radius_f: f32,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    let n = cur.len();
    let mut j = 0;
    while j + W <= n {
        let mut pred = [0.0f32; W];
        for t in 0..W {
            pred[t] = (west[j + t] + north[j + t] + up[j + t])
                - (northwest[j + t] + north_up[j + t] + west_up[j + t])
                + northwest_up[j + t];
        }
        emit_lane::<W>(&cur[j..j + W], &pred, radius_f, &mut codes[j..j + W], &mut outv[j..j + W]);
        j += W;
    }
    emit_tail(
        &cur[j..],
        |t| {
            (west[j + t] + north[j + t] + up[j + t])
                - (northwest[j + t] + north_up[j + t] + west_up[j + t])
                + northwest_up[j + t]
        },
        radius_f,
        &mut codes[j..],
        &mut outv[j..],
    );
}

fn run_w<const W: usize>(
    cfg: &DqConfig,
    blocks: &[f32],
    block_base: usize,
    pads: &PadScalars,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    let shape = cfg.shape;
    let elems = shape.elems();
    let bs = shape.bs;
    let side = shape.halo_side();
    let nb = check_batch(shape, blocks, codes, outv);
    let radius_f = cfg.radius as f32;
    let mut halo = HaloBlock::new(shape);

    for b in 0..nb {
        let block = &blocks[b * elems..(b + 1) * elems];
        prep_halo_dq(&mut halo, block, cfg, pads, block_base + b);
        let buf = &halo.buf;
        let ccodes = &mut codes[b * elems..(b + 1) * elems];
        let coutv = &mut outv[b * elems..(b + 1) * elems];

        match shape.ndim {
            1 => {
                row_1d::<W>(&buf[1..=bs], &buf[0..bs], radius_f, ccodes, coutv);
            }
            2 => {
                for i in 0..bs {
                    let r = (i + 1) * side;
                    let p = i * side;
                    // split borrows: rows of the same halo buffer
                    let (cur, west) = (&buf[r + 1..r + 1 + bs], &buf[r..r + bs]);
                    let (north, northwest) = (&buf[p + 1..p + 1 + bs], &buf[p..p + bs]);
                    row_2d::<W>(
                        cur,
                        west,
                        north,
                        northwest,
                        radius_f,
                        &mut ccodes[i * bs..(i + 1) * bs],
                        &mut coutv[i * bs..(i + 1) * bs],
                    );
                }
            }
            3 => {
                let plane = side * side;
                for k in 0..bs {
                    for i in 0..bs {
                        let r = (k + 1) * plane + (i + 1) * side; // current row
                        let rn = (k + 1) * plane + i * side; // north row
                        let ru = k * plane + (i + 1) * side; // up row
                        let rnu = k * plane + i * side; // north-up row
                        let l = (k * bs + i) * bs;
                        row_3d::<W>(
                            &buf[r + 1..r + 1 + bs],
                            &buf[r..r + bs],
                            &buf[rn + 1..rn + 1 + bs],
                            &buf[rn..rn + bs],
                            &buf[ru + 1..ru + 1 + bs],
                            &buf[ru..ru + bs],
                            &buf[rnu + 1..rnu + 1 + bs],
                            &buf[rnu..rnu + bs],
                            radius_f,
                            &mut ccodes[l..l + bs],
                            &mut coutv[l..l + bs],
                        );
                    }
                }
            }
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};

    // Cross-backend equivalence (the strongest test) lives in quant::tests;
    // here: width-specific edge cases.

    fn zero_pads(ndim: usize) -> PadScalars {
        PadScalars {
            policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
            scalars: vec![0.0],
            ndim,
        }
    }

    #[test]
    fn width_larger_than_block_uses_tail_path() {
        // bs=4 with W=16: whole row is remainder; must still be correct
        let shape = BlockShape::new(2, 4);
        let cfg = DqConfig::new(0.5, 512, shape);
        let blocks: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let mut c16 = vec![0u16; 16];
        let mut v16 = vec![0.0f32; 16];
        VecBackend::new(16).run(&cfg, &blocks, 0, &zero_pads(2), &mut c16, &mut v16);
        let mut c4 = vec![0u16; 16];
        let mut v4 = vec![0.0f32; 16];
        VecBackend::new(4).run(&cfg, &blocks, 0, &zero_pads(2), &mut c4, &mut v4);
        assert_eq!(c16, c4);
        assert_eq!(v16, v4);
    }

    #[test]
    fn branchless_select_handles_exact_radius_boundary() {
        // delta == radius must be an outlier (strict <), delta == radius-1 in-cap
        let shape = BlockShape::new(1, 4);
        let cfg = DqConfig::new(0.5, 8, shape);
        // dq = [8, 7, 0, 0] with pad 0: deltas [8, -1, -7, 0]
        let blocks = vec![8.0f32, 7.0, 0.0, 0.0];
        let mut codes = vec![0u16; 4];
        let mut outv = vec![0.0f32; 4];
        VecBackend::new(4).run(&cfg, &blocks, 0, &zero_pads(1), &mut codes, &mut outv);
        assert_eq!(codes[0], OUTLIER_CODE, "delta == radius is an outlier");
        assert_eq!(outv[0], 8.0);
        assert_eq!(codes[1], 7); // -1 + 8
        assert_eq!(codes[2], 1); // -7 + 8
        assert_eq!(codes[3], 8); // 0 + 8
    }

    #[test]
    fn negative_out_of_cap_is_outlier() {
        let shape = BlockShape::new(1, 2);
        let cfg = DqConfig::new(0.5, 8, shape);
        let blocks = vec![-20.0f32, -20.0];
        let mut codes = vec![0u16; 2];
        let mut outv = vec![0.0f32; 2];
        VecBackend::new(8).run(&cfg, &blocks, 0, &zero_pads(1), &mut codes, &mut outv);
        assert_eq!(codes[0], OUTLIER_CODE);
        assert_eq!(outv[0], -20.0);
        assert_eq!(codes[1], 8); // delta 0 after outlier (pred uses dq, not recon)
    }
}

//! vecSZ — the lane-chunked, branchless dual-quantization backend.
//!
//! The paper's contribution (§III-C): with the RAW dependence removed by
//! dual-quantization, the post-quantization loop is data-parallel. The
//! inner row loops are written as fixed-width lane chunks with a branchless
//! outlier select, which LLVM lowers to packed SIMD (ymm for W=8, zmm for
//! W=16 under `target-cpu=native`) — the analog of the paper's hand-written
//! AVX2/AVX-512 intrinsics, kept ISA-portable exactly the way §III-C argues
//! for.
//!
//! This is the *halo-free* formulation (the §Perf iteration, +20-60% over
//! the original halo-copy path, which has since been removed): instead of
//! copying every block into a `(bs+1)^d` halo buffer, the kernel works
//! directly on a pre-quantized scratch block and *hoists* the border cases
//! to row level (the paper's §III-C: boundary checks at vector-register
//! granularity, not element granularity).
//!
//! Bit-exactness: border neighbours read the same padding scalars the halo
//! planes would hold (replicating the halo fill precedence — later axes
//! overwrite shared cells) and every prediction keeps `predict_halo`'s
//! operation order `(w+n+u)-(nw+nu+wu)+nwu`, so no f32 re-association can
//! diverge from `psz`. Enforced by the equivalence tests below (including
//! edge granularity) and the cross-backend tests in `quant::tests`.

use super::{check_batch, prequant, CodesKind, DqConfig, PqBackend, OUTLIER_CODE};
use crate::padding::PadScalars;

/// Lane-chunked dual-quant backend; `width` ∈ {4, 8, 16} is the paper's
/// "vector length" knob (8 ≈ 256-bit, 16 ≈ 512-bit registers over f32).
#[derive(Clone, Copy, Debug)]
pub struct VecBackend {
    pub width: usize,
}

impl VecBackend {
    pub fn new(width: usize) -> Self {
        assert!(matches!(width, 4 | 8 | 16), "supported lane widths: 4, 8, 16");
        Self { width }
    }
}

impl PqBackend for VecBackend {
    fn name(&self) -> String {
        format!("vec{}", self.width)
    }

    fn kind(&self) -> CodesKind {
        CodesKind::DualQuant
    }

    fn lanes(&self) -> usize {
        self.width
    }

    fn run(
        &self,
        cfg: &DqConfig,
        blocks: &[f32],
        block_base: usize,
        pads: &PadScalars,
        codes: &mut [u16],
        outv: &mut [f32],
    ) {
        match self.width {
            4 => run_w::<4>(cfg, blocks, block_base, pads, codes, outv),
            8 => run_w::<8>(cfg, blocks, block_base, pads, codes, outv),
            16 => run_w::<16>(cfg, blocks, block_base, pads, codes, outv),
            _ => unreachable!(),
        }
    }
}

/// Branch form of the outlier split for single border elements.
#[inline(always)]
fn emit1(dq: f32, pred: f32, radius_f: f32, code: &mut u16, ov: &mut f32) {
    let delta = dq - pred;
    if delta.abs() < radius_f {
        *code = (delta + radius_f) as i32 as u16;
        *ov = 0.0;
    } else {
        *code = OUTLIER_CODE;
        *ov = dq;
    }
}

/// Lane loop over `cur[1..]` with a per-j prediction expression.
macro_rules! lane_loop {
    ($W:expr, $cur:expr, $codes:expr, $outv:expr, $radius_f:expr, |$j:ident| $pred:expr) => {{
        let n = $cur.len();
        let mut j = 1usize;
        while j + $W <= n {
            // fixed-width chunk: LLVM lowers to packed SIMD
            for t in 0..$W {
                let $j = j + t;
                let dqv = $cur[$j];
                let delta = dqv - $pred;
                let ic = (delta.abs() < $radius_f) as u32 as f32;
                $codes[$j] = (ic * (delta + $radius_f)) as i32 as u16;
                $outv[$j] = (1.0 - ic) * dqv;
            }
            j += $W;
        }
        while j < n {
            let $j = j;
            emit1($cur[$j], $pred, $radius_f, &mut $codes[$j], &mut $outv[$j]);
            j += 1;
        }
    }};
}

fn run_w<const W: usize>(
    cfg: &DqConfig,
    blocks: &[f32],
    block_base: usize,
    pads: &PadScalars,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    let shape = cfg.shape;
    let elems = shape.elems();
    let bs = shape.bs;
    let nb = check_batch(shape, blocks, codes, outv);
    let radius_f = cfg.radius as f32;
    let hie = cfg.half_inv_eb();
    let mut dq = vec![0.0f32; elems];

    for b in 0..nb {
        let block = &blocks[b * elems..(b + 1) * elems];
        // pre-quantization pass (vectorizable elementwise)
        for (d, &x) in dq.iter_mut().zip(block) {
            *d = prequant(x, hie);
        }
        let gb = block_base + b;
        let ccodes = &mut codes[b * elems..(b + 1) * elems];
        let coutv = &mut outv[b * elems..(b + 1) * elems];

        match shape.ndim {
            1 => {
                let p0 = prequant(pads.edge_scalar(gb, 0), hie);
                emit1(dq[0], p0, radius_f, &mut ccodes[0], &mut coutv[0]);
                let cur = &dq[..];
                lane_loop!(W, cur, ccodes, coutv, radius_f, |j| cur[j - 1]);
            }
            2 => {
                // halo precedence: axis-1 planes overwrite shared cells,
                // so row-0 body cells hold p0, the column (incl. corner) p1.
                let p0 = prequant(pads.edge_scalar(gb, 0), hie);
                let p1 = prequant(pads.edge_scalar(gb, 1), hie);
                for i in 0..bs {
                    let row = i * bs;
                    let (before, cur_on) = dq.split_at(row);
                    let cur = &cur_on[..bs];
                    let c = &mut ccodes[row..row + bs];
                    let v = &mut coutv[row..row + bs];
                    if i == 0 {
                        // (0,0): w=p1 n=p0 nw=p1 ; (0,j): n=nw=p0
                        emit1(cur[0], p1 + p0 - p1, radius_f, &mut c[0], &mut v[0]);
                        lane_loop!(W, cur, c, v, radius_f, |j| cur[j - 1] + p0 - p0);
                    } else {
                        let north = &before[row - bs..];
                        // (i,0): w=nw=p1
                        emit1(cur[0], p1 + north[0] - p1, radius_f, &mut c[0], &mut v[0]);
                        lane_loop!(W, cur, c, v, radius_f, |j| cur[j - 1] + north[j]
                            - north[j - 1]);
                    }
                }
            }
            3 => {
                // halo precedence (fill order axis0 -> axis1 -> axis2):
                //   cell with j-coord 0            -> p2
                //   else cell with i-coord 0       -> p1
                //   else cell with k-coord 0       -> p0
                let p0 = prequant(pads.edge_scalar(gb, 0), hie);
                let p1 = prequant(pads.edge_scalar(gb, 1), hie);
                let p2 = prequant(pads.edge_scalar(gb, 2), hie);
                let plane = bs * bs;
                for k in 0..bs {
                    for i in 0..bs {
                        let row = k * plane + i * bs;
                        let (before, cur_on) = dq.split_at(row);
                        let cur = &cur_on[..bs];
                        let c = &mut ccodes[row..row + bs];
                        let v = &mut coutv[row..row + bs];
                        // predict_halo order: (w+n+u)-(nw+nu+wu)+nwu
                        match (k > 0, i > 0) {
                            (true, true) => {
                                let north = &before[row - bs..row - bs + bs];
                                let up = &before[row - plane..row - plane + bs];
                                let nu = &before[row - plane - bs..row - plane - bs + bs];
                                // j=0: w=nw=wu=nwu=p2
                                emit1(
                                    cur[0],
                                    (p2 + north[0] + up[0]) - (p2 + nu[0] + p2) + p2,
                                    radius_f,
                                    &mut c[0],
                                    &mut v[0],
                                );
                                lane_loop!(W, cur, c, v, radius_f, |j| (cur[j - 1]
                                    + north[j]
                                    + up[j])
                                    - (north[j - 1] + nu[j] + up[j - 1])
                                    + nu[j - 1]);
                            }
                            (true, false) => {
                                // i == 0: n,nw,nu,nwu live in the i=0 halo
                                let up = &before[row - plane..row - plane + bs];
                                // j=0: w=p2 n=p1 nw=p2 nu=p1 wu=p2 nwu=p2
                                emit1(
                                    cur[0],
                                    (p2 + p1 + up[0]) - (p2 + p1 + p2) + p2,
                                    radius_f,
                                    &mut c[0],
                                    &mut v[0],
                                );
                                // j>=1: n=nw=nu=nwu=p1
                                lane_loop!(W, cur, c, v, radius_f, |j| (cur[j - 1] + p1 + up[j])
                                    - (p1 + p1 + up[j - 1])
                                    + p1);
                            }
                            (false, true) => {
                                // k == 0: u,wu,nu,nwu live in the k=0 halo
                                let north = &before[row - bs..row - bs + bs];
                                // j=0: w=p2 nw=p2 u=p0 nu=p0 wu=p2 nwu=p2
                                emit1(
                                    cur[0],
                                    (p2 + north[0] + p0) - (p2 + p0 + p2) + p2,
                                    radius_f,
                                    &mut c[0],
                                    &mut v[0],
                                );
                                // j>=1: u=wu=nu=nwu=p0
                                lane_loop!(W, cur, c, v, radius_f, |j| (cur[j - 1]
                                    + north[j]
                                    + p0)
                                    - (north[j - 1] + p0 + p0)
                                    + p0);
                            }
                            (false, false) => {
                                // k == i == 0
                                // j=0: w=p2 n=p1 u=p0 nw=p2 nu=p1 wu=p2 nwu=p2
                                emit1(
                                    cur[0],
                                    (p2 + p1 + p0) - (p2 + p1 + p2) + p2,
                                    radius_f,
                                    &mut c[0],
                                    &mut v[0],
                                );
                                // j>=1: n=nw=p1... careful: n = halo(1,0,j+1)
                                // -> i-coord 0 -> p1; nw same -> p1;
                                // u = halo(0,1,j+1) -> k-coord 0 -> p0; wu -> p0;
                                // nu = halo(0,0,j+1) -> i-coord 0 -> p1; nwu -> p1
                                lane_loop!(W, cur, c, v, radius_f, |j| (cur[j - 1] + p1 + p0)
                                    - (p1 + p1 + p0)
                                    + p1);
                            }
                        }
                    }
                }
            }
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::padding::{PadGranularity, PadScalars, PadValue, PaddingPolicy};
    use crate::quant::psz::PszBackend;
    use crate::quant::test_support::random_batch;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;

    // Cross-backend equivalence over random batches also lives in
    // quant::tests; here: the full psz/vec bit-exactness matrix (all dims,
    // odd block sizes, edge-granularity scalars) plus width edge cases.

    fn zero_pads(ndim: usize) -> PadScalars {
        PadScalars {
            policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
            scalars: vec![0.0],
            ndim,
        }
    }

    #[test]
    fn matches_psz_bit_exact_all_dims() {
        let mut rng = Pcg32::seeded(77);
        for &(ndim, bs) in &[(1usize, 64usize), (1, 7), (2, 8), (2, 16), (2, 5), (3, 8), (3, 4)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-3, 512, shape);
            for smooth in [true, false] {
                let (blocks, pads) = random_batch(&mut rng, shape, 5, 4.0, smooth);
                let mut c0 = vec![0u16; blocks.len()];
                let mut v0 = vec![0.0f32; blocks.len()];
                PszBackend.run(&cfg, &blocks, 0, &pads, &mut c0, &mut v0);
                for w in [4usize, 8, 16] {
                    let mut c1 = vec![0u16; blocks.len()];
                    let mut v1 = vec![0.0f32; blocks.len()];
                    VecBackend::new(w).run(&cfg, &blocks, 0, &pads, &mut c1, &mut v1);
                    assert_eq!(c0, c1, "codes: ndim={ndim} bs={bs} w={w} smooth={smooth}");
                    assert_eq!(v0, v1, "outv: ndim={ndim} bs={bs} w={w}");
                }
            }
        }
    }

    #[test]
    fn matches_psz_with_distinct_edge_scalars() {
        // per-axis edge scalars of very different magnitudes stress the
        // f32-order-of-operations equivalence (no collapsed shortcuts!)
        let mut rng = Pcg32::seeded(99);
        for &(ndim, bs) in &[(1usize, 9usize), (2, 8), (3, 6)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-2, 512, shape);
            let (blocks, _) = random_batch(&mut rng, shape, 4, 2.0, true);
            let nb = 4;
            let scalars: Vec<f32> = (0..nb * ndim)
                .map(|q| [1000.0f32, -0.37, 12.5][q % 3] * (1.0 + q as f32))
                .collect();
            let pads = PadScalars {
                policy: PaddingPolicy::new(PadValue::Avg, PadGranularity::Edge),
                scalars,
                ndim,
            };
            let mut c0 = vec![0u16; blocks.len()];
            let mut v0 = vec![0.0f32; blocks.len()];
            PszBackend.run(&cfg, &blocks, 0, &pads, &mut c0, &mut v0);
            for w in [8usize, 16] {
                let mut c1 = vec![0u16; blocks.len()];
                let mut v1 = vec![0.0f32; blocks.len()];
                VecBackend::new(w).run(&cfg, &blocks, 0, &pads, &mut c1, &mut v1);
                assert_eq!(c0, c1, "edge-pad codes: ndim={ndim} bs={bs} w={w}");
                assert_eq!(v0, v1, "edge-pad outv: ndim={ndim} bs={bs} w={w}");
            }
        }
    }

    #[test]
    fn prop_psz_equivalence() {
        check("vec-equivalence", 50, |g| {
            let ndim = 1 + g.rng.bounded(3) as usize;
            let bs = *g.choose(&[3usize, 4, 8, 12]);
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(*g.choose(&[1e-2f64, 1e-3]), 512, shape);
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let (blocks, pads) = random_batch(&mut rng, shape, 3, 6.0, g.rng.next_f32() < 0.5);
            let mut c0 = vec![0u16; blocks.len()];
            let mut v0 = vec![0.0f32; blocks.len()];
            PszBackend.run(&cfg, &blocks, 0, &pads, &mut c0, &mut v0);
            let w = *g.choose(&[4usize, 8, 16]);
            let mut c1 = vec![0u16; blocks.len()];
            let mut v1 = vec![0.0f32; blocks.len()];
            VecBackend::new(w).run(&cfg, &blocks, 0, &pads, &mut c1, &mut v1);
            if c0 == c1 && v0 == v1 {
                Ok(())
            } else {
                Err(format!("diverged ndim={ndim} bs={bs} w={w}"))
            }
        });
    }

    #[test]
    fn width_larger_than_block_uses_tail_path() {
        // bs=4 with W=16: whole row is remainder; must still be correct
        let shape = BlockShape::new(2, 4);
        let cfg = DqConfig::new(0.5, 512, shape);
        let blocks: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let mut c16 = vec![0u16; 16];
        let mut v16 = vec![0.0f32; 16];
        VecBackend::new(16).run(&cfg, &blocks, 0, &zero_pads(2), &mut c16, &mut v16);
        let mut c4 = vec![0u16; 16];
        let mut v4 = vec![0.0f32; 16];
        VecBackend::new(4).run(&cfg, &blocks, 0, &zero_pads(2), &mut c4, &mut v4);
        assert_eq!(c16, c4);
        assert_eq!(v16, v4);
    }

    #[test]
    fn branchless_select_handles_exact_radius_boundary() {
        // delta == radius must be an outlier (strict <), delta == radius-1 in-cap
        let shape = BlockShape::new(1, 4);
        let cfg = DqConfig::new(0.5, 8, shape);
        // dq = [8, 7, 0, 0] with pad 0: deltas [8, -1, -7, 0]
        let blocks = vec![8.0f32, 7.0, 0.0, 0.0];
        let mut codes = vec![0u16; 4];
        let mut outv = vec![0.0f32; 4];
        VecBackend::new(4).run(&cfg, &blocks, 0, &zero_pads(1), &mut codes, &mut outv);
        assert_eq!(codes[0], OUTLIER_CODE, "delta == radius is an outlier");
        assert_eq!(outv[0], 8.0);
        assert_eq!(codes[1], 7); // -1 + 8
        assert_eq!(codes[2], 1); // -7 + 8
        assert_eq!(codes[3], 8); // 0 + 8
    }

    #[test]
    fn negative_out_of_cap_is_outlier() {
        let shape = BlockShape::new(1, 2);
        let cfg = DqConfig::new(0.5, 8, shape);
        let blocks = vec![-20.0f32, -20.0];
        let mut codes = vec![0u16; 2];
        let mut outv = vec![0.0f32; 2];
        VecBackend::new(8).run(&cfg, &blocks, 0, &zero_pads(1), &mut codes, &mut outv);
        assert_eq!(codes[0], OUTLIER_CODE);
        assert_eq!(outv[0], -20.0);
        assert_eq!(codes[1], 8); // delta 0 after outlier (pred uses dq, not recon)
    }
}

//! simdSZ — the explicit-intrinsics dual-quantization backend.
//!
//! [`SimdBackend`] is the hand-written counterpart of
//! [`super::vectorized::VecBackend`]: the same branchless dual-quant math,
//! but executed through
//! the `core::arch` lane layer in [`crate::simd`] with runtime ISA dispatch
//! (AVX2 / AVX-512F / NEON / scalar) instead of hoping LLVM autovectorizes
//! a lane-chunked loop — and with the per-block **prequant pass fused**
//! into the predict/quantize loop, so every element is pre-quantized once,
//! in-register, as it streams through (see `simd::kernel`).
//!
//! Output is bit-identical to `PszBackend` and `VecBackend` on every ISA:
//! the kernel keeps `predict_halo`'s operation order
//! `(w+n+u)-(nw+nu+wu)+nwu` and every lane op has scalar-identical IEEE
//! semantics. The matrix below enforces this across every ISA reachable on
//! the test host (forced per-instance via [`SimdBackend::with_isa`]).
//!
//! ISA selection: [`SimdBackend::new`] snapshots [`Isa::active`] — the
//! detected best unless overridden by `VECSZ_FORCE_ISA` / `--isa` /
//! [`crate::simd::force_isa`].

use super::{CodesKind, DqConfig, PqBackend};
use crate::padding::PadScalars;
use crate::simd::{run_fused, Isa};

/// Explicit-intrinsics dual-quant backend; `width` ∈ {4, 8, 16} is the
/// paper's vector-length knob (the lane-chunk the row loop advances by —
/// chunks wider than the ISA register run as unrolled vector pairs).
#[derive(Clone, Copy, Debug)]
pub struct SimdBackend {
    pub width: usize,
    isa: Isa,
}

impl SimdBackend {
    /// Backend on the active (detected or forced) ISA.
    pub fn new(width: usize) -> Self {
        Self::with_isa(width, Isa::active())
    }

    /// Backend pinned to `isa` (test/bench hook). An ISA the host cannot
    /// run is clamped to the detected best, so construction never yields
    /// an inexecutable kernel.
    pub fn with_isa(width: usize, isa: Isa) -> Self {
        assert!(matches!(width, 4 | 8 | 16), "supported lane widths: 4, 8, 16");
        let isa = if isa.is_available() { isa } else { Isa::detect_best() };
        Self { width, isa }
    }

    /// The ISA this instance dispatches to.
    pub fn isa(&self) -> Isa {
        self.isa
    }
}

impl PqBackend for SimdBackend {
    fn name(&self) -> String {
        format!("simd{}", self.width)
    }

    fn kind(&self) -> CodesKind {
        CodesKind::DualQuant
    }

    fn lanes(&self) -> usize {
        self.width
    }

    fn run(
        &self,
        cfg: &DqConfig,
        blocks: &[f32],
        block_base: usize,
        pads: &PadScalars,
        codes: &mut [u16],
        outv: &mut [f32],
    ) {
        run_fused(self.isa, self.width, cfg, blocks, block_base, pads, codes, outv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};
    use crate::quant::psz::PszBackend;
    use crate::quant::test_support::random_batch;
    use crate::quant::vectorized::VecBackend;
    use crate::quant::OUTLIER_CODE;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;

    fn zero_pads(ndim: usize) -> PadScalars {
        PadScalars {
            policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
            scalars: vec![0.0],
            ndim,
        }
    }

    fn run(
        be: &dyn PqBackend,
        cfg: &DqConfig,
        blocks: &[f32],
        pads: &PadScalars,
    ) -> (Vec<u16>, Vec<f32>) {
        let mut codes = vec![0u16; blocks.len()];
        let mut outv = vec![0.0f32; blocks.len()];
        be.run(cfg, blocks, 0, pads, &mut codes, &mut outv);
        (codes, outv)
    }

    /// The acceptance matrix: SimdBackend == PszBackend == VecBackend,
    /// bit for bit, across all dims, odd block sizes and edge-granularity
    /// pads, on **every ISA reachable on this host** including the forced
    /// scalar fallback.
    #[test]
    fn matrix_matches_psz_and_vec_on_every_isa() {
        let mut rng = Pcg32::seeded(2024);
        for &(ndim, bs) in &[(1usize, 64usize), (1, 7), (2, 8), (2, 16), (2, 5), (3, 8), (3, 4)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-3, 512, shape);
            for smooth in [true, false] {
                let (blocks, pads) = random_batch(&mut rng, shape, 5, 4.0, smooth);
                let (c0, v0) = run(&PszBackend, &cfg, &blocks, &pads);
                for w in [4usize, 8, 16] {
                    let (cv, vv) = run(&VecBackend::new(w), &cfg, &blocks, &pads);
                    assert_eq!(c0, cv, "vec{w} baseline ndim={ndim} bs={bs}");
                    for isa in Isa::available() {
                        let be = SimdBackend::with_isa(w, isa);
                        let (cs, vs) = run(&be, &cfg, &blocks, &pads);
                        let tag = format!(
                            "simd{w}/{} ndim={ndim} bs={bs} smooth={smooth}",
                            isa.name()
                        );
                        assert_eq!(c0, cs, "codes {tag}");
                        assert_eq!(v0, vs, "outv {tag}");
                        assert_eq!(vv, vs, "outv vs vec {tag}");
                    }
                }
            }
        }
    }

    #[test]
    fn matrix_edge_granularity_scalars_every_isa() {
        // per-axis edge scalars of very different magnitudes stress the
        // f32 op-order equivalence through the broadcast-row substitution
        let mut rng = Pcg32::seeded(99);
        for &(ndim, bs) in &[(1usize, 9usize), (2, 8), (3, 6)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-2, 512, shape);
            let (blocks, _) = random_batch(&mut rng, shape, 4, 2.0, true);
            let scalars: Vec<f32> = (0..4 * ndim)
                .map(|q| [1000.0f32, -0.37, 12.5][q % 3] * (1.0 + q as f32))
                .collect();
            let pads = PadScalars {
                policy: PaddingPolicy::new(PadValue::Avg, PadGranularity::Edge),
                scalars,
                ndim,
            };
            let (c0, v0) = run(&PszBackend, &cfg, &blocks, &pads);
            for isa in Isa::available() {
                for w in [8usize, 16] {
                    let (c1, v1) = run(&SimdBackend::with_isa(w, isa), &cfg, &blocks, &pads);
                    assert_eq!(c0, c1, "edge codes ndim={ndim} w={w} isa={}", isa.name());
                    assert_eq!(v0, v1, "edge outv ndim={ndim} w={w} isa={}", isa.name());
                }
            }
        }
    }

    #[test]
    fn prop_equivalence_random_shapes_and_isas() {
        // randomized shapes AND a randomized ISA choice per case
        check("simd-psz-equivalence", 60, |g| {
            let ndim = 1 + g.rng.bounded(3) as usize;
            let bs = *g.choose(&[3usize, 4, 5, 8, 12, 16]);
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(*g.choose(&[1e-2f64, 1e-3, 1e-4]), 512, shape);
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let (blocks, pads) = random_batch(&mut rng, shape, 3, 6.0, g.rng.next_f32() < 0.5);
            let (c0, v0) = run(&PszBackend, &cfg, &blocks, &pads);
            let avail = Isa::available();
            let isa = avail[g.rng.bounded(avail.len() as u32) as usize];
            let w = *g.choose(&[4usize, 8, 16]);
            let (c1, v1) = run(&SimdBackend::with_isa(w, isa), &cfg, &blocks, &pads);
            if c0 == c1 && v0 == v1 {
                Ok(())
            } else {
                Err(format!("simd{w}/{} diverged ndim={ndim} bs={bs}", isa.name()))
            }
        });
    }

    #[test]
    fn exact_radius_boundary_every_isa() {
        // delta == radius must be an outlier (strict <), delta == radius-1
        // in-cap — the same acceptance case VecBackend carries
        let shape = BlockShape::new(1, 4);
        let cfg = DqConfig::new(0.5, 8, shape);
        let blocks = vec![8.0f32, 7.0, 0.0, 0.0]; // deltas [8, -1, -7, 0]
        for isa in Isa::available() {
            for w in [4usize, 8, 16] {
                let (codes, outv) =
                    run(&SimdBackend::with_isa(w, isa), &cfg, &blocks, &zero_pads(1));
                let tag = format!("w={w} isa={}", isa.name());
                assert_eq!(codes[0], OUTLIER_CODE, "delta == radius outlier {tag}");
                assert_eq!(outv[0], 8.0, "{tag}");
                assert_eq!(&codes[1..], &[7, 1, 8], "{tag}");
            }
        }
    }

    #[test]
    fn negative_out_of_cap_is_outlier_every_isa() {
        let shape = BlockShape::new(1, 2);
        let cfg = DqConfig::new(0.5, 8, shape);
        let blocks = vec![-20.0f32, -20.0];
        for isa in Isa::available() {
            let (codes, outv) = run(&SimdBackend::with_isa(8, isa), &cfg, &blocks, &zero_pads(1));
            assert_eq!(codes[0], OUTLIER_CODE, "isa {}", isa.name());
            assert_eq!(outv[0], -20.0);
            assert_eq!(codes[1], 8, "pred uses dq, not recon ({})", isa.name());
        }
    }

    #[test]
    fn forced_scalar_matches_active_isa() {
        // the two dispatch extremes the CI matrix pins: whatever the host
        // detects vs the forced scalar fallback
        let mut rng = Pcg32::seeded(5);
        let shape = BlockShape::new(2, 16);
        let cfg = DqConfig::new(1e-3, 512, shape);
        let (blocks, pads) = random_batch(&mut rng, shape, 8, 3.0, true);
        let (ca, va) = run(&SimdBackend::new(16), &cfg, &blocks, &pads);
        let (cs, vs) = run(&SimdBackend::with_isa(16, Isa::Scalar), &cfg, &blocks, &pads);
        assert_eq!(ca, cs);
        assert_eq!(va, vs);
    }

    #[test]
    fn width_larger_than_block_uses_tail_path() {
        let shape = BlockShape::new(2, 4);
        let cfg = DqConfig::new(0.5, 512, shape);
        let blocks: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let (c16, v16) = run(&SimdBackend::new(16), &cfg, &blocks, &zero_pads(2));
        let (c4, v4) = run(&VecBackend::new(4), &cfg, &blocks, &zero_pads(2));
        assert_eq!(c16, c4);
        assert_eq!(v16, v4);
    }

    #[test]
    fn backend_identity() {
        let be = SimdBackend::new(8);
        assert_eq!(be.name(), "simd8");
        assert_eq!(be.lanes(), 8);
        assert_eq!(be.kind(), CodesKind::DualQuant);
        assert!(be.isa().is_available());
    }
}

//! Decompression of quant-code streams — the sequential (cascading)
//! reverse path of both algorithms.
//!
//! Decompression keeps the RAW dependence (each element needs its already-
//! reconstructed neighbours), which is why the paper vectorizes compression
//! only (§III-A). Blocks are still independent, so the coordinator
//! parallelizes *across* blocks.

use super::{CodesKind, DqConfig, OUTLIER_CODE};
use crate::blocks::HaloBlock;
use crate::lorenzo::{for_each_coord, predict_halo};
use crate::padding::PadScalars;

/// Reconstruct one block from its code/outlier streams into `out` (length
/// `bs^d`, data units).
pub fn decode_block(
    kind: CodesKind,
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    pads: &PadScalars,
    b: usize,
    halo: &mut HaloBlock,
    out: &mut [f32],
) {
    match kind {
        CodesKind::DualQuant => decode_block_dualquant(cfg, codes, outv, pads, b, halo, out),
        CodesKind::Sz14 => decode_block_sz14(cfg, codes, outv, pads, b, halo, out),
    }
}

/// Dual-quant reverse (Algorithm 2 decompress): reconstruct d° exactly by
/// the cascading Lorenzo scan in the pre-quantized domain, then scale.
pub fn decode_block_dualquant(
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    pads: &PadScalars,
    b: usize,
    halo: &mut HaloBlock,
    out: &mut [f32],
) {
    let shape = cfg.shape;
    let hie = cfg.half_inv_eb();
    let twice_eb = cfg.twice_eb();
    let radius = cfg.radius as i32;
    halo.fill_halo(|axis| super::prequant(pads.edge_scalar(b, axis), hie));
    for_each_coord(shape, |l, c| {
        let dq = if codes[l] == OUTLIER_CODE {
            outv[l]
        } else {
            let pred = predict_halo(&halo.buf, shape, c);
            pred + (codes[l] as i32 - radius) as f32
        };
        let hidx = halo.interior_index(c);
        halo.buf[hidx] = dq;
        out[l] = dq * twice_eb;
    });
}

/// SZ-1.4 reverse (Algorithm 1 decompress): cascade in data units; outliers
/// are verbatim originals.
pub fn decode_block_sz14(
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    pads: &PadScalars,
    b: usize,
    halo: &mut HaloBlock,
    out: &mut [f32],
) {
    let shape = cfg.shape;
    let twice_eb = cfg.twice_eb();
    let radius = cfg.radius as i32;
    halo.fill_halo(|axis| pads.edge_scalar(b, axis));
    for_each_coord(shape, |l, c| {
        let v = if codes[l] == OUTLIER_CODE {
            outv[l]
        } else {
            let pred = predict_halo(&halo.buf, shape, c);
            pred + (codes[l] as i32 - radius) as f32 * twice_eb
        };
        let hidx = halo.interior_index(c);
        halo.buf[hidx] = v;
        out[l] = v;
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::quant::psz::PszBackend;
    use crate::quant::sz14::Sz14Backend;
    use crate::quant::test_support::random_batch;
    use crate::quant::vectorized::VecBackend;
    use crate::quant::PqBackend;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;

    /// Max |rec - orig| over a full encode/decode roundtrip of a batch.
    fn roundtrip_max_err(be: &dyn PqBackend, cfg: &DqConfig, blocks: &[f32], pads: &PadScalars) -> f32 {
        let elems = cfg.shape.elems();
        let nb = blocks.len() / elems;
        let mut codes = vec![0u16; blocks.len()];
        let mut outv = vec![0.0f32; blocks.len()];
        be.run(cfg, blocks, 0, pads, &mut codes, &mut outv);
        let mut halo = HaloBlock::new(cfg.shape);
        let mut rec = vec![0.0f32; elems];
        let mut max_err = 0.0f32;
        for b in 0..nb {
            decode_block(
                be.kind(),
                cfg,
                &codes[b * elems..(b + 1) * elems],
                &outv[b * elems..(b + 1) * elems],
                pads,
                b,
                &mut halo,
                &mut rec,
            );
            for (r, d) in rec.iter().zip(&blocks[b * elems..(b + 1) * elems]) {
                max_err = max_err.max((r - d).abs());
            }
        }
        max_err
    }

    #[test]
    fn dualquant_roundtrip_bound_all_dims() {
        let mut rng = Pcg32::seeded(21);
        for &(ndim, bs) in &[(1usize, 64usize), (2, 16), (3, 8)] {
            for &eb in &[1e-2f64, 1e-3, 1e-4] {
                let shape = BlockShape::new(ndim, bs);
                let cfg = DqConfig::new(eb, 512, shape);
                let (blocks, pads) = random_batch(&mut rng, shape, 4, 3.0, true);
                let tol = (eb + 1e-6) as f32;
                for be in [&PszBackend as &dyn PqBackend, &VecBackend::new(8)] {
                    let err = roundtrip_max_err(be, &cfg, &blocks, &pads);
                    assert!(err <= tol, "{} ndim={ndim} bs={bs} eb={eb}: err {err}", be.name());
                }
            }
        }
    }

    #[test]
    fn sz14_roundtrip_bound() {
        let mut rng = Pcg32::seeded(22);
        for &(ndim, bs) in &[(1usize, 32usize), (2, 8), (3, 8)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-3, 512, shape);
            let (blocks, pads) = random_batch(&mut rng, shape, 3, 2.0, true);
            let err = roundtrip_max_err(&Sz14Backend, &cfg, &blocks, &pads);
            assert!(err <= 1e-3 + 1e-6, "sz14 err {err}");
        }
    }

    #[test]
    fn dualquant_reconstruction_is_exact_in_prequant_domain() {
        // decode must reproduce d° EXACTLY (integer cascade), so the only
        // error is the final scale — verify on rough data with outliers.
        let shape = BlockShape::new(2, 8);
        let cfg = DqConfig::new(1e-4, 16, shape); // small radius: many outliers
        let mut rng = Pcg32::seeded(33);
        let (blocks, pads) = random_batch(&mut rng, shape, 3, 10.0, false);
        let elems = shape.elems();
        let mut codes = vec![0u16; blocks.len()];
        let mut outv = vec![0.0f32; blocks.len()];
        PszBackend.run(&cfg, &blocks, 0, &pads, &mut codes, &mut outv);
        let mut halo = HaloBlock::new(shape);
        let mut rec = vec![0.0f32; elems];
        for b in 0..3 {
            decode_block_dualquant(
                &cfg,
                &codes[b * elems..(b + 1) * elems],
                &outv[b * elems..(b + 1) * elems],
                &pads,
                b,
                &mut halo,
                &mut rec,
            );
            for (l, r) in rec.iter().enumerate() {
                let dq_expected =
                    super::super::prequant(blocks[b * elems + l], cfg.half_inv_eb());
                assert_eq!(*r, dq_expected * cfg.twice_eb(), "block {b} elem {l}");
            }
        }
    }

    #[test]
    fn prop_roundtrip_bound_random() {
        check("decode-roundtrip-bound", 40, |g| {
            let ndim = 1 + g.rng.bounded(3) as usize;
            let bs = *g.choose(&[4usize, 8]);
            let shape = BlockShape::new(ndim, bs);
            let eb = *g.choose(&[1e-2f64, 1e-3]);
            let cfg = DqConfig::new(eb, 512, shape);
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let smooth = g.rng.next_f32() < 0.5;
            let (blocks, pads) = random_batch(&mut rng, shape, 2, 4.0, smooth);
            let tol = (eb + 1e-6) as f32;
            for be in [&PszBackend as &dyn PqBackend, &VecBackend::new(16), &Sz14Backend] {
                let err = roundtrip_max_err(be, &cfg, &blocks, &pads);
                if err > tol {
                    return Err(format!("{} err {err} > {tol}", be.name()));
                }
            }
            Ok(())
        });
    }
}

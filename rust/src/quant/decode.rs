//! Decompression of quant-code streams — the reverse (reconstruction) path
//! of both algorithms, behind a backend hierarchy mirroring the compress
//! side.
//!
//! # The decode backend hierarchy
//!
//! Reconstruction keeps a RAW dependence — each element needs its already-
//! reconstructed neighbours — which is why the paper vectorizes compression
//! only (§III-A). Two implementations share the [`DecodeBackend`] trait:
//!
//! * [`ScalarDecodeBackend`] — the cascading halo-buffer loop
//!   ([`decode_block_dualquant`] / [`decode_block_sz14`]). **The
//!   bit-exactness reference** the SIMD backend is tested against.
//! * [`SimdDecodeBackend`] — the explicit-intrinsics reverse-Lorenzo
//!   **wavefront** kernel ([`crate::simd::decode`]): in 2D/3D the cells of
//!   an anti-diagonal `i + j = d` are mutually independent (their
//!   neighbours live on diagonals `d-1`/`d-2`), so `W` lanes reconstruct
//!   `W` wavefront cells at once over a skewed per-diagonal layout; 3D
//!   sweeps plane by plane against the fully reconstructed up-plane. 1D is
//!   a true west prefix dependency and falls back to the scalar cascade on
//!   every ISA.
//!
//! # ISA dispatch & the bit-exactness guarantee
//!
//! [`SimdDecodeBackend::new`] snapshots [`crate::simd::Isa::active`] — so
//! `VECSZ_FORCE_ISA`, the CLI `--isa` flag and [`crate::simd::force_isa`]
//! govern decode exactly as they govern compress — and
//! [`default_decode_backend`] is what `compressor::decode_body` (and
//! through it every container/stream decode path) dispatches on: the
//! wavefront kernel on the active SIMD ISA, the scalar reference when the
//! dispatch resolves to scalar.
//!
//! Every backend produces **bit-identical** output on every ISA: the
//! wavefront keeps the reference's exact f32 sequence per cell (halo-fill
//! precedence, `predict_halo`'s `(w+n+u)-(nw+nu+wu)+nwu` order, the
//! `(code as i32 - radius) as f32` delta, the final `dq * twice_eb`
//! scale), and outlier substitution is mask+select on `codes ==
//! OUTLIER_CODE`. The matrix in this module's tests enforces equality
//! against the scalar reference across dims × odd block sizes × every
//! host-reachable ISA, on encoder output and on adversarial raw streams.

use super::{CodesKind, DqConfig, OUTLIER_CODE};
use crate::blocks::HaloBlock;
use crate::lorenzo::{for_each_coord, predict_halo};
use crate::padding::PadScalars;
use crate::simd::{run_reverse, Isa};

/// Reconstruct one block from its code/outlier streams into `out` (length
/// `bs^d`, data units) — the scalar reference path.
pub fn decode_block(
    kind: CodesKind,
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    pads: &PadScalars,
    b: usize,
    halo: &mut HaloBlock,
    out: &mut [f32],
) {
    match kind {
        CodesKind::DualQuant => decode_block_dualquant(cfg, codes, outv, pads, b, halo, out),
        CodesKind::Sz14 => decode_block_sz14(cfg, codes, outv, pads, b, halo, out),
    }
}

/// Dual-quant reverse (Algorithm 2 decompress): reconstruct d° exactly by
/// the cascading Lorenzo scan in the pre-quantized domain, then scale.
pub fn decode_block_dualquant(
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    pads: &PadScalars,
    b: usize,
    halo: &mut HaloBlock,
    out: &mut [f32],
) {
    let shape = cfg.shape;
    let hie = cfg.half_inv_eb();
    let twice_eb = cfg.twice_eb();
    let radius = cfg.radius as i32;
    halo.fill_halo(|axis| super::prequant(pads.edge_scalar(b, axis), hie));
    for_each_coord(shape, |l, c| {
        let dq = if codes[l] == OUTLIER_CODE {
            outv[l]
        } else {
            let pred = predict_halo(&halo.buf, shape, c);
            pred + (codes[l] as i32 - radius) as f32
        };
        let hidx = halo.interior_index(c);
        halo.buf[hidx] = dq;
        out[l] = dq * twice_eb;
    });
}

/// SZ-1.4 reverse (Algorithm 1 decompress): cascade in data units; outliers
/// are verbatim originals.
pub fn decode_block_sz14(
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    pads: &PadScalars,
    b: usize,
    halo: &mut HaloBlock,
    out: &mut [f32],
) {
    let shape = cfg.shape;
    let twice_eb = cfg.twice_eb();
    let radius = cfg.radius as i32;
    halo.fill_halo(|axis| pads.edge_scalar(b, axis));
    for_each_coord(shape, |l, c| {
        let v = if codes[l] == OUTLIER_CODE {
            outv[l]
        } else {
            let pred = predict_halo(&halo.buf, shape, c);
            pred + (codes[l] as i32 - radius) as f32 * twice_eb
        };
        let hidx = halo.interior_index(c);
        halo.buf[hidx] = v;
        out[l] = v;
    });
}

/// Block-reconstruction backend — the decode-side mirror of
/// [`super::PqBackend`].
///
/// `codes`/`outv` hold `nb = codes.len() / shape.elems()` blocks
/// back-to-back (the P&Q output layout); `out` receives the reconstructed
/// data-unit values in the same layout; `block_base` is the global index of
/// the first block (padding scalars are indexed globally). Every
/// implementation must be bit-identical to [`ScalarDecodeBackend`].
pub trait DecodeBackend: Send + Sync {
    fn name(&self) -> String;
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &self,
        kind: CodesKind,
        cfg: &DqConfig,
        codes: &[u16],
        outv: &[f32],
        block_base: usize,
        pads: &PadScalars,
        out: &mut [f32],
    );
}

/// The cascading halo-buffer reference decoder.
pub struct ScalarDecodeBackend;

impl DecodeBackend for ScalarDecodeBackend {
    fn name(&self) -> String {
        "scalar-ref".into()
    }

    fn decode(
        &self,
        kind: CodesKind,
        cfg: &DqConfig,
        codes: &[u16],
        outv: &[f32],
        block_base: usize,
        pads: &PadScalars,
        out: &mut [f32],
    ) {
        let elems = cfg.shape.elems();
        assert_eq!(codes.len() % elems, 0, "codes not a whole number of blocks");
        let nb = codes.len() / elems;
        assert_eq!(outv.len(), nb * elems);
        assert_eq!(out.len(), nb * elems);
        let mut halo = HaloBlock::new(cfg.shape);
        for b in 0..nb {
            decode_block(
                kind,
                cfg,
                &codes[b * elems..(b + 1) * elems],
                &outv[b * elems..(b + 1) * elems],
                pads,
                block_base + b,
                &mut halo,
                &mut out[b * elems..(b + 1) * elems],
            );
        }
    }
}

/// The explicit-intrinsics wavefront decoder; `width` ∈ {4, 8, 16} gates
/// the ISA tier exactly as on the compress side (the wavefront itself
/// always steps by the native register width — decode diagonals are short,
/// so a wider unroll chunk would only grow the scalar tails).
#[derive(Clone, Copy, Debug)]
pub struct SimdDecodeBackend {
    pub width: usize,
    isa: Isa,
}

impl SimdDecodeBackend {
    /// Backend on the active (detected or forced) ISA.
    pub fn new(width: usize) -> Self {
        Self::with_isa(width, Isa::active())
    }

    /// Backend pinned to `isa` (test/bench hook). An ISA the host cannot
    /// run is clamped to the detected best, so construction never yields
    /// an inexecutable kernel.
    pub fn with_isa(width: usize, isa: Isa) -> Self {
        assert!(matches!(width, 4 | 8 | 16), "supported lane widths: 4, 8, 16");
        let isa = if isa.is_available() { isa } else { Isa::detect_best() };
        Self { width, isa }
    }

    /// The ISA this instance dispatches to.
    pub fn isa(&self) -> Isa {
        self.isa
    }
}

impl DecodeBackend for SimdDecodeBackend {
    fn name(&self) -> String {
        format!("simd{}/{}", self.width, self.isa.name())
    }

    fn decode(
        &self,
        kind: CodesKind,
        cfg: &DqConfig,
        codes: &[u16],
        outv: &[f32],
        block_base: usize,
        pads: &PadScalars,
        out: &mut [f32],
    ) {
        run_reverse(self.isa, self.width, kind, cfg, codes, outv, block_base, pads, out);
    }
}

/// The decoder the container/stream decode paths dispatch to: the
/// wavefront kernel on the active ISA, or the scalar reference when
/// dispatch resolves to scalar (so `VECSZ_FORCE_ISA=scalar` and `--isa
/// scalar` exercise the reference end to end).
pub fn default_decode_backend() -> Box<dyn DecodeBackend> {
    match Isa::active() {
        Isa::Scalar => Box::new(ScalarDecodeBackend),
        isa => Box::new(SimdDecodeBackend::with_isa(16, isa)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};
    use crate::quant::psz::PszBackend;
    use crate::quant::sz14::Sz14Backend;
    use crate::quant::test_support::random_batch;
    use crate::quant::vectorized::VecBackend;
    use crate::quant::PqBackend;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;

    /// Max |rec - orig| over a full encode/decode roundtrip of a batch.
    fn roundtrip_max_err(be: &dyn PqBackend, cfg: &DqConfig, blocks: &[f32], pads: &PadScalars) -> f32 {
        let elems = cfg.shape.elems();
        let nb = blocks.len() / elems;
        let mut codes = vec![0u16; blocks.len()];
        let mut outv = vec![0.0f32; blocks.len()];
        be.run(cfg, blocks, 0, pads, &mut codes, &mut outv);
        let mut halo = HaloBlock::new(cfg.shape);
        let mut rec = vec![0.0f32; elems];
        let mut max_err = 0.0f32;
        for b in 0..nb {
            decode_block(
                be.kind(),
                cfg,
                &codes[b * elems..(b + 1) * elems],
                &outv[b * elems..(b + 1) * elems],
                pads,
                b,
                &mut halo,
                &mut rec,
            );
            for (r, d) in rec.iter().zip(&blocks[b * elems..(b + 1) * elems]) {
                max_err = max_err.max((r - d).abs());
            }
        }
        max_err
    }

    #[test]
    fn dualquant_roundtrip_bound_all_dims() {
        let mut rng = Pcg32::seeded(21);
        for &(ndim, bs) in &[(1usize, 64usize), (2, 16), (3, 8)] {
            for &eb in &[1e-2f64, 1e-3, 1e-4] {
                let shape = BlockShape::new(ndim, bs);
                let cfg = DqConfig::new(eb, 512, shape);
                let (blocks, pads) = random_batch(&mut rng, shape, 4, 3.0, true);
                let tol = (eb + 1e-6) as f32;
                for be in [&PszBackend as &dyn PqBackend, &VecBackend::new(8)] {
                    let err = roundtrip_max_err(be, &cfg, &blocks, &pads);
                    assert!(err <= tol, "{} ndim={ndim} bs={bs} eb={eb}: err {err}", be.name());
                }
            }
        }
    }

    #[test]
    fn sz14_roundtrip_bound() {
        let mut rng = Pcg32::seeded(22);
        for &(ndim, bs) in &[(1usize, 32usize), (2, 8), (3, 8)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-3, 512, shape);
            let (blocks, pads) = random_batch(&mut rng, shape, 3, 2.0, true);
            let err = roundtrip_max_err(&Sz14Backend, &cfg, &blocks, &pads);
            assert!(err <= 1e-3 + 1e-6, "sz14 err {err}");
        }
    }

    #[test]
    fn dualquant_reconstruction_is_exact_in_prequant_domain() {
        // decode must reproduce d° EXACTLY (integer cascade), so the only
        // error is the final scale — verify on rough data with outliers.
        let shape = BlockShape::new(2, 8);
        let cfg = DqConfig::new(1e-4, 16, shape); // small radius: many outliers
        let mut rng = Pcg32::seeded(33);
        let (blocks, pads) = random_batch(&mut rng, shape, 3, 10.0, false);
        let elems = shape.elems();
        let mut codes = vec![0u16; blocks.len()];
        let mut outv = vec![0.0f32; blocks.len()];
        PszBackend.run(&cfg, &blocks, 0, &pads, &mut codes, &mut outv);
        let mut halo = HaloBlock::new(shape);
        let mut rec = vec![0.0f32; elems];
        for b in 0..3 {
            decode_block_dualquant(
                &cfg,
                &codes[b * elems..(b + 1) * elems],
                &outv[b * elems..(b + 1) * elems],
                &pads,
                b,
                &mut halo,
                &mut rec,
            );
            for (l, r) in rec.iter().enumerate() {
                let dq_expected =
                    super::super::prequant(blocks[b * elems + l], cfg.half_inv_eb());
                assert_eq!(*r, dq_expected * cfg.twice_eb(), "block {b} elem {l}");
            }
        }
    }

    #[test]
    fn prop_roundtrip_bound_random() {
        check("decode-roundtrip-bound", 40, |g| {
            let ndim = 1 + g.rng.bounded(3) as usize;
            let bs = *g.choose(&[4usize, 8]);
            let shape = BlockShape::new(ndim, bs);
            let eb = *g.choose(&[1e-2f64, 1e-3]);
            let cfg = DqConfig::new(eb, 512, shape);
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let smooth = g.rng.next_f32() < 0.5;
            let (blocks, pads) = random_batch(&mut rng, shape, 2, 4.0, smooth);
            let tol = (eb + 1e-6) as f32;
            for be in [&PszBackend as &dyn PqBackend, &VecBackend::new(16), &Sz14Backend] {
                let err = roundtrip_max_err(be, &cfg, &blocks, &pads);
                if err > tol {
                    return Err(format!("{} err {err} > {tol}", be.name()));
                }
            }
            Ok(())
        });
    }

    // -------------------- decode backend bit-exactness matrix --------------------

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    fn decode_with(
        be: &dyn DecodeBackend,
        kind: CodesKind,
        cfg: &DqConfig,
        codes: &[u16],
        outv: &[f32],
        pads: &PadScalars,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; codes.len()];
        be.decode(kind, cfg, codes, outv, 0, pads, &mut out);
        out
    }

    /// The acceptance matrix: SimdDecodeBackend == ScalarDecodeBackend,
    /// bit for bit, across all dims, odd block sizes, both code kinds and
    /// **every ISA reachable on this host** — on real encoder output.
    #[test]
    fn matrix_simd_decode_matches_scalar_reference_every_isa() {
        let mut rng = Pcg32::seeded(777);
        for &(ndim, bs) in &[(1usize, 64usize), (1, 7), (2, 8), (2, 5), (2, 16), (3, 8), (3, 5)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-3, 512, shape);
            for smooth in [true, false] {
                let (blocks, pads) = random_batch(&mut rng, shape, 5, 4.0, smooth);
                for (enc, kind) in [
                    (&PszBackend as &dyn PqBackend, CodesKind::DualQuant),
                    (&Sz14Backend, CodesKind::Sz14),
                ] {
                    let mut codes = vec![0u16; blocks.len()];
                    let mut outv = vec![0.0f32; blocks.len()];
                    enc.run(&cfg, &blocks, 0, &pads, &mut codes, &mut outv);
                    let r0 = decode_with(&ScalarDecodeBackend, kind, &cfg, &codes, &outv, &pads);
                    for isa in Isa::available() {
                        for w in [4usize, 8, 16] {
                            let be = SimdDecodeBackend::with_isa(w, isa);
                            let r1 = decode_with(&be, kind, &cfg, &codes, &outv, &pads);
                            assert_eq!(
                                bits(&r0),
                                bits(&r1),
                                "{kind:?} ndim={ndim} bs={bs} smooth={smooth} w={w} isa={}",
                                isa.name()
                            );
                        }
                    }
                }
            }
        }
    }

    /// Adversarial raw streams (not encoder output): arbitrary codes with
    /// outlier holes, arbitrary outlier values, per-axis edge scalars of
    /// wildly different magnitudes — equality must hold for ANY input.
    #[test]
    fn matrix_adversarial_raw_streams_every_isa() {
        let mut rng = Pcg32::seeded(888);
        for &(ndim, bs) in &[(1usize, 9usize), (2, 3), (2, 7), (2, 12), (3, 3), (3, 6)] {
            let shape = BlockShape::new(ndim, bs);
            let elems = shape.elems();
            let nb = 4usize;
            for &(radius, out_pct) in &[(2u16, 60u32), (8, 25), (512, 5), (40_000, 10)] {
                let cfg = DqConfig::new(1e-2, radius, shape);
                let cap = (2 * radius as u32).min(65_535);
                let codes: Vec<u16> = (0..nb * elems)
                    .map(|_| {
                        if rng.bounded(100) < out_pct {
                            OUTLIER_CODE
                        } else {
                            (1 + rng.bounded(cap - 1)) as u16
                        }
                    })
                    .collect();
                let outv: Vec<f32> =
                    (0..nb * elems).map(|_| (rng.next_f32() * 2.0 - 1.0) * 1e4).collect();
                let scalars: Vec<f32> = (0..nb * ndim)
                    .map(|q| [1000.0f32, -0.37, 12.5][q % 3] * (1.0 + q as f32))
                    .collect();
                let pads = PadScalars {
                    policy: PaddingPolicy::new(PadValue::Avg, PadGranularity::Edge),
                    scalars,
                    ndim,
                };
                for kind in [CodesKind::DualQuant, CodesKind::Sz14] {
                    let r0 = decode_with(&ScalarDecodeBackend, kind, &cfg, &codes, &outv, &pads);
                    for isa in Isa::available() {
                        let be = SimdDecodeBackend::with_isa(16, isa);
                        let r1 = decode_with(&be, kind, &cfg, &codes, &outv, &pads);
                        assert_eq!(
                            bits(&r0),
                            bits(&r1),
                            "{kind:?} ndim={ndim} bs={bs} radius={radius} isa={}",
                            isa.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_outlier_blocks_every_isa() {
        // every element an outlier: the wavefront must pass the verbatim
        // values through (scaled for dual-quant) and cascade nothing
        for &(ndim, bs) in &[(1usize, 8usize), (2, 8), (3, 4)] {
            let shape = BlockShape::new(ndim, bs);
            let elems = shape.elems();
            let cfg = DqConfig::new(0.5, 8, shape); // twice_eb = 1.0
            let codes = vec![OUTLIER_CODE; elems];
            let outv: Vec<f32> = (0..elems).map(|l| l as f32 - 3.0).collect();
            let pads = PadScalars {
                policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
                scalars: vec![0.0],
                ndim,
            };
            for kind in [CodesKind::DualQuant, CodesKind::Sz14] {
                for isa in Isa::available() {
                    let be = SimdDecodeBackend::with_isa(8, isa);
                    let r = decode_with(&be, kind, &cfg, &codes, &outv, &pads);
                    assert_eq!(bits(&r), bits(&outv), "{kind:?} ndim={ndim} isa={}", isa.name());
                }
            }
        }
    }

    #[test]
    fn prop_isa_randomized_decode_equivalence() {
        // randomized shape, eb, batch AND a randomized ISA+width per case;
        // decode through the wavefront, cross-check the scalar reference
        // and the roundtrip bound in one pass
        check("simd-decode-equivalence", 60, |g| {
            let ndim = 1 + g.rng.bounded(3) as usize;
            let bs = *g.choose(&[3usize, 4, 5, 8, 12, 16]);
            let shape = BlockShape::new(ndim, bs);
            let eb = *g.choose(&[1e-2f64, 1e-3, 1e-4]);
            let cfg = DqConfig::new(eb, 512, shape);
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let (blocks, pads) = random_batch(&mut rng, shape, 3, 5.0, g.rng.next_f32() < 0.5);
            let mut codes = vec![0u16; blocks.len()];
            let mut outv = vec![0.0f32; blocks.len()];
            PszBackend.run(&cfg, &blocks, 0, &pads, &mut codes, &mut outv);
            let avail = Isa::available();
            let isa = avail[g.rng.bounded(avail.len() as u32) as usize];
            let w = *g.choose(&[4usize, 8, 16]);
            let be = SimdDecodeBackend::with_isa(w, isa);
            let r0 =
                decode_with(&ScalarDecodeBackend, CodesKind::DualQuant, &cfg, &codes, &outv, &pads);
            let r1 = decode_with(&be, CodesKind::DualQuant, &cfg, &codes, &outv, &pads);
            if bits(&r0) != bits(&r1) {
                return Err(format!("simd{w}/{} diverged ndim={ndim} bs={bs}", isa.name()));
            }
            let tol = (eb + 1e-6) as f32;
            for (r, d) in r1.iter().zip(&blocks) {
                if (r - d).abs() > tol {
                    return Err(format!("bound violated: |{r} - {d}| > {tol}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batched_decode_matches_per_block_decode() {
        // block_base indexing: decoding blocks [2, 5) as a batch must equal
        // decoding each block alone with its global index
        let shape = BlockShape::new(2, 8);
        let cfg = DqConfig::new(1e-3, 512, shape);
        let elems = shape.elems();
        let mut rng = Pcg32::seeded(99);
        let (blocks, pads) = random_batch(&mut rng, shape, 5, 3.0, true);
        let mut codes = vec![0u16; blocks.len()];
        let mut outv = vec![0.0f32; blocks.len()];
        PszBackend.run(&cfg, &blocks, 0, &pads, &mut codes, &mut outv);
        for be in [&ScalarDecodeBackend as &dyn DecodeBackend, &SimdDecodeBackend::new(8)] {
            let mut batch = vec![0.0f32; 3 * elems];
            be.decode(
                CodesKind::DualQuant,
                &cfg,
                &codes[2 * elems..5 * elems],
                &outv[2 * elems..5 * elems],
                2,
                &pads,
                &mut batch,
            );
            for (k, b) in (2usize..5).enumerate() {
                let mut one = vec![0.0f32; elems];
                be.decode(
                    CodesKind::DualQuant,
                    &cfg,
                    &codes[b * elems..(b + 1) * elems],
                    &outv[b * elems..(b + 1) * elems],
                    b,
                    &pads,
                    &mut one,
                );
                assert_eq!(
                    bits(&batch[k * elems..(k + 1) * elems]),
                    bits(&one),
                    "{} block {b}",
                    be.name()
                );
            }
        }
    }

    #[test]
    fn decode_backend_identity_and_default_dispatch() {
        let be = SimdDecodeBackend::new(8);
        assert_eq!(be.name(), format!("simd8/{}", be.isa().name()));
        assert!(be.isa().is_available());
        assert_eq!(ScalarDecodeBackend.name(), "scalar-ref");
        // the default decoder follows the active dispatch: scalar reference
        // when the dispatch resolves to scalar, the wavefront otherwise
        let def = default_decode_backend();
        if Isa::active() == Isa::Scalar {
            assert_eq!(def.name(), "scalar-ref");
        } else {
            assert_eq!(def.name(), format!("simd16/{}", Isa::active().name()));
        }
    }
}

//! Prediction + quantization backends — the paper's hot path.
//!
//! # The backend hierarchy
//!
//! Four implementations of the P&Q stage share one trait so the bench
//! harness, the coordinator and the figure generators can swap them:
//!
//! * [`sz14::Sz14Backend`] — Algorithm 1: predict on *reconstructed*
//!   values, linear-scale quantization. Carries the loop RAW dependence;
//!   the paper's `SZ-1.4` baseline.
//! * [`psz::PszBackend`] — Algorithm 2 (dual-quant) written as the
//!   straightforward scalar loop with a data-dependent branch; the paper's
//!   `pSZ` (serial dual-quant, `-O3`) baseline. **The bit-exactness
//!   reference** every vectorized backend is tested against.
//! * [`vectorized::VecBackend`] — dual-quant with branchless, lane-chunked
//!   inner loops (width 8 ≈ AVX2 class, width 16 ≈ AVX-512 class) that
//!   LLVM *autovectorizes* — portable, but silently scalar on the default
//!   `target-cpu`, and it burns a separate prequant pass per block.
//! * [`simd::SimdBackend`] — the explicit-intrinsics kernel (§III-C done
//!   with `core::arch`): runtime ISA dispatch (x86-64 AVX2, AVX-512F
//!   behind the `avx512` cargo feature, aarch64 NEON, scalar fallback) and
//!   the prequant pass **fused** into the predict/quantize lane loop.
//!
//! A fifth implementation lives in `runtime::PjrtBackend`: the same math
//! as an AOT-compiled XLA artifact.
//!
//! The decompression side has its own mirror hierarchy behind
//! [`decode::DecodeBackend`]: the cascading scalar reference and the SIMD
//! reverse-Lorenzo **wavefront** backend (anti-diagonal cells are
//! dependency-free), dispatched through the same ISA machinery — see the
//! [`decode`] module doc.
//!
//! # ISA dispatch & the bit-exactness guarantee
//!
//! `SimdBackend::new` snapshots [`crate::simd::Isa::active`]: the best ISA
//! `is_x86_feature_detected!` reports (NEON is architecturally guaranteed
//! on aarch64), overridable for benchmarking/testing via the
//! `VECSZ_FORCE_ISA` environment variable, the CLI `--isa` flag, or
//! [`crate::simd::force_isa`]. Overrides the host cannot execute are
//! clamped to the detected best — the dispatcher never runs an
//! instruction the CPU lacks.
//!
//! All dual-quant backends produce **byte-identical** codes and outlier
//! streams on every ISA: each kernel keeps the paper's operation order
//! `(w+n+u)-(nw+nu+wu)+nwu` and uses only lane ops with scalar-identical
//! IEEE-754 semantics (ties-to-even rounding, truncating converts). The
//! equivalence matrix in `simd::tests` enforces this against `PszBackend`
//! across every reachable ISA, and the backends are bit-exact against the
//! Python oracle.

pub mod decode;
pub mod psz;
pub mod simd;
pub mod sz14;
pub mod vectorized;

use crate::blocks::{BlockShape, HaloBlock};
use crate::padding::PadScalars;

/// Code stream semantics (stored in the container header; decode dispatches
/// on it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodesKind {
    /// Codes are pre-quantized-domain Lorenzo deltas (Algorithm 2).
    DualQuant,
    /// Codes are linear-quantized prediction errors in data units
    /// (Algorithm 1); outlier values are verbatim originals.
    Sz14,
}

/// Reserved quant code marking an outlier.
pub const OUTLIER_CODE: u16 = 0;

/// Configuration of one P&Q run.
#[derive(Clone, Copy, Debug)]
pub struct DqConfig {
    /// Absolute error bound.
    pub eb: f64,
    /// Quantization radius: codes span [1, 2*radius-1]; cap = 2*radius.
    pub radius: u16,
    pub shape: BlockShape,
}

impl DqConfig {
    pub fn new(eb: f64, radius: u16, shape: BlockShape) -> Self {
        assert!(eb > 0.0, "error bound must be positive");
        assert!(radius >= 2, "radius must be >= 2");
        Self { eb, radius, shape }
    }

    #[inline]
    pub fn half_inv_eb(&self) -> f32 {
        (0.5 / self.eb) as f32
    }

    #[inline]
    pub fn twice_eb(&self) -> f32 {
        (2.0 * self.eb) as f32
    }

    /// Alphabet size for the Huffman stage (codes are < 2*radius).
    pub fn alphabet(&self) -> usize {
        2 * self.radius as usize
    }
}

/// Pre-quantization: d° = round(d / (2 eb)); ties-to-even matches the
/// Python (numpy/jax) kernels bit-for-bit.
#[inline(always)]
pub fn prequant(x: f32, half_inv_eb: f32) -> f32 {
    (x * half_inv_eb).round_ties_even()
}

/// The prediction + quantization stage over a batch of gathered blocks.
///
/// `blocks` holds `nb = codes.len() / shape.elems()` blocks back-to-back in
/// row-major block layout; `block_base` is the global index of the first
/// block (padding scalars are indexed globally). Outputs are written in the
/// same layout: `codes[b * elems + l]`, `outv` likewise (0.0 unless the
/// element is an outlier).
pub trait PqBackend: Send + Sync {
    fn name(&self) -> String;
    fn kind(&self) -> CodesKind;
    /// Lane width the backend models (1 for scalar backends) — used by the
    /// Amdahl analysis (Table III).
    fn lanes(&self) -> usize;
    fn run(
        &self,
        cfg: &DqConfig,
        blocks: &[f32],
        block_base: usize,
        pads: &PadScalars,
        codes: &mut [u16],
        outv: &mut [f32],
    );
}

/// Build the pre-quantized halo for block `b`: halo planes carry the
/// pre-quantized edge padding scalars, interior the pre-quantized payload.
pub(crate) fn prep_halo_dq(
    halo: &mut HaloBlock,
    block: &[f32],
    cfg: &DqConfig,
    pads: &PadScalars,
    b: usize,
) {
    let hie = cfg.half_inv_eb();
    halo.fill_halo(|axis| prequant(pads.edge_scalar(b, axis), hie));
    halo.load_interior(block, |x| prequant(x, hie));
}

/// Shape-checked batch entry used by all backends' `run` implementations.
pub(crate) fn check_batch(shape: BlockShape, blocks: &[f32], codes: &[u16], outv: &[f32]) -> usize {
    let elems = shape.elems();
    assert_eq!(blocks.len() % elems, 0, "blocks not a whole number of blocks");
    let nb = blocks.len() / elems;
    assert_eq!(codes.len(), nb * elems);
    assert_eq!(outv.len(), nb * elems);
    nb
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};
    use crate::util::prng::Pcg32;

    /// Random gathered-block batch + matching pad scalars.
    pub fn random_batch(
        rng: &mut Pcg32,
        shape: BlockShape,
        nb: usize,
        scale: f32,
        smooth: bool,
    ) -> (Vec<f32>, PadScalars) {
        let elems = shape.elems();
        let mut blocks = vec![0.0f32; nb * elems];
        if smooth {
            let mut x = 0.0f32;
            for v in blocks.iter_mut() {
                x += (rng.next_f32() * 2.0 - 1.0) * scale * 0.05;
                *v = x;
            }
        } else {
            for v in blocks.iter_mut() {
                *v = (rng.next_f32() * 2.0 - 1.0) * scale;
            }
        }
        let scalars: Vec<f32> = (0..nb)
            .map(|b| {
                let s = &blocks[b * elems..(b + 1) * elems];
                s.iter().sum::<f32>() / elems as f32
            })
            .collect();
        let pads = PadScalars {
            policy: PaddingPolicy::new(PadValue::Avg, PadGranularity::Block),
            scalars,
            ndim: shape.ndim,
        };
        (blocks, pads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::psz::PszBackend;
    use crate::quant::sz14::Sz14Backend;
    use crate::quant::vectorized::VecBackend;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;
    use test_support::random_batch;

    fn run_backend(
        be: &dyn PqBackend,
        cfg: &DqConfig,
        blocks: &[f32],
        pads: &crate::padding::PadScalars,
    ) -> (Vec<u16>, Vec<f32>) {
        let mut codes = vec![0u16; blocks.len()];
        let mut outv = vec![0.0f32; blocks.len()];
        be.run(cfg, blocks, 0, pads, &mut codes, &mut outv);
        (codes, outv)
    }

    #[test]
    fn all_dualquant_backends_agree_bit_exact() {
        let mut rng = Pcg32::seeded(42);
        for &(ndim, bs) in &[(1usize, 64usize), (1, 8), (2, 8), (2, 16), (3, 8)] {
            let shape = BlockShape::new(ndim, bs);
            let cfg = DqConfig::new(1e-3, 512, shape);
            for smooth in [true, false] {
                let (blocks, pads) = random_batch(&mut rng, shape, 6, 3.0, smooth);
                let (c0, v0) = run_backend(&PszBackend, &cfg, &blocks, &pads);
                let (c8, v8) = run_backend(&VecBackend::new(8), &cfg, &blocks, &pads);
                let (c16, v16) = run_backend(&VecBackend::new(16), &cfg, &blocks, &pads);
                assert_eq!(c0, c8, "psz vs vec8 ndim={ndim} bs={bs} smooth={smooth}");
                assert_eq!(v0, v8);
                assert_eq!(c0, c16, "psz vs vec16 ndim={ndim} bs={bs}");
                assert_eq!(v0, v16);
                let (cs, vs) =
                    run_backend(&crate::quant::simd::SimdBackend::new(8), &cfg, &blocks, &pads);
                assert_eq!(c0, cs, "psz vs simd8 ndim={ndim} bs={bs} smooth={smooth}");
                assert_eq!(v0, vs);
            }
        }
    }

    #[test]
    fn prop_backend_equivalence_random_shapes() {
        check("dq-backend-equivalence", 60, |g| {
            let ndim = 1 + g.rng.bounded(3) as usize;
            let bs = *g.choose(&[4usize, 8, 12, 16]);
            let shape = BlockShape::new(ndim, bs);
            let eb = *g.choose(&[1e-2f64, 1e-3, 1e-4]);
            let cfg = DqConfig::new(eb, 512, shape);
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let (blocks, pads) = random_batch(&mut rng, shape, 3, 5.0, g.rng.next_f32() < 0.5);
            let (c0, v0) = run_backend(&PszBackend, &cfg, &blocks, &pads);
            let w = *g.choose(&[8usize, 16]);
            let (c1, v1) = run_backend(&VecBackend::new(w), &cfg, &blocks, &pads);
            if c0 == c1 && v0 == v1 {
                Ok(())
            } else {
                Err(format!("vec{w} diverged ndim={ndim} bs={bs} eb={eb}"))
            }
        });
    }

    #[test]
    fn constant_blocks_have_no_outliers_with_avg_padding() {
        let shape = BlockShape::new(2, 8);
        let cfg = DqConfig::new(1e-3, 512, shape);
        let blocks = vec![13.5f32; 2 * shape.elems()];
        let pads = crate::padding::PadScalars {
            policy: crate::padding::PaddingPolicy::new(
                crate::padding::PadValue::Avg,
                crate::padding::PadGranularity::Block,
            ),
            scalars: vec![13.5, 13.5],
            ndim: 2,
        };
        for be in [&PszBackend as &dyn PqBackend, &VecBackend::new(8), &Sz14Backend] {
            let (codes, _) = run_backend(be, &cfg, &blocks, &pads);
            assert!(
                codes.iter().all(|&c| c == cfg.radius),
                "{}: expected all-exact codes",
                be.name()
            );
        }
    }

    #[test]
    fn rough_data_tiny_eb_produces_outliers() {
        let shape = BlockShape::new(1, 64);
        let cfg = DqConfig::new(1e-6, 512, shape);
        let mut rng = Pcg32::seeded(3);
        let (blocks, pads) = random_batch(&mut rng, shape, 4, 100.0, false);
        let (codes, outv) = run_backend(&PszBackend, &cfg, &blocks, &pads);
        let n_out = codes.iter().filter(|&&c| c == OUTLIER_CODE).count();
        assert!(n_out > 0, "expected outliers");
        // outlier exclusivity
        for (c, v) in codes.iter().zip(&outv) {
            if *c != OUTLIER_CODE {
                assert_eq!(*v, 0.0);
            }
        }
    }

    #[test]
    fn sz14_codes_differ_from_dualquant_but_both_bounded() {
        // the two algorithms produce different code streams (different
        // prediction domains) yet identical error-bound guarantees — the
        // roundtrip bound is asserted in decode::tests.
        let shape = BlockShape::new(2, 8);
        let cfg = DqConfig::new(1e-3, 512, shape);
        let mut rng = Pcg32::seeded(11);
        let (blocks, pads) = random_batch(&mut rng, shape, 4, 2.0, true);
        let (c_dq, _) = run_backend(&PszBackend, &cfg, &blocks, &pads);
        let (c_14, _) = run_backend(&Sz14Backend, &cfg, &blocks, &pads);
        assert_eq!(c_dq.len(), c_14.len());
    }

    #[test]
    fn dqconfig_accessors() {
        let cfg = DqConfig::new(1e-2, 512, BlockShape::new(1, 8));
        assert!((cfg.half_inv_eb() - 50.0).abs() < 1e-6);
        assert!((cfg.twice_eb() - 0.02).abs() < 1e-9);
        assert_eq!(cfg.alphabet(), 1024);
    }
}

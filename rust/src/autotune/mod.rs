//! Autotuning of (block size × vector length) — §III-E / §V-F.
//!
//! Before compressing, sample a fixed percentage of blocks, run the
//! dual-quant stage on the sample under every candidate configuration for
//! `iterations` repetitions, and pick the configuration with the best
//! average P&Q bandwidth. The paper amortizes this cost across simulation
//! time-steps because the winning configuration is stable in time (§V-F);
//! [`top_k_stability`] reproduces that analysis.

use crate::blocks::{gather_block, BlockShape};
use crate::compressor::{default_block_size, BackendChoice};
use crate::data::Field;
use crate::padding::{compute_scalars, PaddingPolicy};
use crate::quant::{DqConfig, PqBackend};
use crate::util::prng::Pcg32;
use crate::util::timer::{mb_per_s, Timer};

/// One candidate configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuneConfig {
    pub block_size: usize,
    /// Lane width (the paper's vector-register length: 8 ≈ 256-bit,
    /// 16 ≈ 512-bit).
    pub width: usize,
    /// `true`: the explicit-intrinsics fused `SimdBackend`; `false`: the
    /// autovectorized `VecBackend`. Both are bit-exact, so the heuristic
    /// is free to pick whichever measures faster on this host/field.
    pub simd: bool,
}

impl TuneConfig {
    /// The `compressor` backend this candidate stands for.
    pub fn backend_choice(&self) -> BackendChoice {
        if self.simd {
            BackendChoice::Simd { width: self.width }
        } else {
            BackendChoice::Vec { width: self.width }
        }
    }

    /// Display label (`vec8` / `simd16`).
    pub fn backend_label(&self) -> String {
        format!("{}{}", if self.simd { "simd" } else { "vec" }, self.width)
    }
}

/// Candidate grid per dimensionality (§III-D: multiples of the vector
/// register; 128/256 showed no improvement in the paper's study). Every
/// (block size × width) point appears twice — once per dual-quant backend
/// (autovectorized `vec`, explicit-intrinsics `simd`) — since the two can
/// rank differently per host/field while staying bit-exact.
pub fn candidate_grid(ndim: usize, widths: &[usize]) -> Vec<TuneConfig> {
    let sizes: &[usize] = match ndim {
        1 => &[8, 16, 32, 64],
        2 => &[8, 16, 32, 64],
        _ => &[8, 16, 32],
    };
    let mut out = Vec::new();
    for &bs in sizes {
        for &w in widths {
            for simd in [false, true] {
                out.push(TuneConfig { block_size: bs, width: w, simd });
            }
        }
    }
    out
}

/// Measured performance of one configuration on the sampled blocks.
#[derive(Clone, Copy, Debug)]
pub struct TunePoint {
    pub config: TuneConfig,
    pub mb_per_s: f64,
}

#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: TuneConfig,
    pub table: Vec<TunePoint>,
    /// Wall time spent tuning (Fig 7's numerator).
    pub tune_seconds: f64,
    pub sampled_blocks: usize,
}

/// Autotune settings: the Fig 6/7 axes.
#[derive(Clone, Copy, Debug)]
pub struct TuneSettings {
    /// Percentage of blocks to sample (1.0 = 1%).
    pub sample_pct: f64,
    /// Repetitions averaged per configuration.
    pub iterations: usize,
    pub seed: u64,
}

impl Default for TuneSettings {
    fn default() -> Self {
        Self { sample_pct: 5.0, iterations: 2, seed: 0xA1170 }
    }
}

/// Measure one configuration on a sample of block indices; returns MB/s of
/// the full P&Q stage (gather + dual-quant), mirroring what `pq_stage`
/// does on the whole field — gathering inside the timed loop keeps the
/// sample's memory-access pattern honest (a cache-warm pre-gathered batch
/// systematically favours large blocks and mispicks; §V-F measures the
/// operation as it will actually run).
#[allow(clippy::too_many_arguments)]
fn measure_config(
    cfg: TuneConfig,
    field: &Field,
    idx: &[usize],
    eb: f64,
    radius: u16,
    pads: &crate::padding::PadScalars,
    sample_pads: &crate::padding::PadScalars,
    iterations: usize,
) -> f64 {
    let ndim = field.dims.ndim;
    let shape = BlockShape::new(ndim, cfg.block_size);
    let elems = shape.elems();
    let dq = DqConfig::new(eb, radius, shape);
    let backend = cfg.backend_choice().instantiate();
    let mut blocks = vec![0.0f32; idx.len() * elems];
    let mut codes = vec![0u16; blocks.len()];
    let mut outv = vec![0.0f32; blocks.len()];
    let mut run_once = || {
        for (s, &b) in idx.iter().enumerate() {
            gather_block(
                &field.data,
                &field.dims,
                cfg.block_size,
                b,
                pads.block_scalar(b),
                &mut blocks[s * elems..(s + 1) * elems],
            );
        }
        backend.run(&dq, &blocks, 0, sample_pads, &mut codes, &mut outv);
    };
    // warmup once (page-in, branch training), then timed iterations
    run_once();
    let t = Timer::start();
    for _ in 0..iterations.max(1) {
        run_once();
    }
    // Normalize by *useful field bytes*, not gathered bytes: boundary
    // blocks are padded, and large block sizes can more than double the
    // gathered volume on shallow fields — counting padding would inflate
    // their apparent bandwidth relative to the full-field ground truth.
    let nb_total = field.dims.num_blocks(cfg.block_size);
    let useful_bytes_per_block = field.data.len() as f64 * 4.0 / nb_total as f64;
    let useful = useful_bytes_per_block * idx.len() as f64 * iterations.max(1) as f64;
    useful / 1e6 / t.elapsed_s().max(f64::MIN_POSITIVE)
}

/// Run the autotuner on `field`.
pub fn autotune(
    field: &Field,
    eb: f64,
    radius: u16,
    padding: PaddingPolicy,
    widths: &[usize],
    settings: TuneSettings,
) -> TuneResult {
    let ndim = field.dims.ndim;
    let t_total = Timer::start();
    let grid = candidate_grid(ndim, widths);
    let mut table = Vec::with_capacity(grid.len());
    let mut rng = Pcg32::seeded(settings.seed);
    let mut sampled_blocks = 0usize;

    for cfg in &grid {
        let bs = cfg.block_size;
        let shape = BlockShape::new(ndim, bs);
        let elems = shape.elems();
        let nb = field.dims.num_blocks(bs);
        let k = ((nb as f64 * settings.sample_pct / 100.0).ceil() as usize).clamp(1, nb);
        sampled_blocks = sampled_blocks.max(k);
        let idx = rng.sample_indices(nb, k);
        // per-config pads (block scalars depend on bs); sampled blocks are
        // re-based to 0..k so the scalars vector is compacted to the sample.
        let full_pads = compute_scalars(&field.data, &field.dims, bs, padding);
        let scalars: Vec<f32> = idx.iter().map(|&b| full_pads.block_scalar(b)).collect();
        let sample_pads = crate::padding::PadScalars {
            policy: PaddingPolicy::new(
                crate::padding::PadValue::Avg,
                crate::padding::PadGranularity::Block,
            ),
            scalars,
            ndim,
        };
        let mbs = measure_config(
            *cfg,
            field,
            &idx,
            eb,
            radius,
            &full_pads,
            &sample_pads,
            settings.iterations,
        );
        table.push(TunePoint { config: *cfg, mb_per_s: mbs });
    }

    let best = table
        .iter()
        .max_by(|a, b| a.mb_per_s.total_cmp(&b.mb_per_s))
        .map(|p| p.config)
        .unwrap_or(TuneConfig { block_size: default_block_size(ndim), width: 8, simd: false });
    TuneResult { best, table, tune_seconds: t_total.elapsed_s(), sampled_blocks }
}

/// Exhaustive *full-field* measurement of every configuration (ground truth
/// for Fig 5 / the "peak" of Fig 6).
pub fn exhaustive_full(
    field: &Field,
    eb: f64,
    radius: u16,
    padding: PaddingPolicy,
    widths: &[usize],
    backend_threads: usize,
) -> Vec<TunePoint> {
    let ndim = field.dims.ndim;
    candidate_grid(ndim, widths)
        .into_iter()
        .map(|cfg| {
            let c = crate::compressor::Config {
                eb: crate::compressor::EbMode::Abs(eb),
                radius,
                block_size: cfg.block_size,
                padding,
                backend: cfg.backend_choice(),
                threads: backend_threads,
            };
            let backend = c.backend.instantiate();
            let (_, _, _, secs) = crate::compressor::pq_stage(field, &c, backend.as_ref());
            TunePoint { config: cfg, mb_per_s: mb_per_s(field.data.len() * 4, secs) }
        })
        .collect()
}

/// §V-F time-series analysis: fraction of `results` whose best config is
/// within the top-k configs of the aggregate ranking.
pub fn top_k_stability(results: &[TuneResult], k: usize) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    // aggregate mean bandwidth per config
    let mut agg: Vec<(TuneConfig, f64, usize)> = Vec::new();
    for r in results {
        for p in &r.table {
            if let Some(e) = agg.iter_mut().find(|e| e.0 == p.config) {
                e.1 += p.mb_per_s;
                e.2 += 1;
            } else {
                agg.push((p.config, p.mb_per_s, 1));
            }
        }
    }
    for e in agg.iter_mut() {
        e.1 /= e.2 as f64;
    }
    agg.sort_by(|a, b| b.1.total_cmp(&a.1));
    let top: Vec<TuneConfig> = agg.iter().take(k).map(|e| e.0).collect();
    let hits = results.iter().filter(|r| top.contains(&r.best)).count();
    hits as f64 / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::Dims;
    use crate::data::Field;

    fn test_field() -> Field {
        let dims = Dims::d2(96, 96);
        let mut rng = Pcg32::seeded(4);
        let mut x = 0.0f32;
        let data: Vec<f32> = (0..dims.len())
            .map(|_| {
                x += (rng.next_f32() - 0.5) * 0.05;
                x
            })
            .collect();
        Field::new("t", dims, data)
    }

    #[test]
    fn grid_shape_matches_paper_counts() {
        // paper §V-F counts per (bs x vector len) point, doubled by the
        // vec/simd backend axis:
        // Intel 2D: 8 configs (4 sizes x 2 widths) -> 16 candidates
        assert_eq!(candidate_grid(2, &[8, 16]).len(), 16);
        // AMD: 4 configs (4 sizes x 1 width) -> 8
        assert_eq!(candidate_grid(1, &[8]).len(), 8);
        assert_eq!(candidate_grid(3, &[8, 16]).len(), 12);
        // both backends present for every (bs, width) point
        let g = candidate_grid(2, &[8]);
        assert_eq!(g.iter().filter(|c| c.simd).count(), g.len() / 2);
    }

    #[test]
    fn autotune_returns_a_grid_member_and_timings() {
        let f = test_field();
        let r = autotune(
            &f,
            1e-3,
            512,
            PaddingPolicy::ZERO,
            &[8, 16],
            TuneSettings { sample_pct: 10.0, iterations: 1, seed: 1 },
        );
        assert!(candidate_grid(2, &[8, 16]).contains(&r.best));
        assert_eq!(r.table.len(), 16);
        assert!(r.tune_seconds > 0.0);
        assert!(r.table.iter().all(|p| p.mb_per_s > 0.0));
    }

    #[test]
    fn higher_sample_pct_samples_more_blocks() {
        let f = test_field();
        let lo = autotune(&f, 1e-3, 512, PaddingPolicy::ZERO, &[8],
            TuneSettings { sample_pct: 2.0, iterations: 1, seed: 1 });
        let hi = autotune(&f, 1e-3, 512, PaddingPolicy::ZERO, &[8],
            TuneSettings { sample_pct: 50.0, iterations: 1, seed: 1 });
        assert!(hi.sampled_blocks > lo.sampled_blocks);
    }

    #[test]
    fn stability_metric_bounds() {
        let f = test_field();
        let runs: Vec<TuneResult> = (0..4)
            .map(|s| {
                autotune(&f, 1e-3, 512, PaddingPolicy::ZERO, &[8, 16],
                    TuneSettings { sample_pct: 10.0, iterations: 1, seed: s })
            })
            .collect();
        let s1 = top_k_stability(&runs, 1);
        let s2 = top_k_stability(&runs, 2);
        let s_all = top_k_stability(&runs, 16);
        assert!((0.0..=1.0).contains(&s1));
        assert!(s2 >= s1);
        assert_eq!(s_all, 1.0);
    }

    #[test]
    fn exhaustive_covers_grid() {
        let f = test_field();
        let pts = exhaustive_full(&f, 1e-3, 512, PaddingPolicy::ZERO, &[8], 1);
        assert_eq!(pts.len(), 8);
    }
}

//! Bit-level I/O and varint coding (substrate for the Huffman coder, the
//! LZSS back-end and the `.vsz` container).
//!
//! Bits are packed LSB-first into little-endian u64 words: the first bit
//! written is bit 0 of byte 0. The reader consumes in the same order, so a
//! write/read pair is always an identity (property-tested below).

/// LSB-first bit writer with a u64 accumulator.
#[derive(Default)]
pub struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { out: Vec::with_capacity(bytes), acc: 0, nbits: 0 }
    }

    /// Write the low `n` bits of `v` (n <= 32 per call; the accumulator
    /// keeps < 32 pending bits so `v << nbits` never overflows u64).
    #[inline]
    pub fn put(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 32, "put() supports at most 32 bits per call");
        debug_assert!(n == 0 || v < (1u64 << n), "value {v} wider than {n} bits");
        self.acc |= v << self.nbits;
        self.nbits += n;
        // word-at-a-time flush (§Perf: the byte-loop version halved Huffman
        // encode throughput): drain 4 whole bytes in one extend.
        if self.nbits >= 32 {
            self.out.extend_from_slice(&self.acc.to_le_bytes()[..4]);
            self.acc >>= 32;
            self.nbits -= 32;
        }
    }

    #[inline]
    pub fn put_bit(&mut self, b: bool) {
        self.put(b as u64, 1);
    }

    /// Number of complete bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.out.len() as u64 * 8 + self.nbits as u64
    }

    /// Flush the partial byte (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        while self.nbits > 0 {
            self.out.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits = self.nbits.saturating_sub(8);
        }
        self.out
    }
}

/// LSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: usize, // next byte index
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self { data, pos: 0, acc: 0, nbits: 0 }
    }

    /// Top up the accumulator. Hot path loads one little-endian u64 and
    /// advances by however many whole bytes fit above the pending bits
    /// (§Perf: the byte-at-a-time loop was the Huffman decode bottleneck).
    /// Bits of the partially-consumed boundary byte are deposited twice
    /// across successive refills; the OR is idempotent because they are the
    /// same stream bits at the same accumulator positions.
    #[inline]
    fn refill(&mut self) {
        if self.pos + 8 <= self.data.len() {
            let w = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into().unwrap());
            self.acc |= w << self.nbits;
            let take = (64 - self.nbits) >> 3; // whole bytes that fit
            self.pos += take as usize;
            self.nbits += take * 8;
            return;
        }
        while self.nbits <= 56 && self.pos < self.data.len() {
            self.acc |= (self.data[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read `n` bits (n <= 57). Returns None past end of stream.
    #[inline]
    pub fn get(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 57);
        if self.nbits < n {
            self.refill();
            if self.nbits < n {
                return None;
            }
        }
        if n == 0 {
            return Some(0);
        }
        let v = self.acc & ((1u64 << n) - 1);
        self.acc >>= n;
        self.nbits -= n;
        Some(v)
    }

    /// Peek up to `n` bits without consuming (may return fewer near EOF —
    /// missing high bits read as zero).
    #[inline]
    pub fn peek(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        if self.nbits < n {
            self.refill();
        }
        if n == 0 {
            0
        } else {
            self.acc & ((1u64 << n) - 1)
        }
    }

    /// Consume `n` bits previously peeked.
    #[inline]
    pub fn consume(&mut self, n: u32) {
        debug_assert!(self.nbits >= n, "consume past refill window");
        self.acc >>= n;
        self.nbits -= n;
    }

    pub fn get_bit(&mut self) -> Option<bool> {
        self.get(1).map(|b| b != 0)
    }

    /// Bits remaining (counting unconsumed accumulator + unread bytes).
    pub fn remaining_bits(&self) -> u64 {
        self.nbits as u64 + (self.data.len() - self.pos) as u64 * 8
    }
}

/// LEB128 unsigned varint append.
pub fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// LEB128 unsigned varint read; returns (value, bytes consumed).
pub fn get_uvarint(data: &[u8]) -> Option<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &b) in data.iter().enumerate() {
        if shift >= 64 {
            return None;
        }
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Some((v, i + 1));
        }
        shift += 7;
    }
    None
}

/// Zigzag for signed varints.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Byte cursor for the container parser: sequential typed reads with
/// explicit errors instead of panics.
pub struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self { data, pos: 0 }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.remaining() < n {
            return None;
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Some(s)
    }

    pub fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }

    pub fn u16(&mut self) -> Option<u16> {
        self.take(2).map(|s| u16::from_le_bytes([s[0], s[1]]))
    }

    pub fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Option<f64> {
        self.u64().map(f64::from_bits)
    }

    pub fn uvarint(&mut self) -> Option<u64> {
        let (v, n) = get_uvarint(&self.data[self.pos..])?;
        self.pos += n;
        Some(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn bit_roundtrip_simple() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0xFF, 8);
        w.put(0, 5);
        w.put(0x12345, 20);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3), Some(0b101));
        assert_eq!(r.get(8), Some(0xFF));
        assert_eq!(r.get(5), Some(0));
        assert_eq!(r.get(20), Some(0x12345));
    }

    #[test]
    fn bit_reader_eof() {
        let mut w = BitWriter::new();
        w.put(3, 2);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(8), Some(3)); // zero-padded final byte
        assert_eq!(r.get(8), None);
    }

    #[test]
    fn peek_consume_matches_get() {
        let mut w = BitWriter::new();
        for i in 0..100u64 {
            w.put(i % 32, 5);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for i in 0..100u64 {
            let p = r.peek(5);
            r.consume(5);
            assert_eq!(p, i % 32);
        }
    }

    #[test]
    fn wide_peek_partial_consume_across_word_refills() {
        // the two-symbol Huffman decode pattern: peek a wide window, then
        // consume fewer bits, repeatedly crossing the 8-byte fast-refill
        // boundary with pending stale bits in the accumulator
        let mut w = BitWriter::new();
        let mut items = Vec::new();
        for i in 0..5000u64 {
            let width = 1 + (i % 30) as u32;
            let v = i.wrapping_mul(0x9E37_79B9_7F4A_7C15) & ((1u64 << width) - 1);
            w.put(v, width);
            items.push((v, width));
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, width) in &items {
            let p = r.peek(30);
            assert_eq!(p & ((1u64 << width) - 1), v);
            r.consume(width);
        }
        assert!(r.remaining_bits() < 8, "only zero padding may remain");
    }

    #[test]
    fn prop_bit_roundtrip_random_widths() {
        check("bitio-roundtrip", 200, |g| {
            let n = g.len() * 4;
            let items: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let width = 1 + g.rng.bounded(32);
                    let v = g.rng.next_u64() & ((1u64 << width) - 1);
                    (v, width)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, width) in &items {
                w.put(v, width);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(v, width) in &items {
                if r.get(width) != Some(v) {
                    return Err(format!("mismatch at width {width}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn varint_roundtrip_edges() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_uvarint(&mut buf, v);
            let (got, n) = get_uvarint(&buf).unwrap();
            assert_eq!(got, v);
            assert_eq!(n, buf.len());
        }
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        // small magnitudes map to small codes
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn cursor_typed_reads() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&0xAABBu16.to_le_bytes());
        buf.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
        put_uvarint(&mut buf, 777);
        let mut c = Cursor::new(&buf);
        assert_eq!(c.u16(), Some(0xAABB));
        assert_eq!(c.u32(), Some(0xDEADBEEF));
        assert_eq!(c.uvarint(), Some(777));
        assert_eq!(c.u8(), None);
    }
}

//! Canonical, length-limited Huffman coding (substrate).
//!
//! The paper's encoding stage compresses the dual-quant integer codes with
//! Huffman coding; outlier/value streams reuse the same coder over bytes.
//!
//! Design:
//! * code lengths from a heap-built Huffman tree, then clamped to
//!   `MAX_BITS` with a Kraft-sum repair pass (zlib-style),
//! * canonical code assignment (sorted by length, then symbol), so the
//!   header only stores lengths,
//! * sparse header: varint (symbol, length) pairs for non-zero lengths,
//! * decode through a flat `2^max_len` lookup table (symbol + length per
//!   entry) — one peek/consume per symbol on the hot path.

use crate::bitio::{BitReader, BitWriter, get_uvarint, put_uvarint};
use crate::error::{Result, VszError};

/// Maximum code length; 2^15 table = 32K entries keeps the LUT inside L2.
pub const MAX_BITS: u32 = 15;

/// Frequency histogram over a u16-symbol stream.
pub fn histogram(symbols: &[u16], alphabet: usize) -> Vec<u64> {
    let mut h = vec![0u64; alphabet];
    for &s in symbols {
        h[s as usize] += 1;
    }
    h
}

/// Compute Huffman code lengths for `freqs` (0-freq symbols get length 0),
/// limited to `max_bits`.
pub fn code_lengths(freqs: &[u64], max_bits: u32) -> Vec<u8> {
    let n = freqs.len();
    let mut lens = vec![0u8; n];
    let present: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    match present.len() {
        0 => return lens,
        1 => {
            lens[present[0]] = 1;
            return lens;
        }
        _ => {}
    }

    // Heap Huffman over (weight, node). Nodes 0..n are leaves, >= n internal.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut parent = vec![usize::MAX; n + present.len()];
    let mut next_internal = n;
    for &i in &present {
        heap.push(Reverse((freqs[i], i)));
    }
    while heap.len() > 1 {
        let Reverse((wa, a)) = heap.pop().unwrap();
        let Reverse((wb, b)) = heap.pop().unwrap();
        let p = next_internal;
        next_internal += 1;
        parent[a] = p;
        parent[b] = p;
        heap.push(Reverse((wa + wb, p)));
    }
    let root = heap.pop().unwrap().0 .1;

    // Depth of each leaf = code length.
    for &i in &present {
        let mut d = 0u32;
        let mut node = i;
        while node != root {
            node = parent[node];
            d += 1;
        }
        lens[i] = d.min(255) as u8;
    }

    // Length-limit repair: clamp, then restore Kraft sum <= 1 by lengthening
    // the deepest still-extendable codes (cheapest distortion).
    let mut over = false;
    for &i in &present {
        if lens[i] as u32 > max_bits {
            lens[i] = max_bits as u8;
            over = true;
        }
    }
    if over {
        let kraft = |lens: &[u8]| -> u64 {
            // scaled by 2^max_bits to stay integral
            present.iter().map(|&i| 1u64 << (max_bits - lens[i] as u32)).sum()
        };
        let budget = 1u64 << max_bits;
        while kraft(&lens) > budget {
            // lengthen the symbol with the largest length < max_bits
            let mut best: Option<usize> = None;
            for &i in &present {
                if (lens[i] as u32) < max_bits
                    && best.map_or(true, |b| lens[i] > lens[b])
                {
                    best = Some(i);
                }
            }
            let b = best.expect("kraft repair: no extendable symbol");
            lens[b] += 1;
        }
    }
    lens
}

/// Canonical code assignment: returns per-symbol (code, len) with codes in
/// MSB-first canonical order. Symbols with len 0 get (0, 0).
pub fn canonical_codes(lens: &[u8]) -> Vec<(u32, u8)> {
    let max_len = lens.iter().copied().max().unwrap_or(0) as u32;
    let mut bl_count = vec![0u32; max_len as usize + 1];
    for &l in lens {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = vec![0u32; max_len as usize + 2];
    let mut code = 0u32;
    for bits in 1..=max_len as usize {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    // canonical order = (len, symbol) ascending; iterating symbols in order
    // per length achieves that.
    let mut out = vec![(0u32, 0u8); lens.len()];
    for bits in 1..=max_len as usize {
        for (sym, &l) in lens.iter().enumerate() {
            if l as usize == bits {
                out[sym] = (next_code[bits], l);
                next_code[bits] += 1;
            }
        }
    }
    out
}

#[inline]
fn reverse_bits(v: u32, n: u8) -> u32 {
    v.reverse_bits() >> (32 - n as u32)
}

/// Encoder: symbol -> (LSB-first reversed code, length).
pub struct Encoder {
    table: Vec<(u32, u8)>,
}

impl Encoder {
    pub fn from_lengths(lens: &[u8]) -> Self {
        let codes = canonical_codes(lens);
        let table = codes
            .iter()
            .map(|&(c, l)| if l == 0 { (0, 0) } else { (reverse_bits(c, l), l) })
            .collect();
        Self { table }
    }

    #[inline]
    pub fn encode_symbol(&self, w: &mut BitWriter, sym: u16) {
        let (code, len) = self.table[sym as usize];
        debug_assert!(len > 0, "encoding symbol {sym} with no code");
        w.put(code as u64, len as u32);
    }

    pub fn encode_all(&self, symbols: &[u16]) -> Vec<u8> {
        let mut w = BitWriter::with_capacity(symbols.len() / 2 + 16);
        for &s in symbols {
            self.encode_symbol(&mut w, s);
        }
        w.finish()
    }

    /// Exact bit cost of a stream under this code (for ratio estimates).
    pub fn cost_bits(&self, hist: &[u64]) -> u64 {
        hist.iter()
            .zip(&self.table)
            .map(|(&f, &(_, l))| f * l as u64)
            .sum()
    }
}

/// Decoder: flat LUT of 2^max_len entries, each (symbol, length).
pub struct Decoder {
    lut: Vec<u32>, // sym in low 16, len in bits 16..24
    max_len: u32,
}

impl Decoder {
    pub fn from_lengths(lens: &[u8]) -> Result<Self> {
        let max_len = lens.iter().copied().max().unwrap_or(0) as u32;
        if max_len == 0 {
            return Ok(Self { lut: vec![], max_len: 0 });
        }
        if max_len > MAX_BITS {
            return Err(VszError::format(format!("huffman length {max_len} > {MAX_BITS}")));
        }
        let codes = canonical_codes(lens);
        let mut lut = vec![u32::MAX; 1usize << max_len];
        for (sym, &(code, len)) in codes.iter().enumerate() {
            if len == 0 {
                continue;
            }
            let rev = reverse_bits(code, len) as usize;
            let step = 1usize << len;
            let entry = (sym as u32) | ((len as u32) << 16);
            let mut idx = rev;
            while idx < lut.len() {
                if lut[idx] != u32::MAX {
                    return Err(VszError::format("huffman: overlapping codes (bad lengths)"));
                }
                lut[idx] = entry;
                idx += step;
            }
        }
        Ok(Self { lut, max_len })
    }

    /// Decode exactly `count` symbols.
    pub fn decode_all(&self, bytes: &[u8], count: usize) -> Result<Vec<u16>> {
        let mut out = Vec::with_capacity(count);
        let mut r = BitReader::new(bytes);
        for _ in 0..count {
            let idx = r.peek(self.max_len) as usize;
            let entry = *self
                .lut
                .get(idx)
                .ok_or_else(|| VszError::format("huffman: truncated stream"))?;
            if entry == u32::MAX {
                return Err(VszError::format("huffman: invalid code"));
            }
            let len = entry >> 16;
            if r.remaining_bits() < len as u64 {
                return Err(VszError::format("huffman: stream underrun"));
            }
            r.consume(len);
            out.push(entry as u16);
        }
        Ok(out)
    }
}

/// Serialize code lengths sparsely: varint n_pairs, then (delta-sym, len).
pub fn write_lengths(out: &mut Vec<u8>, lens: &[u8]) {
    let pairs: Vec<(usize, u8)> =
        lens.iter().enumerate().filter(|(_, &l)| l > 0).map(|(s, &l)| (s, l)).collect();
    put_uvarint(out, lens.len() as u64);
    put_uvarint(out, pairs.len() as u64);
    let mut prev = 0usize;
    for (s, l) in pairs {
        put_uvarint(out, (s - prev) as u64);
        out.push(l);
        prev = s;
    }
}

/// Parse lengths written by [`write_lengths`]; returns (lens, bytes read).
pub fn read_lengths(data: &[u8]) -> Result<(Vec<u8>, usize)> {
    let mut pos = 0usize;
    let varint = |pos: &mut usize| -> Result<u64> {
        let (v, n) =
            get_uvarint(&data[*pos..]).ok_or_else(|| VszError::format("huffman header EOF"))?;
        *pos += n;
        Ok(v)
    };
    let alphabet = varint(&mut pos)? as usize;
    let npairs = varint(&mut pos)? as usize;
    if alphabet > 1 << 20 {
        return Err(VszError::format("huffman: absurd alphabet size"));
    }
    let mut lens = vec![0u8; alphabet];
    let mut sym = 0usize;
    for i in 0..npairs {
        let delta = varint(&mut pos)? as usize;
        sym = if i == 0 { delta } else { sym + delta };
        let l = *data.get(pos).ok_or_else(|| VszError::format("huffman header EOF"))?;
        pos += 1;
        if sym >= alphabet || l as u32 > MAX_BITS {
            return Err(VszError::format("huffman: bad (symbol,length) pair"));
        }
        lens[sym] = l;
    }
    Ok((lens, pos))
}

/// One-call stream compression: header (lengths) + varint count + payload.
pub fn compress_u16(symbols: &[u16], alphabet: usize) -> Vec<u8> {
    let hist = histogram(symbols, alphabet);
    let lens = code_lengths(&hist, MAX_BITS);
    let enc = Encoder::from_lengths(&lens);
    let mut out = Vec::new();
    write_lengths(&mut out, &lens);
    put_uvarint(&mut out, symbols.len() as u64);
    let payload = enc.encode_all(symbols);
    out.extend_from_slice(&payload);
    out
}

/// Inverse of [`compress_u16`].
pub fn decompress_u16(data: &[u8]) -> Result<Vec<u16>> {
    let (lens, mut pos) = read_lengths(data)?;
    let (count, n) =
        get_uvarint(&data[pos..]).ok_or_else(|| VszError::format("huffman count EOF"))?;
    pos += n;
    if count == 0 {
        return Ok(Vec::new());
    }
    // every symbol consumes at least one bit, so a forged count can never
    // exceed the remaining payload bits — reject before allocating
    if count > (data.len() - pos) as u64 * 8 {
        return Err(VszError::format("huffman: count exceeds payload"));
    }
    let dec = Decoder::from_lengths(&lens)?;
    dec.decode_all(&data[pos..], count as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;

    #[test]
    fn lengths_satisfy_kraft() {
        let freqs = vec![100u64, 50, 20, 10, 5, 2, 1, 1];
        let lens = code_lengths(&freqs, MAX_BITS);
        let kraft: f64 = lens.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-12);
        // optimal Huffman on this distribution is exactly Kraft-tight
        assert!((kraft - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_symbol_stream() {
        let syms = vec![7u16; 1000];
        let blob = compress_u16(&syms, 16);
        assert!(blob.len() < 200); // ~1 bit per symbol
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
    }

    #[test]
    fn empty_stream() {
        let blob = compress_u16(&[], 16);
        assert_eq!(decompress_u16(&blob).unwrap(), Vec::<u16>::new());
    }

    #[test]
    fn skewed_quant_code_stream_compresses_hard() {
        // mimic dual-quant output: mass at `radius`, tails around it
        let mut rng = Pcg32::seeded(9);
        let radius = 512u16;
        let syms: Vec<u16> = (0..100_000)
            .map(|_| {
                let r = rng.next_f32();
                if r < 0.8 {
                    radius
                } else if r < 0.95 {
                    radius + 1 - (rng.bounded(3) as u16)
                } else {
                    radius - 8 + rng.bounded(16) as u16
                }
            })
            .collect();
        let blob = compress_u16(&syms, 1024);
        // entropy of this distribution is ~1.2 bits/sym; 16-bit raw = 200KB
        assert!(blob.len() < 40_000, "blob {} bytes", blob.len());
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
    }

    #[test]
    fn length_limit_enforced_on_pathological_freqs() {
        // fibonacci-ish frequencies force long codes without a limit
        let mut freqs = vec![0u64; 40];
        let (mut a, mut b) = (1u64, 1u64);
        for f in freqs.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let lens = code_lengths(&freqs, MAX_BITS);
        assert!(lens.iter().all(|&l| l as u32 <= MAX_BITS));
        let kraft: f64 = lens.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-12);
        // still decodable end-to-end
        let mut syms = Vec::new();
        for (s, &f) in freqs.iter().enumerate() {
            for _ in 0..(f.min(50)) {
                syms.push(s as u16);
            }
        }
        let blob = compress_u16(&syms, 40);
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
    }

    #[test]
    fn header_roundtrip_sparse() {
        let mut lens = vec![0u8; 1024];
        lens[0] = 3;
        lens[511] = 2;
        lens[512] = 1;
        lens[1023] = 3;
        let mut buf = Vec::new();
        write_lengths(&mut buf, &lens);
        let (got, used) = read_lengths(&buf).unwrap();
        assert_eq!(got, lens);
        assert_eq!(used, buf.len());
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        check("huffman-roundtrip", 60, |g| {
            let n = g.len() * 50;
            let alphabet = *g.choose(&[2usize, 17, 256, 1024]);
            let syms: Vec<u16> = (0..n)
                .map(|_| {
                    // zipf-ish skew: square the uniform
                    let u = g.rng.next_f32();
                    ((u * u * (alphabet as f32 - 1.0)) as u16).min(alphabet as u16 - 1)
                })
                .collect();
            let blob = compress_u16(&syms, alphabet);
            let back = decompress_u16(&blob).map_err(|e| e.to_string())?;
            if back == syms {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }

    #[test]
    fn decoder_rejects_garbage() {
        assert!(decompress_u16(&[0xFF, 0xFF, 0xFF]).is_err());
    }
}

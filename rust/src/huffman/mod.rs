//! Canonical, length-limited Huffman coding (substrate).
//!
//! The paper's encoding stage compresses the dual-quant integer codes with
//! Huffman coding; outlier/value streams reuse the same coder over bytes.
//!
//! Design:
//! * histogramming is 4-way interleaved (independent sub-histograms merged
//!   once) so skewed streams do not serialize on one hot counter's
//!   store-to-load dependency, with the pooled per-worker merge on top,
//! * code lengths from a heap-built Huffman tree, then clamped to
//!   `MAX_BITS` with a single-pass Kraft-sum repair over the bit-length
//!   histogram (zlib-style),
//! * canonical code assignment (sorted by length, then symbol), so the
//!   header only stores lengths,
//! * sparse header: varint (symbol, length) pairs for non-zero lengths,
//! * decode through a flat `2^max_len` **two-symbol** lookup table: when a
//!   complete second code also fits in the peeked window, one entry yields
//!   both symbols in a single peek/consume,
//! * encode writes symbol **pairs** per `BitWriter::put` (2 × `MAX_BITS`
//!   ≤ 30 bits fits one call).
//!
//! # Payload formats
//!
//! Three stream formats share the code-table header and the bitstream
//! coder:
//!
//! * **legacy unframed** ([`compress_u16`]) — header, varint count, one
//!   monolithic bitstream. Still written for the small internal token
//!   streams of [`crate::lossless`], still decoded everywhere.
//! * **`HUF2` chunked** ([`compress_u16_chunked`]) — the container CODES
//!   format of the first parallel entropy stage: a 4-byte magic, the shared
//!   code-table header, and the symbol stream split into fixed-size
//!   [`CHUNK_SYMS`] chunks, each encoded as an independent byte-aligned
//!   bitstream. A per-chunk (symbol-count, bit-length) offset table lets
//!   [`decompress_u16_pooled`] decode chunks concurrently on the
//!   [`ThreadPool`]. Chunk geometry is fixed by `CHUNK_SYMS`, never by the
//!   worker count, so the output bytes are identical for every thread
//!   count. Still decoded everywhere; no longer written by the container.
//! * **`HUF3` framed** ([`compress_u16_framed`]) — the entropy engine v2
//!   revision, written for the container CODES sections and the large
//!   [`crate::lossless`] token streams. Same fixed chunk geometry as HUF2
//!   plus two per-chunk options, each announced by a flag byte in the
//!   chunk entry:
//!   - a **gap array** (Rivera et al.: self-synchronizing Huffman
//!     streams): a CRC32-guarded side index of the bit offsets where every
//!     [`GAP_INTERVAL_SYMS`]-th symbol starts, so the decoder can split
//!     *one chunk's* bitstream across pool workers — each segment decodes
//!     independently into its pre-sized output slice and a single-chunk
//!     payload finally scales on threads;
//!   - a **local code table** for non-stationary streams, carried only
//!     when the chunk-local canonical table beats the shared one by at
//!     least [`LOCAL_TABLE_MIN_GAIN`] bytes including its own header
//!     (size-gated, so stationary streams pay nothing).
//!
//! [`decompress_u16`] dispatches on the `HUF2`/`HUF3` magics: real legacy
//! payloads can never collide with them (their first byte is the uvarint
//! of the alphabet size, and every alphabet this crate ever wrote —
//! `2 * radius` for quant codes, 256 for lossless token bytes — is even,
//! while `HUF2_MAGIC[0]` and `HUF3_MAGIC[0]` are odd; the three magic
//! bytes that follow make an accidental match with a hand-rolled odd
//! alphabet practically impossible). Every payload ever written by any
//! revision of this crate therefore keeps decoding bit-exactly through the
//! same entry point.
//!
//! # HUF3 layout
//!
//! ```text
//! magic [0xF7 'H' 'F' '3']
//! shared code table            (write_lengths: sparse varint pairs)
//! uvarint chunk_syms           (always CHUNK_SYMS when written by us)
//! uvarint gap_interval         (0 = no gap arrays anywhere)
//! uvarint n_chunks
//! per chunk:                   (the chunk entry table)
//!   u8 flags                   (bit0 = local table, bit1 = gap array;
//!                               unknown bits reject the payload)
//!   uvarint sym_count
//!   uvarint bit_len
//!   uvarint table_len          (only when flags bit0)
//!   uvarint gap_len            (only when flags bit1)
//! per chunk, concatenated:
//!   [local code table: table_len bytes, write_lengths format]
//!   [gap blob: gap_len bytes = u32-LE CRC32 | uvarint n_points |
//!    n_points ascending uvarint bit-offset deltas]
//!   bitstream: ceil(bit_len / 8) bytes
//! ```
//!
//! Gap point `k` (0-based) is the absolute bit offset where symbol
//! `(k + 1) * gap_interval` of the chunk starts; segment boundaries are
//! validated against the same per-segment `[count, count * MAX_BITS]` bit
//! bounds as chunks, and each segment must consume exactly its bit span —
//! the HUF2 integrity check, applied per segment.

use crate::bitio::{BitReader, BitWriter, get_uvarint, put_uvarint};
use crate::coordinator::pool::ThreadPool;
use crate::error::{Result, VszError};

/// Maximum code length; the 2^15-entry two-symbol LUT (8 B/entry) stays
/// inside a 256 KiB L2 slice.
pub const MAX_BITS: u32 = 15;

/// Symbols per HUF2 chunk. Fixed (never derived from the worker count) so
/// the encoded bytes are identical for every thread count; at the ~2
/// bits/symbol typical of quant codes a chunk is a ~16 KiB bitstream —
/// plenty of chunks to balance, large enough to amortize the per-chunk
/// byte-alignment padding (< 1 byte per chunk) and table entry.
pub const CHUNK_SYMS: usize = 1 << 16;

/// Magic prefix of the chunked HUF2 payload (see the module doc for why it
/// cannot collide with a legacy payload).
pub const HUF2_MAGIC: [u8; 4] = [0xF5, b'H', b'F', b'2'];

/// Magic prefix of the framed HUF3 payload (odd first byte for the same
/// legacy-collision argument as [`HUF2_MAGIC`]).
pub const HUF3_MAGIC: [u8; 4] = [0xF7, b'H', b'F', b'3'];

/// Symbol-count floor below which the parallel histogram is not worth the
/// fan-out.
const PAR_HIST_MIN: usize = 2 * CHUNK_SYMS;

/// Symbol-count floor below which the 4-way interleaved histogram is not
/// worth its `4 × alphabet` counter allocation. Shared with
/// [`GAP_INTERVAL_SYMS`]: both mark the same tipping point where
/// per-symbol work starts to dominate fixed per-block overhead.
pub const UNROLL_HIST_MIN: usize = 4096;

/// Default gap-array resync interval: a segment of this many symbols is
/// the smallest unit worth an independent decode lane. Reuses
/// [`UNROLL_HIST_MIN`] (the same work-vs-overhead tipping point measured
/// for the interleaved histogram) and must stay **even** so a resync point
/// never lands inside the encoder's two-symbol `put`.
pub const GAP_INTERVAL_SYMS: usize = UNROLL_HIST_MIN;

/// Minimum whole-payload saving (bytes, including the local table's own
/// header) before a HUF3 chunk carries a chunk-local code table instead of
/// using the shared one. Keeps stationary streams on the shared table —
/// one decoder LUT build instead of one per chunk.
pub const LOCAL_TABLE_MIN_GAIN: u64 = 64;

/// HUF3 chunk entry flag: the chunk carries its own canonical code table.
const CHUNK_LOCAL_TABLE: u8 = 1 << 0;
/// HUF3 chunk entry flag: the chunk carries a gap array.
const CHUNK_GAP_ARRAY: u8 = 1 << 1;

/// Knobs of the HUF3 encoder ([`compress_u16_framed`]). The defaults are
/// what the container writes; both knobs only change the encoded layout,
/// never the decoded symbols.
#[derive(Clone, Debug)]
pub struct EntropyOptions {
    /// Symbols between gap-array resync points; 0 disables gap arrays.
    /// Rounded up to the next even value (pair-encode alignment).
    pub gap_interval: usize,
    /// Allow chunks to carry local code tables when the size gate
    /// ([`LOCAL_TABLE_MIN_GAIN`]) says they pay for themselves.
    pub per_chunk_tables: bool,
}

impl Default for EntropyOptions {
    fn default() -> Self {
        Self { gap_interval: GAP_INTERVAL_SYMS, per_chunk_tables: true }
    }
}

/// Frequency histogram over a u16-symbol stream.
///
/// For streams past [`UNROLL_HIST_MIN`] this runs **4-way interleaved**:
/// four independent sub-histograms take every 4th symbol and are summed at
/// the end. Quant-code streams are heavily skewed (most symbols equal the
/// radius), so a single counter array serializes on the store-to-load
/// dependency of the hot bucket; independent sub-histograms give the CPU
/// four dependency chains to overlap. The merge is a commutative sum, so
/// the result is identical to the naive loop.
pub fn histogram(symbols: &[u16], alphabet: usize) -> Vec<u64> {
    // the interleave pays a 4×alphabet allocate/zero/merge, so it needs the
    // counting work to dominate: require both the absolute floor and that
    // the stream outweighs the per-bucket overhead (a small stream over a
    // huge --radius alphabet must stay on the naive loop)
    if symbols.len() < UNROLL_HIST_MIN.max(4 * alphabet) {
        let mut h = vec![0u64; alphabet];
        for &s in symbols {
            h[s as usize] += 1;
        }
        return h;
    }
    // one flat allocation, sub-histogram k at offset k * alphabet
    let mut sub = vec![0u64; 4 * alphabet];
    let (h0, rest) = sub.split_at_mut(alphabet);
    let (h1, rest) = rest.split_at_mut(alphabet);
    let (h2, h3) = rest.split_at_mut(alphabet);
    let mut chunks = symbols.chunks_exact(4);
    for c in &mut chunks {
        h0[c[0] as usize] += 1;
        h1[c[1] as usize] += 1;
        h2[c[2] as usize] += 1;
        h3[c[3] as usize] += 1;
    }
    for &s in chunks.remainder() {
        h0[s as usize] += 1;
    }
    let mut h = vec![0u64; alphabet];
    for k in 0..4 {
        for (a, b) in h.iter_mut().zip(&sub[k * alphabet..(k + 1) * alphabet]) {
            *a += b;
        }
    }
    h
}

/// Histogram via per-worker partial histograms merged once (the merge is a
/// commutative sum, so the result is independent of worker count).
fn histogram_pooled(symbols: &[u16], alphabet: usize, pool: Option<&ThreadPool>) -> Vec<u64> {
    let pool = match pool {
        Some(p) if symbols.len() >= PAR_HIST_MIN && p.threads() > 1 => p,
        _ => return histogram(symbols, alphabet),
    };
    let nw = pool.threads().min(symbols.len().div_ceil(CHUNK_SYMS));
    let per = symbols.len().div_ceil(nw);
    let parts = pool.scoped_scatter_gather(nw, |i| {
        let lo = (i * per).min(symbols.len());
        let hi = ((i + 1) * per).min(symbols.len());
        histogram(&symbols[lo..hi], alphabet)
    });
    let mut h = vec![0u64; alphabet];
    for part in parts {
        for (a, b) in h.iter_mut().zip(part) {
            *a += b;
        }
    }
    h
}

/// Compute Huffman code lengths for `freqs` (0-freq symbols get length 0),
/// limited to `max_bits`.
///
/// # Panics
/// When more than `2^max_bits` symbols have non-zero frequency no
/// `max_bits`-limited prefix code exists; the repair pass panics with
/// "no extendable symbol" (the same contract as the pre-histogram repair
/// loop). With `MAX_BITS = 15` this needs > 32768 distinct symbols — a
/// `radius` above 16384 combined with a stream that actually uses most of
/// its alphabet.
pub fn code_lengths(freqs: &[u64], max_bits: u32) -> Vec<u8> {
    let n = freqs.len();
    let mut lens = vec![0u8; n];
    let present: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    match present.len() {
        0 => return lens,
        1 => {
            lens[present[0]] = 1;
            return lens;
        }
        _ => {}
    }

    // Heap Huffman over (weight, node). Nodes 0..n are leaves, >= n internal.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut parent = vec![usize::MAX; n + present.len()];
    let mut next_internal = n;
    for &i in &present {
        heap.push(Reverse((freqs[i], i)));
    }
    while heap.len() > 1 {
        let Reverse((wa, a)) = heap.pop().unwrap();
        let Reverse((wb, b)) = heap.pop().unwrap();
        let p = next_internal;
        next_internal += 1;
        parent[a] = p;
        parent[b] = p;
        heap.push(Reverse((wa + wb, p)));
    }
    let root = heap.pop().unwrap().0 .1;

    // Depth of each leaf = code length.
    for &i in &present {
        let mut d = 0u32;
        let mut node = i;
        while node != root {
            node = parent[node];
            d += 1;
        }
        lens[i] = d.min(255) as u8;
    }

    if present.iter().all(|&i| (lens[i] as u32) <= max_bits) {
        return lens;
    }

    // Single-pass length-limit repair over the bit-length histogram
    // (zlib-style): clamp every over-long code to max_bits, then restore
    // Kraft <= 1 by repeatedly moving one symbol from the deepest
    // non-full level down one level (the cheapest distortion). Lengths are
    // then reassigned in ascending (original depth, frequency descending,
    // symbol) order: deeper tree leaves keep the longer codes, and within
    // one depth the rarest symbols absorb the lengthening — deterministic
    // and O(n log n) instead of the old per-move full rescan.
    let mb = max_bits as usize;
    let max_depth = present.iter().map(|&i| lens[i] as usize).max().unwrap();
    let mut bl_count = vec![0u64; mb + 2];
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); max_depth + 1];
    for &i in &present {
        bl_count[(lens[i] as usize).min(mb)] += 1;
        buckets[lens[i] as usize].push(i);
    }
    let budget = 1u64 << max_bits;
    let mut kraft: u64 = (1..=mb).map(|l| bl_count[l] << (mb - l)).sum();
    let mut l = mb - 1;
    while kraft > budget {
        while bl_count[l] == 0 {
            assert!(l > 1, "kraft repair: no extendable symbol");
            l -= 1;
        }
        bl_count[l] -= 1;
        bl_count[l + 1] += 1;
        kraft -= budget >> (l + 1);
        if l < mb - 1 {
            l += 1; // the moved symbol may now be the deepest extendable one
        }
    }
    let mut new_len = 1usize;
    for bucket in &mut buckets {
        // stable sort: frequency descending, ties stay in symbol order
        bucket.sort_by_key(|&i| std::cmp::Reverse(freqs[i]));
        for &i in bucket.iter() {
            while bl_count[new_len] == 0 {
                new_len += 1;
            }
            bl_count[new_len] -= 1;
            lens[i] = new_len as u8;
        }
    }
    lens
}

/// Canonical code assignment: returns per-symbol (code, len) with codes in
/// MSB-first canonical order. Symbols with len 0 get (0, 0).
pub fn canonical_codes(lens: &[u8]) -> Vec<(u32, u8)> {
    let max_len = lens.iter().copied().max().unwrap_or(0) as u32;
    let mut bl_count = vec![0u32; max_len as usize + 1];
    for &l in lens {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = vec![0u32; max_len as usize + 2];
    let mut code = 0u32;
    for bits in 1..=max_len as usize {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    // canonical order = (len, symbol) ascending; iterating symbols in order
    // per length achieves that.
    let mut out = vec![(0u32, 0u8); lens.len()];
    for bits in 1..=max_len as usize {
        for (sym, &l) in lens.iter().enumerate() {
            if l as usize == bits {
                out[sym] = (next_code[bits], l);
                next_code[bits] += 1;
            }
        }
    }
    out
}

#[inline]
fn reverse_bits(v: u32, n: u8) -> u32 {
    v.reverse_bits() >> (32 - n as u32)
}

/// Encoder: symbol -> (LSB-first reversed code, length).
pub struct Encoder {
    table: Vec<(u32, u8)>,
}

impl Encoder {
    pub fn from_lengths(lens: &[u8]) -> Self {
        let codes = canonical_codes(lens);
        let table = codes
            .iter()
            .map(|&(c, l)| if l == 0 { (0, 0) } else { (reverse_bits(c, l), l) })
            .collect();
        Self { table }
    }

    #[inline]
    pub fn encode_symbol(&self, w: &mut BitWriter, sym: u16) {
        let (code, len) = self.table[sym as usize];
        debug_assert!(len > 0, "encoding symbol {sym} with no code");
        w.put(code as u64, len as u32);
    }

    /// The pair-batched hot loop shared by [`encode_chunk`] and
    /// [`encode_chunk_gaps`]: symbols are written two at a time
    /// (2 × `MAX_BITS` ≤ 30 bits fits one `put`), which is bit-identical
    /// to the one-at-a-time loop.
    ///
    /// [`encode_chunk`]: Encoder::encode_chunk
    /// [`encode_chunk_gaps`]: Encoder::encode_chunk_gaps
    fn encode_seg(&self, w: &mut BitWriter, symbols: &[u16]) {
        let mut pairs = symbols.chunks_exact(2);
        for p in &mut pairs {
            let (c0, l0) = self.table[p[0] as usize];
            let (c1, l1) = self.table[p[1] as usize];
            debug_assert!(l0 > 0 && l1 > 0, "encoding symbol with no code");
            w.put((c0 as u64) | ((c1 as u64) << l0), l0 as u32 + l1 as u32);
        }
        for &s in pairs.remainder() {
            self.encode_symbol(w, s);
        }
    }

    /// Encode `symbols` into a byte-aligned bitstream; returns the bytes
    /// and the exact bit length before padding.
    pub fn encode_chunk(&self, symbols: &[u16]) -> (Vec<u8>, u64) {
        let mut w = BitWriter::with_capacity(symbols.len() / 2 + 16);
        self.encode_seg(&mut w, symbols);
        let bits = w.bit_len();
        (w.finish(), bits)
    }

    /// Like [`encode_chunk`](Encoder::encode_chunk), additionally recording
    /// the gap array: the absolute bit offset where every
    /// `gap_interval`-th symbol starts (the first segment's offset 0 is
    /// implicit and not recorded). `gap_interval` must be even so a resync
    /// point never splits a two-symbol `put`; the bitstream is then
    /// bit-identical to `encode_chunk` — only pair boundaries are ever
    /// segment boundaries.
    pub fn encode_chunk_gaps(
        &self,
        symbols: &[u16],
        gap_interval: usize,
    ) -> (Vec<u8>, u64, Vec<u64>) {
        debug_assert!(gap_interval >= 2 && gap_interval % 2 == 0, "gap interval must be even");
        let mut w = BitWriter::with_capacity(symbols.len() / 2 + 16);
        let mut gaps = Vec::with_capacity(symbols.len() / gap_interval + 1);
        let mut segs = symbols.chunks(gap_interval);
        if let Some(first) = segs.next() {
            self.encode_seg(&mut w, first);
        }
        for seg in segs {
            gaps.push(w.bit_len());
            self.encode_seg(&mut w, seg);
        }
        let bits = w.bit_len();
        (w.finish(), bits, gaps)
    }

    pub fn encode_all(&self, symbols: &[u16]) -> Vec<u8> {
        self.encode_chunk(symbols).0
    }

    /// Exact bit cost of a stream under this code (for ratio estimates).
    pub fn cost_bits(&self, hist: &[u64]) -> u64 {
        hist.iter()
            .zip(&self.table)
            .map(|(&f, &(_, l))| f * l as u64)
            .sum()
    }
}

/// Peek width of the decode loop: enough for one two-symbol LUT hit.
const PAIR_PEEK_BITS: u32 = 2 * MAX_BITS;

/// Decoder: flat two-symbol LUT of 2^max_len entries.
///
/// Entry layout (u64): `sym1[0..16] | sym2[16..32] | len1[32..40] |
/// len_pair[40..48] | count[48..50]`. `count` is 0 for an invalid window,
/// 1 when only the first code is determined by the window, 2 when a
/// complete second code also fits — the hot loop then emits both symbols
/// from a single peek/consume.
pub struct Decoder {
    lut: Vec<u64>,
    max_len: u32,
}

impl Decoder {
    pub fn from_lengths(lens: &[u8]) -> Result<Self> {
        let max_len = lens.iter().copied().max().unwrap_or(0) as u32;
        if max_len == 0 {
            return Ok(Self { lut: vec![], max_len: 0 });
        }
        if max_len > MAX_BITS {
            return Err(VszError::format(format!("huffman length {max_len} > {MAX_BITS}")));
        }
        let codes = canonical_codes(lens);
        // single-symbol LUT first (sym in low 16, len in bits 16..24)
        let mut single = vec![u32::MAX; 1usize << max_len];
        for (sym, &(code, len)) in codes.iter().enumerate() {
            if len == 0 {
                continue;
            }
            let rev = reverse_bits(code, len) as usize;
            let step = 1usize << len;
            let entry = (sym as u32) | ((len as u32) << 16);
            let mut idx = rev;
            while idx < single.len() {
                if single[idx] != u32::MAX {
                    return Err(VszError::format("huffman: overlapping codes (bad lengths)"));
                }
                single[idx] = entry;
                idx += step;
            }
        }
        // derive the two-symbol LUT: after consuming len1 bits the next
        // bits of the window are idx >> len1 (zero-extended), so the
        // second code is determined exactly when its length fits the
        // remaining window.
        let mut lut = vec![0u64; single.len()];
        for (idx, e) in lut.iter_mut().enumerate() {
            let e1 = single[idx];
            if e1 == u32::MAX {
                continue;
            }
            let s1 = (e1 & 0xFFFF) as u64;
            let l1 = (e1 >> 16) as u64;
            let mut packed = s1 | (l1 << 32) | (l1 << 40) | (1u64 << 48);
            let rem = max_len as u64 - l1;
            if rem > 0 {
                let e2 = single[idx >> l1];
                if e2 != u32::MAX {
                    let l2 = (e2 >> 16) as u64;
                    if l2 <= rem {
                        packed = s1
                            | (((e2 & 0xFFFF) as u64) << 16)
                            | (l1 << 32)
                            | ((l1 + l2) << 40)
                            | (2u64 << 48);
                    }
                }
            }
            *e = packed;
        }
        Ok(Self { lut, max_len })
    }

    /// Decode exactly `out.len()` symbols from `r` into `out`. Writing
    /// into a caller-sized slice (instead of pushing to a `Vec`) is what
    /// lets gap-array segments of one chunk decode concurrently into
    /// disjoint windows of the final output.
    fn decode_into_slice(&self, r: &mut BitReader, out: &mut [u16]) -> Result<()> {
        let n = out.len();
        if n == 0 {
            return Ok(());
        }
        if self.max_len == 0 {
            return Err(VszError::format("huffman: truncated stream"));
        }
        let mask = (1usize << self.max_len) - 1;
        let mut i = 0usize;
        while i < n {
            // peek wide enough that a pair consume never outruns the
            // refill window (PAIR_PEEK_BITS >= len_pair)
            let idx = (r.peek(PAIR_PEEK_BITS) as usize) & mask;
            let e = self.lut[idx];
            if e == 0 {
                return Err(VszError::format("huffman: invalid code"));
            }
            if (e >> 48) == 2 && n - i >= 2 {
                let lp = ((e >> 40) & 0xFF) as u32;
                if r.remaining_bits() >= lp as u64 {
                    r.consume(lp);
                    out[i] = e as u16;
                    out[i + 1] = (e >> 16) as u16;
                    i += 2;
                    continue;
                }
            }
            let l1 = ((e >> 32) & 0xFF) as u32;
            if r.remaining_bits() < l1 as u64 {
                return Err(VszError::format("huffman: stream underrun"));
            }
            r.consume(l1);
            out[i] = e as u16;
            i += 1;
        }
        Ok(())
    }

    /// Decode exactly `count` symbols.
    pub fn decode_all(&self, bytes: &[u8], count: usize) -> Result<Vec<u16>> {
        let mut out = vec![0u16; count];
        let mut r = BitReader::new(bytes);
        self.decode_into_slice(&mut r, &mut out)?;
        Ok(out)
    }

    /// Decode one HUF2 chunk: exactly `count` symbols that must consume
    /// exactly `bit_len` bits (the length the encoder recorded in the
    /// chunk offset table) — a strong cheap integrity check.
    pub fn decode_chunk(&self, bytes: &[u8], count: usize, bit_len: u64) -> Result<Vec<u16>> {
        let mut out = vec![0u16; count];
        self.decode_segment(bytes, 0, bit_len, &mut out)?;
        Ok(out)
    }

    /// Decode one gap-array segment into `out` (exactly `out.len()`
    /// symbols). `bytes` must start at the byte containing the segment's
    /// first bit; `skip_bits` (< 8) discards the tail of the previous
    /// segment sharing that byte. Decoding must consume exactly
    /// `span_bits` bits past the skip — the HUF2 chunk integrity check,
    /// applied per segment, so a corrupt gap offset can never mis-decode
    /// silently.
    pub fn decode_segment(
        &self,
        bytes: &[u8],
        skip_bits: u32,
        span_bits: u64,
        out: &mut [u16],
    ) -> Result<()> {
        let mut r = BitReader::new(bytes);
        if skip_bits > 0 && r.get(skip_bits).is_none() {
            return Err(VszError::format("huffman: truncated segment"));
        }
        self.decode_into_slice(&mut r, out)?;
        let consumed = bytes.len() as u64 * 8 - r.remaining_bits() - skip_bits as u64;
        if consumed != span_bits {
            return Err(VszError::format("huffman: segment bit length mismatch"));
        }
        Ok(())
    }
}

/// Serialize code lengths sparsely: varint n_pairs, then (delta-sym, len).
pub fn write_lengths(out: &mut Vec<u8>, lens: &[u8]) {
    let pairs: Vec<(usize, u8)> =
        lens.iter().enumerate().filter(|(_, &l)| l > 0).map(|(s, &l)| (s, l)).collect();
    put_uvarint(out, lens.len() as u64);
    put_uvarint(out, pairs.len() as u64);
    let mut prev = 0usize;
    for (s, l) in pairs {
        put_uvarint(out, (s - prev) as u64);
        out.push(l);
        prev = s;
    }
}

/// Parse lengths written by [`write_lengths`]; returns (lens, bytes read).
pub fn read_lengths(data: &[u8]) -> Result<(Vec<u8>, usize)> {
    let mut pos = 0usize;
    let varint = |pos: &mut usize| -> Result<u64> {
        let (v, n) =
            get_uvarint(&data[*pos..]).ok_or_else(|| VszError::format("huffman header EOF"))?;
        *pos += n;
        Ok(v)
    };
    let alphabet = varint(&mut pos)? as usize;
    let npairs = varint(&mut pos)? as usize;
    if alphabet > 1 << 20 {
        return Err(VszError::format("huffman: absurd alphabet size"));
    }
    let mut lens = vec![0u8; alphabet];
    let mut sym = 0usize;
    for i in 0..npairs {
        let delta = varint(&mut pos)? as usize;
        sym = if i == 0 { delta } else { sym + delta };
        let l = *data.get(pos).ok_or_else(|| VszError::format("huffman header EOF"))?;
        pos += 1;
        if sym >= alphabet || l as u32 > MAX_BITS {
            return Err(VszError::format("huffman: bad (symbol,length) pair"));
        }
        lens[sym] = l;
    }
    Ok((lens, pos))
}

/// One-call stream compression, legacy unframed format: header (lengths) +
/// varint count + one monolithic payload. Kept as the format of the small
/// internal token streams in [`crate::lossless`] and for backward
/// compatibility with pre-HUF2 containers; the container CODES sections use
/// [`compress_u16_chunked`].
pub fn compress_u16(symbols: &[u16], alphabet: usize) -> Vec<u8> {
    let hist = histogram(symbols, alphabet);
    let lens = code_lengths(&hist, MAX_BITS);
    let enc = Encoder::from_lengths(&lens);
    let mut out = Vec::new();
    write_lengths(&mut out, &lens);
    put_uvarint(&mut out, symbols.len() as u64);
    let payload = enc.encode_all(symbols);
    out.extend_from_slice(&payload);
    out
}

/// Chunked HUF2 compression (see the module doc for the layout):
/// one shared code table, then the symbols encoded in fixed
/// [`CHUNK_SYMS`]-sized chunks — concurrently on `pool` when given — with
/// a per-chunk (symbol-count, bit-length) offset table so the decoder can
/// fan chunks out. Output bytes are identical for every `pool`
/// width (including `None`): chunk geometry depends only on the input.
pub fn compress_u16_chunked(
    symbols: &[u16],
    alphabet: usize,
    pool: Option<&ThreadPool>,
) -> Vec<u8> {
    let hist = histogram_pooled(symbols, alphabet, pool);
    let lens = code_lengths(&hist, MAX_BITS);
    let enc = Encoder::from_lengths(&lens);
    let n_chunks = symbols.len().div_ceil(CHUNK_SYMS);
    let encode_one = |i: usize| {
        let lo = i * CHUNK_SYMS;
        let hi = (lo + CHUNK_SYMS).min(symbols.len());
        enc.encode_chunk(&symbols[lo..hi])
    };
    let chunks: Vec<(Vec<u8>, u64)> = match pool {
        Some(pool) if n_chunks > 1 && pool.threads() > 1 => {
            pool.scoped_scatter_gather(n_chunks, encode_one)
        }
        _ => (0..n_chunks).map(encode_one).collect(),
    };

    let payload_len: usize = chunks.iter().map(|(b, _)| b.len()).sum();
    let mut out = Vec::with_capacity(payload_len + 8 * n_chunks + 64);
    out.extend_from_slice(&HUF2_MAGIC);
    write_lengths(&mut out, &lens);
    put_uvarint(&mut out, CHUNK_SYMS as u64);
    put_uvarint(&mut out, n_chunks as u64);
    for (i, (_, bits)) in chunks.iter().enumerate() {
        let lo = i * CHUNK_SYMS;
        let hi = (lo + CHUNK_SYMS).min(symbols.len());
        put_uvarint(&mut out, (hi - lo) as u64);
        put_uvarint(&mut out, *bits);
    }
    for (bytes, _) in &chunks {
        out.extend_from_slice(bytes);
    }
    out
}

/// Everything one HUF3 chunk contributes to the payload.
struct FramedChunk {
    flags: u8,
    table: Vec<u8>, // serialized local code table (empty = shared table)
    gaps: Vec<u8>,  // CRC-guarded gap blob (empty = no gap array)
    stream: Vec<u8>,
    bits: u64,
    sym_count: usize,
}

/// Framed HUF3 compression (see the module doc for the layout): the HUF2
/// chunk geometry plus per-chunk gap arrays and size-gated local code
/// tables. Chunks encode concurrently on `pool` when given; geometry and
/// the local-table gate depend only on the input, so the output bytes are
/// identical for every `pool` width (including `None`).
pub fn compress_u16_framed(
    symbols: &[u16],
    alphabet: usize,
    pool: Option<&ThreadPool>,
    opts: &EntropyOptions,
) -> Vec<u8> {
    // pair-encode alignment: resync points may only sit on even symbol
    // boundaries, so an odd requested interval rounds up
    let gap_interval =
        if opts.gap_interval == 0 { 0 } else { opts.gap_interval.max(2).next_multiple_of(2) };
    let hist = histogram_pooled(symbols, alphabet, pool);
    let lens = code_lengths(&hist, MAX_BITS);
    let shared = Encoder::from_lengths(&lens);
    let n_chunks = symbols.len().div_ceil(CHUNK_SYMS);

    let encode_one = |i: usize| -> FramedChunk {
        let lo = i * CHUNK_SYMS;
        let hi = (lo + CHUNK_SYMS).min(symbols.len());
        let chunk = &symbols[lo..hi];
        let mut flags = 0u8;
        let mut table = Vec::new();
        let mut local_enc = None;
        if opts.per_chunk_tables {
            // size gate: the local table pays its own header and must
            // still beat the shared table by LOCAL_TABLE_MIN_GAIN bytes
            let ch_hist = histogram(chunk, alphabet);
            let shared_bytes = shared.cost_bits(&ch_hist).div_ceil(8);
            let local_lens = code_lengths(&ch_hist, MAX_BITS);
            let mut hdr = Vec::new();
            write_lengths(&mut hdr, &local_lens);
            let local = Encoder::from_lengths(&local_lens);
            let local_bytes = local.cost_bits(&ch_hist).div_ceil(8) + hdr.len() as u64;
            if local_bytes + LOCAL_TABLE_MIN_GAIN <= shared_bytes {
                flags |= CHUNK_LOCAL_TABLE;
                table = hdr;
                local_enc = Some(local);
            }
        }
        let enc = local_enc.as_ref().unwrap_or(&shared);
        let (stream, bits, gap_offsets) = if gap_interval != 0 && chunk.len() > gap_interval {
            enc.encode_chunk_gaps(chunk, gap_interval)
        } else {
            let (s, b) = enc.encode_chunk(chunk);
            (s, b, Vec::new())
        };
        let mut gaps = Vec::new();
        if !gap_offsets.is_empty() {
            flags |= CHUNK_GAP_ARRAY;
            let mut blob = Vec::with_capacity(3 * gap_offsets.len() + 4);
            put_uvarint(&mut blob, gap_offsets.len() as u64);
            let mut prev = 0u64;
            for &off in &gap_offsets {
                put_uvarint(&mut blob, off - prev);
                prev = off;
            }
            gaps.reserve(blob.len() + 4);
            gaps.extend_from_slice(&crate::util::crc32(&blob).to_le_bytes());
            gaps.extend_from_slice(&blob);
        }
        FramedChunk { flags, table, gaps, stream, bits, sym_count: chunk.len() }
    };
    let chunks: Vec<FramedChunk> = match pool {
        Some(pool) if n_chunks > 1 && pool.threads() > 1 => {
            pool.scoped_scatter_gather(n_chunks, encode_one)
        }
        _ => (0..n_chunks).map(encode_one).collect(),
    };

    let payload_len: usize =
        chunks.iter().map(|c| c.table.len() + c.gaps.len() + c.stream.len()).sum();
    let mut out = Vec::with_capacity(payload_len + 12 * n_chunks + 64);
    out.extend_from_slice(&HUF3_MAGIC);
    write_lengths(&mut out, &lens);
    put_uvarint(&mut out, CHUNK_SYMS as u64);
    put_uvarint(&mut out, gap_interval as u64);
    put_uvarint(&mut out, n_chunks as u64);
    for c in &chunks {
        out.push(c.flags);
        put_uvarint(&mut out, c.sym_count as u64);
        put_uvarint(&mut out, c.bits);
        if c.flags & CHUNK_LOCAL_TABLE != 0 {
            put_uvarint(&mut out, c.table.len() as u64);
        }
        if c.flags & CHUNK_GAP_ARRAY != 0 {
            put_uvarint(&mut out, c.gaps.len() as u64);
        }
    }
    for c in &chunks {
        out.extend_from_slice(&c.table);
        out.extend_from_slice(&c.gaps);
        out.extend_from_slice(&c.stream);
    }
    out
}

/// Inverse of [`compress_u16`]/[`compress_u16_chunked`]/
/// [`compress_u16_framed`] (dispatches on the HUF2/HUF3 magics), serial.
pub fn decompress_u16(data: &[u8]) -> Result<Vec<u16>> {
    decompress_u16_pooled(data, None)
}

/// Like [`decompress_u16`], but HUF2 chunks and HUF3 gap-array segments
/// are decoded concurrently on `pool` when given (legacy payloads are one
/// bit-serial stream, so they always decode on the calling thread).
pub fn decompress_u16_pooled(data: &[u8], pool: Option<&ThreadPool>) -> Result<Vec<u16>> {
    if data.starts_with(&HUF2_MAGIC) {
        return decompress_huf2(data, pool);
    }
    if data.starts_with(&HUF3_MAGIC) {
        return decompress_huf3(data, pool);
    }
    let (lens, mut pos) = read_lengths(data)?;
    let (count, n) =
        get_uvarint(&data[pos..]).ok_or_else(|| VszError::format("huffman count EOF"))?;
    pos += n;
    if count == 0 {
        return Ok(Vec::new());
    }
    // every symbol consumes at least one bit, so a forged count can never
    // exceed the remaining payload bits — reject before allocating
    if count > (data.len() - pos) as u64 * 8 {
        return Err(VszError::format("huffman: count exceeds payload"));
    }
    let dec = Decoder::from_lengths(&lens)?;
    dec.decode_all(&data[pos..], count as usize)
}

fn decompress_huf2(data: &[u8], pool: Option<&ThreadPool>) -> Result<Vec<u16>> {
    let body = &data[HUF2_MAGIC.len()..];
    let (lens, mut pos) = read_lengths(body)?;
    let varint = |pos: &mut usize| -> Result<u64> {
        let (v, n) =
            get_uvarint(&body[*pos..]).ok_or_else(|| VszError::format("HUF2 header EOF"))?;
        *pos += n;
        Ok(v)
    };
    let chunk_syms = varint(&mut pos)? as usize;
    if chunk_syms == 0 || chunk_syms > 1 << 28 {
        return Err(VszError::format("huffman: bad HUF2 chunk size"));
    }
    let n_chunks = varint(&mut pos)?;
    // every offset-table entry takes at least two bytes, so a forged count
    // can never exceed the remaining header bytes — reject before reading
    if n_chunks > (body.len() - pos) as u64 / 2 {
        return Err(VszError::format("huffman: HUF2 chunk count exceeds payload"));
    }
    let n_chunks = n_chunks as usize;

    // offset table: (symbol count, bit length, byte offset) per chunk
    let mut table: Vec<(usize, u64, u64)> = Vec::with_capacity(n_chunks.min(1 << 16));
    let mut total_syms = 0u64;
    let mut total_bytes = 0u64;
    for i in 0..n_chunks {
        let sym_count = varint(&mut pos)? as usize;
        let bit_len = varint(&mut pos)?;
        let last = i + 1 == n_chunks;
        if sym_count == 0 || sym_count > chunk_syms || (!last && sym_count != chunk_syms) {
            return Err(VszError::format("huffman: bad HUF2 chunk symbol count"));
        }
        if bit_len < sym_count as u64 || bit_len > sym_count as u64 * MAX_BITS as u64 {
            return Err(VszError::format("huffman: bad HUF2 chunk bit length"));
        }
        table.push((sym_count, bit_len, total_bytes));
        total_syms += sym_count as u64;
        total_bytes += bit_len.div_ceil(8);
    }
    let payload = &body[pos..];
    if payload.len() as u64 != total_bytes {
        return Err(VszError::format("huffman: HUF2 payload length mismatch"));
    }
    if n_chunks == 0 {
        return Ok(Vec::new());
    }

    let dec = Decoder::from_lengths(&lens)?;
    let decode_one = |i: usize| -> Result<Vec<u16>> {
        let (count, bits, off) = table[i];
        let lo = off as usize;
        let hi = lo + bits.div_ceil(8) as usize;
        dec.decode_chunk(&payload[lo..hi], count, bits)
    };
    let parts: Vec<Result<Vec<u16>>> = match pool {
        Some(pool) if n_chunks > 1 && pool.threads() > 1 => {
            pool.scoped_scatter_gather(n_chunks, decode_one)
        }
        _ => (0..n_chunks).map(decode_one).collect(),
    };
    let mut out = Vec::with_capacity(total_syms as usize);
    for part in parts {
        out.extend_from_slice(&part?);
    }
    Ok(out)
}

/// One chunk entry of a HUF3 payload header.
struct Huf3Entry {
    flags: u8,
    sym_count: usize,
    bit_len: u64,
    table_len: usize,
    gap_len: usize,
}

/// Parsed HUF3 header: shared lengths, geometry, chunk entries, and the
/// absolute offset where the concatenated per-chunk payload starts.
struct Huf3Header {
    lens: Vec<u8>,
    gap_interval: usize,
    entries: Vec<Huf3Entry>,
    payload_start: usize,
}

/// Validate and parse everything before the HUF3 payload bytes. Shared by
/// [`decompress_u16_pooled`] and [`inspect_payload`] so the two can never
/// disagree on what a well-formed header is.
fn parse_huf3_header(data: &[u8]) -> Result<Huf3Header> {
    let body = &data[HUF3_MAGIC.len()..];
    let (lens, mut pos) = read_lengths(body)?;
    let varint = |pos: &mut usize| -> Result<u64> {
        let (v, n) =
            get_uvarint(&body[*pos..]).ok_or_else(|| VszError::format("HUF3 header EOF"))?;
        *pos += n;
        Ok(v)
    };
    let chunk_syms = varint(&mut pos)? as usize;
    if chunk_syms == 0 || chunk_syms > 1 << 28 {
        return Err(VszError::format("huffman: bad HUF3 chunk size"));
    }
    // odd intervals can never come from the pair-aligned encoder
    let gap_interval = varint(&mut pos)? as usize;
    if gap_interval % 2 != 0 {
        return Err(VszError::format("huffman: bad HUF3 gap interval"));
    }
    let n_chunks = varint(&mut pos)?;
    // every chunk entry takes at least three bytes (flags + two varints),
    // so a forged count can never exceed the remaining header bytes
    if n_chunks > (body.len() - pos) as u64 / 3 {
        return Err(VszError::format("huffman: HUF3 chunk count exceeds payload"));
    }
    let n_chunks = n_chunks as usize;
    let mut entries: Vec<Huf3Entry> = Vec::with_capacity(n_chunks.min(1 << 16));
    for i in 0..n_chunks {
        let flags = *body.get(pos).ok_or_else(|| VszError::format("HUF3 header EOF"))?;
        pos += 1;
        if flags & !(CHUNK_LOCAL_TABLE | CHUNK_GAP_ARRAY) != 0 {
            return Err(VszError::format("huffman: unknown HUF3 chunk flags"));
        }
        let sym_count = varint(&mut pos)? as usize;
        let bit_len = varint(&mut pos)?;
        let last = i + 1 == n_chunks;
        if sym_count == 0 || sym_count > chunk_syms || (!last && sym_count != chunk_syms) {
            return Err(VszError::format("huffman: bad HUF3 chunk symbol count"));
        }
        if bit_len < sym_count as u64 || bit_len > sym_count as u64 * MAX_BITS as u64 {
            return Err(VszError::format("huffman: bad HUF3 chunk bit length"));
        }
        let table_len =
            if flags & CHUNK_LOCAL_TABLE != 0 { varint(&mut pos)? as usize } else { 0 };
        let gap_len = if flags & CHUNK_GAP_ARRAY != 0 {
            if gap_interval == 0 || sym_count <= gap_interval {
                return Err(VszError::format("huffman: HUF3 gap array on unsplittable chunk"));
            }
            varint(&mut pos)? as usize
        } else {
            0
        };
        entries.push(Huf3Entry { flags, sym_count, bit_len, table_len, gap_len });
    }
    Ok(Huf3Header { lens, gap_interval, entries, payload_start: HUF3_MAGIC.len() + pos })
}

/// One decode unit of a HUF3 payload: a whole chunk when it has no gap
/// array, otherwise one gap segment of a chunk.
struct Huf3Seg {
    chunk: usize,   // selects the decoder (shared vs chunk-local)
    out_off: usize, // absolute symbol offset into the output
    count: usize,
    byte_lo: usize, // absolute payload byte range holding the bits
    byte_hi: usize,
    skip_bits: u32, // sub-byte start position inside byte_lo
    span_bits: u64, // exact bits the segment must consume
}

fn decompress_huf3(data: &[u8], pool: Option<&ThreadPool>) -> Result<Vec<u16>> {
    let h = parse_huf3_header(data)?;
    let payload = &data[h.payload_start..];
    let gap_interval = h.gap_interval;

    // region walk: per chunk [local table][gap blob][bitstream], with
    // overflow-safe bounds so forged lengths reject instead of wrapping
    struct ChunkRegions {
        table: std::ops::Range<usize>,
        gaps: std::ops::Range<usize>,
        stream_start: usize,
        sym_off: usize,
    }
    let mut regions: Vec<ChunkRegions> = Vec::with_capacity(h.entries.len());
    let mut off = 0usize;
    let mut total_syms = 0usize;
    for e in &h.entries {
        let stream_len = e.bit_len.div_ceil(8) as usize;
        let need = e
            .table_len
            .checked_add(e.gap_len)
            .and_then(|v| v.checked_add(stream_len))
            .filter(|&v| v <= payload.len() - off)
            .ok_or_else(|| VszError::format("huffman: HUF3 payload overrun"))?;
        let t0 = off;
        let g0 = t0 + e.table_len;
        let s0 = g0 + e.gap_len;
        regions.push(ChunkRegions {
            table: t0..g0,
            gaps: g0..s0,
            stream_start: s0,
            sym_off: total_syms,
        });
        off += need;
        total_syms += e.sym_count;
    }
    if off != payload.len() {
        return Err(VszError::format("huffman: HUF3 payload length mismatch"));
    }
    if h.entries.is_empty() {
        return Ok(Vec::new());
    }

    // decoders: the shared table once (when any chunk uses it), plus one
    // per local-table chunk — the LUT build is the real per-chunk cost,
    // so local tables build concurrently on the pool
    let needs_shared = h.entries.iter().any(|e| e.flags & CHUNK_LOCAL_TABLE == 0);
    let shared_dec = if needs_shared { Some(Decoder::from_lengths(&h.lens)?) } else { None };
    let local_idx: Vec<usize> =
        (0..h.entries.len()).filter(|&i| h.entries[i].flags & CHUNK_LOCAL_TABLE != 0).collect();
    let build_one = |k: usize| -> Result<Decoder> {
        let ci = local_idx[k];
        let (llens, used) = read_lengths(&payload[regions[ci].table.clone()])?;
        if used != h.entries[ci].table_len {
            return Err(VszError::format("huffman: HUF3 local table length mismatch"));
        }
        Decoder::from_lengths(&llens)
    };
    let built: Vec<Result<Decoder>> = match pool {
        Some(pool) if local_idx.len() > 1 && pool.threads() > 1 => {
            pool.scoped_scatter_gather(local_idx.len(), build_one)
        }
        _ => (0..local_idx.len()).map(build_one).collect(),
    };
    let mut decoders: Vec<Option<Decoder>> = (0..h.entries.len()).map(|_| None).collect();
    for (k, d) in built.into_iter().enumerate() {
        decoders[local_idx[k]] = Some(d?);
    }

    // flatten every chunk into its decode segments; gap blobs are CRC- and
    // bounds-checked here, before any worker touches the bitstream
    let mut segs: Vec<Huf3Seg> = Vec::new();
    for (ci, (e, c)) in h.entries.iter().zip(&regions).enumerate() {
        let mut bounds: Vec<u64> = vec![0];
        if e.flags & CHUNK_GAP_ARRAY != 0 {
            let blob = &payload[c.gaps.clone()];
            if blob.len() < 5 {
                return Err(VszError::format("huffman: HUF3 gap blob truncated"));
            }
            let stored = u32::from_le_bytes(blob[..4].try_into().unwrap());
            if crate::util::crc32(&blob[4..]) != stored {
                return Err(VszError::format("huffman: HUF3 gap array CRC mismatch"));
            }
            let mut gpos = 4usize;
            let gvar = |gpos: &mut usize| -> Result<u64> {
                let (v, n) = get_uvarint(&blob[*gpos..])
                    .ok_or_else(|| VszError::format("huffman: HUF3 gap blob EOF"))?;
                *gpos += n;
                Ok(v)
            };
            let n_points = gvar(&mut gpos)? as usize;
            if n_points != e.sym_count.div_ceil(gap_interval) - 1 {
                return Err(VszError::format("huffman: HUF3 gap point count mismatch"));
            }
            bounds.reserve(n_points + 1);
            let mut prev = 0u64;
            for _ in 0..n_points {
                let delta = gvar(&mut gpos)?;
                if delta == 0 {
                    return Err(VszError::format("huffman: HUF3 gap offsets not increasing"));
                }
                prev = prev
                    .checked_add(delta)
                    .filter(|&v| v < e.bit_len)
                    .ok_or_else(|| VszError::format("huffman: HUF3 gap offset out of range"))?;
                bounds.push(prev);
            }
            if gpos != blob.len() {
                return Err(VszError::format("huffman: HUF3 gap blob trailing bytes"));
            }
        }
        bounds.push(e.bit_len);
        let seg_syms = if bounds.len() > 2 { gap_interval } else { e.sym_count };
        for (j, w) in bounds.windows(2).enumerate() {
            let count = seg_syms.min(e.sym_count - j * seg_syms);
            let span = w[1] - w[0];
            if span < count as u64 || span > count as u64 * MAX_BITS as u64 {
                return Err(VszError::format("huffman: bad HUF3 gap segment span"));
            }
            segs.push(Huf3Seg {
                chunk: ci,
                out_off: c.sym_off + j * seg_syms,
                count,
                byte_lo: c.stream_start + (w[0] / 8) as usize,
                byte_hi: c.stream_start + w[1].div_ceil(8) as usize,
                skip_bits: (w[0] % 8) as u32,
                span_bits: span,
            });
        }
    }

    let mut out = vec![0u16; total_syms];
    let base = crate::util::SendPtr::new(out.as_mut_ptr());
    let decode_one = |i: usize| -> Result<()> {
        crate::failpoint::hit("huffman_decode")?;
        let s = &segs[i];
        let dec = decoders[s.chunk]
            .as_ref()
            .or(shared_dec.as_ref())
            .expect("decoder exists for every chunk by construction");
        // SAFETY: segment output windows [out_off, out_off + count) are
        // disjoint and partition [0, total_syms) by construction, so
        // concurrent writers never alias
        let window =
            unsafe { std::slice::from_raw_parts_mut(base.get().add(s.out_off), s.count) };
        dec.decode_segment(&payload[s.byte_lo..s.byte_hi], s.skip_bits, s.span_bits, window)
    };
    let results: Vec<Result<()>> = match pool {
        Some(pool) if segs.len() > 1 && pool.threads() > 1 => {
            pool.scoped_scatter_gather(segs.len(), decode_one)
        }
        _ => (0..segs.len()).map(decode_one).collect(),
    };
    for r in results {
        r?;
    }
    Ok(out)
}

/// Summary of an entropy payload's framing for `vsz stream inspect` and
/// the chunk autotuner — a header-only walk, no symbol decode.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EntropyInfo {
    /// `"legacy"`, `"huf2"` or `"huf3"`.
    pub framing: &'static str,
    /// Huffman chunk count (1 for a legacy payload).
    pub n_chunks: usize,
    /// HUF3 chunks carrying their own code table (0 elsewhere).
    pub local_tables: usize,
    /// Independent decode units: chunks, with gap-array chunks counting
    /// one unit per gap segment.
    pub segments: usize,
    /// Total symbol count.
    pub total_syms: u64,
}

/// Classify and summarize any payload this module ever wrote (legacy
/// unframed, HUF2, HUF3) without decoding it.
pub fn inspect_payload(data: &[u8]) -> Result<EntropyInfo> {
    if data.starts_with(&HUF3_MAGIC) {
        let h = parse_huf3_header(data)?;
        let mut info = EntropyInfo {
            framing: "huf3",
            n_chunks: h.entries.len(),
            local_tables: 0,
            segments: 0,
            total_syms: 0,
        };
        for e in &h.entries {
            info.total_syms += e.sym_count as u64;
            info.local_tables += (e.flags & CHUNK_LOCAL_TABLE != 0) as usize;
            info.segments += if e.flags & CHUNK_GAP_ARRAY != 0 {
                e.sym_count.div_ceil(h.gap_interval)
            } else {
                1
            };
        }
        return Ok(info);
    }
    if data.starts_with(&HUF2_MAGIC) {
        let body = &data[HUF2_MAGIC.len()..];
        let (_, mut pos) = read_lengths(body)?;
        let varint = |pos: &mut usize| -> Result<u64> {
            let (v, n) =
                get_uvarint(&body[*pos..]).ok_or_else(|| VszError::format("HUF2 header EOF"))?;
            *pos += n;
            Ok(v)
        };
        varint(&mut pos)?; // chunk size
        let n_chunks = varint(&mut pos)?;
        if n_chunks > (body.len() - pos) as u64 / 2 {
            return Err(VszError::format("huffman: HUF2 chunk count exceeds payload"));
        }
        let mut total_syms = 0u64;
        for _ in 0..n_chunks {
            total_syms += varint(&mut pos)?;
            varint(&mut pos)?; // bit length
        }
        let n_chunks = n_chunks as usize;
        return Ok(EntropyInfo {
            framing: "huf2",
            n_chunks,
            local_tables: 0,
            segments: n_chunks,
            total_syms,
        });
    }
    let (_, pos) = read_lengths(data)?;
    let (count, _) =
        get_uvarint(&data[pos..]).ok_or_else(|| VszError::format("huffman count EOF"))?;
    Ok(EntropyInfo {
        framing: "legacy",
        n_chunks: 1,
        local_tables: 0,
        segments: 1,
        total_syms: count,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;

    /// The skewed quant-code-like stream used across the entropy tests.
    fn skewed_codes(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = Pcg32::seeded(seed);
        let radius = 512u16;
        (0..n)
            .map(|_| {
                let r = rng.next_f32();
                if r < 0.8 {
                    radius
                } else if r < 0.95 {
                    radius + 1 - (rng.bounded(3) as u16)
                } else {
                    radius - 8 + rng.bounded(16) as u16
                }
            })
            .collect()
    }

    #[test]
    fn interleaved_histogram_matches_naive_reference() {
        // cover both sides of UNROLL_HIST_MIN and every remainder length
        let mut rng = Pcg32::seeded(77);
        for n in [0usize, 1, 3, 100, 4095, 4096, 4097, 4098, 4099, 20_000] {
            let syms: Vec<u16> = (0..n).map(|_| rng.bounded(1024) as u16).collect();
            let mut reference = vec![0u64; 1024];
            for &s in &syms {
                reference[s as usize] += 1;
            }
            assert_eq!(histogram(&syms, 1024), reference, "n={n}");
        }
        // heavily skewed stream (the case the interleave exists for)
        let syms = skewed_codes(50_000, 9);
        let mut reference = vec![0u64; 1024];
        for &s in &syms {
            reference[s as usize] += 1;
        }
        assert_eq!(histogram(&syms, 1024), reference);
        // a huge alphabet with a smallish stream stays on (and matches)
        // the naive path — the gate scales with alphabet size
        let syms: Vec<u16> = (0..10_000).map(|_| rng.bounded(60_000) as u16).collect();
        let mut reference = vec![0u64; 65_536];
        for &s in &syms {
            reference[s as usize] += 1;
        }
        assert_eq!(histogram(&syms, 65_536), reference);
    }

    #[test]
    fn lengths_satisfy_kraft() {
        let freqs = vec![100u64, 50, 20, 10, 5, 2, 1, 1];
        let lens = code_lengths(&freqs, MAX_BITS);
        let kraft: f64 = lens.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-12);
        // optimal Huffman on this distribution is exactly Kraft-tight
        assert!((kraft - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_symbol_stream() {
        let syms = vec![7u16; 1000];
        let blob = compress_u16(&syms, 16);
        assert!(blob.len() < 200); // ~1 bit per symbol
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
    }

    #[test]
    fn empty_stream() {
        let blob = compress_u16(&[], 16);
        assert_eq!(decompress_u16(&blob).unwrap(), Vec::<u16>::new());
    }

    #[test]
    fn skewed_quant_code_stream_compresses_hard() {
        // mimic dual-quant output: mass at `radius`, tails around it
        let syms = skewed_codes(100_000, 9);
        let blob = compress_u16(&syms, 1024);
        // entropy of this distribution is ~1.2 bits/sym; 16-bit raw = 200KB
        assert!(blob.len() < 40_000, "blob {} bytes", blob.len());
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
    }

    #[test]
    fn length_limit_enforced_on_pathological_freqs() {
        // fibonacci-ish frequencies force long codes without a limit
        let mut freqs = vec![0u64; 40];
        let (mut a, mut b) = (1u64, 1u64);
        for f in freqs.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let lens = code_lengths(&freqs, MAX_BITS);
        assert!(lens.iter().all(|&l| l as u32 <= MAX_BITS));
        let kraft: f64 = lens.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-12);
        // still decodable end-to-end
        let mut syms = Vec::new();
        for (s, &f) in freqs.iter().enumerate() {
            for _ in 0..(f.min(50)) {
                syms.push(s as u16);
            }
        }
        let blob = compress_u16(&syms, 40);
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
    }

    #[test]
    fn kraft_repair_monotone_in_original_depth() {
        // after repair, a symbol that sat deeper in the unlimited tree must
        // never end up with a shorter code than a shallower one
        let mut freqs = vec![0u64; 64];
        let (mut a, mut b) = (1u64, 1u64);
        for f in freqs.iter_mut() {
            *f = a;
            let c = a.saturating_add(b);
            a = b;
            b = c;
        }
        let unlimited = code_lengths(&freqs, 60);
        let limited = code_lengths(&freqs, MAX_BITS);
        for i in 0..freqs.len() {
            for j in 0..freqs.len() {
                if unlimited[i] < unlimited[j] {
                    assert!(
                        limited[i] <= limited[j],
                        "depth order inverted: {i} ({}->{}) vs {j} ({}->{})",
                        unlimited[i],
                        limited[i],
                        unlimited[j],
                        limited[j]
                    );
                }
            }
        }
    }

    #[test]
    fn header_roundtrip_sparse() {
        let mut lens = vec![0u8; 1024];
        lens[0] = 3;
        lens[511] = 2;
        lens[512] = 1;
        lens[1023] = 3;
        let mut buf = Vec::new();
        write_lengths(&mut buf, &lens);
        let (got, used) = read_lengths(&buf).unwrap();
        assert_eq!(got, lens);
        assert_eq!(used, buf.len());
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        check("huffman-roundtrip", 60, |g| {
            let n = g.len() * 50;
            let alphabet = *g.choose(&[2usize, 17, 256, 1024]);
            let syms: Vec<u16> = (0..n)
                .map(|_| {
                    // zipf-ish skew: square the uniform
                    let u = g.rng.next_f32();
                    ((u * u * (alphabet as f32 - 1.0)) as u16).min(alphabet as u16 - 1)
                })
                .collect();
            let blob = compress_u16(&syms, alphabet);
            let back = decompress_u16(&blob).map_err(|e| e.to_string())?;
            if back == syms {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }

    #[test]
    fn prop_roundtrip_chunked_matches_input() {
        check("huffman-huf2-roundtrip", 40, |g| {
            let n = g.len() * 50;
            let alphabet = *g.choose(&[2usize, 17, 256, 1024]);
            let syms: Vec<u16> = (0..n)
                .map(|_| {
                    let u = g.rng.next_f32();
                    ((u * u * (alphabet as f32 - 1.0)) as u16).min(alphabet as u16 - 1)
                })
                .collect();
            let blob = compress_u16_chunked(&syms, alphabet, None);
            let back = decompress_u16(&blob).map_err(|e| e.to_string())?;
            if back == syms {
                Ok(())
            } else {
                Err("chunked roundtrip mismatch".into())
            }
        });
    }

    #[test]
    fn decoder_rejects_garbage() {
        assert!(decompress_u16(&[0xFF, 0xFF, 0xFF]).is_err());
    }

    // ------------------------------------------------------ HUF2 chunked

    #[test]
    fn chunked_empty_and_tiny_streams() {
        let blob = compress_u16_chunked(&[], 16, None);
        assert_eq!(decompress_u16(&blob).unwrap(), Vec::<u16>::new());
        let syms = vec![7u16; 3];
        let blob = compress_u16_chunked(&syms, 16, None);
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
    }

    #[test]
    fn chunked_multi_chunk_roundtrip_serial_and_pooled() {
        // > 3 chunks so the offset table and the stitched payload are real
        let syms = skewed_codes(3 * CHUNK_SYMS + 1234, 21);
        let blob = compress_u16_chunked(&syms, 1024, None);
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
        let pool = ThreadPool::new(4);
        assert_eq!(decompress_u16_pooled(&blob, Some(&pool)).unwrap(), syms);
    }

    #[test]
    fn chunked_encode_is_thread_count_deterministic() {
        // 1, 2 and 7 workers must produce byte-identical payloads
        let syms = skewed_codes(2 * CHUNK_SYMS + 777, 23);
        let serial = compress_u16_chunked(&syms, 1024, None);
        for nthreads in [2usize, 7] {
            let pool = ThreadPool::new(nthreads);
            let par = compress_u16_chunked(&syms, 1024, Some(&pool));
            assert_eq!(serial, par, "{nthreads} workers changed the payload bytes");
        }
    }

    #[test]
    fn chunked_and_legacy_decode_to_the_same_symbols() {
        let syms = skewed_codes(CHUNK_SYMS + 99, 25);
        let legacy = compress_u16(&syms, 1024);
        let chunked = compress_u16_chunked(&syms, 1024, None);
        assert_ne!(legacy, chunked); // different framing...
        assert_eq!(
            decompress_u16(&legacy).unwrap(),
            decompress_u16(&chunked).unwrap() // ...same stream
        );
    }

    #[test]
    fn huf2_corruption_sweep_over_header_and_offset_table() {
        // mirror the container sweeps: flip every byte of the HUF2 header +
        // chunk offset table; decode must never panic, and whenever it
        // still succeeds the symbol count must be unchanged (content
        // integrity is the container CRC's job, one layer up).
        let syms = skewed_codes(2 * CHUNK_SYMS + 500, 27);
        let blob = compress_u16_chunked(&syms, 1024, None);
        // locate the payload start by re-walking the header
        let body = &blob[HUF2_MAGIC.len()..];
        let (_, mut pos) = read_lengths(body).unwrap();
        let (_, n1) = get_uvarint(&body[pos..]).unwrap(); // chunk size
        pos += n1;
        let (n_chunks, n2) = get_uvarint(&body[pos..]).unwrap();
        pos += n2;
        for _ in 0..n_chunks {
            let (_, a) = get_uvarint(&body[pos..]).unwrap();
            pos += a;
            let (_, b) = get_uvarint(&body[pos..]).unwrap();
            pos += b;
        }
        let header_end = HUF2_MAGIC.len() + pos;
        for at in 0..header_end {
            let mut bad = blob.clone();
            bad[at] ^= 0xA5;
            match decompress_u16(&bad) {
                Err(_) => {}
                Ok(out) => assert_eq!(
                    out.len(),
                    syms.len(),
                    "flip at {at} silently changed the symbol count"
                ),
            }
        }
        // truncation sweep: every cut must be rejected
        for cut in [0, 2, 5, header_end - 1, header_end, blob.len() / 2, blob.len() - 1] {
            assert!(decompress_u16(&blob[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn two_symbol_lut_matches_pairwise_reference_decode() {
        // decode a stream symbol-by-symbol through get() as a reference
        let syms = skewed_codes(10_000, 31);
        let hist = histogram(&syms, 1024);
        let lens = code_lengths(&hist, MAX_BITS);
        let enc = Encoder::from_lengths(&lens);
        let (payload, bits) = enc.encode_chunk(&syms);
        // reference: walk the canonical codes bit by bit
        let codes = canonical_codes(&lens);
        let by_rev: std::collections::HashMap<(u32, u8), u16> = codes
            .iter()
            .enumerate()
            .filter(|(_, &(_, l))| l > 0)
            .map(|(s, &(c, l))| ((super::reverse_bits(c, l), l), s as u16))
            .collect();
        let mut r = BitReader::new(&payload);
        let mut reference = Vec::new();
        'outer: while reference.len() < syms.len() {
            let mut code = 0u32;
            for l in 1..=MAX_BITS as u8 {
                code |= (r.get(1).unwrap() as u32) << (l - 1);
                if let Some(&s) = by_rev.get(&(code, l)) {
                    reference.push(s);
                    continue 'outer;
                }
            }
            panic!("reference decode lost sync");
        }
        assert_eq!(reference, syms);
        let dec = Decoder::from_lengths(&lens).unwrap();
        assert_eq!(dec.decode_chunk(&payload, syms.len(), bits).unwrap(), syms);
    }

    #[test]
    fn chunk_bit_length_mismatch_is_rejected() {
        let syms = skewed_codes(4096, 33);
        let hist = histogram(&syms, 1024);
        let lens = code_lengths(&hist, MAX_BITS);
        let enc = Encoder::from_lengths(&lens);
        let dec = Decoder::from_lengths(&lens).unwrap();
        let (payload, bits) = enc.encode_chunk(&syms);
        assert!(dec.decode_chunk(&payload, syms.len(), bits).is_ok());
        assert!(dec.decode_chunk(&payload, syms.len(), bits + 1).is_err());
        assert!(dec.decode_chunk(&payload, syms.len() - 1, bits).is_err());
    }

    // ------------------------------------------------------- HUF3 framed

    /// A deliberately non-stationary stream: each chunk concentrates on a
    /// different symbol neighborhood, so chunk-local code tables beat the
    /// shared table and the size gate must engage.
    fn nonstationary_codes(n: usize, seed: u64) -> Vec<u16> {
        let mut rng = Pcg32::seeded(seed);
        (0..n)
            .map(|i| {
                let center = [128u16, 512, 900][(i / CHUNK_SYMS) % 3];
                let r = rng.next_f32();
                if r < 0.8 {
                    center
                } else if r < 0.95 {
                    center + 1 - (rng.bounded(3) as u16)
                } else {
                    center - 8 + rng.bounded(16) as u16
                }
            })
            .collect()
    }

    #[test]
    fn huf3_roundtrip_with_local_tables_and_gap_arrays() {
        let syms = nonstationary_codes(2 * CHUNK_SYMS + 4321, 41);
        let blob = compress_u16_framed(&syms, 1024, None, &EntropyOptions::default());
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
        for nthreads in [2usize, 7] {
            let pool = ThreadPool::new(nthreads);
            assert_eq!(decompress_u16_pooled(&blob, Some(&pool)).unwrap(), syms);
        }
        let info = inspect_payload(&blob).unwrap();
        assert_eq!(info.framing, "huf3");
        assert_eq!(info.n_chunks, 3);
        assert_eq!(info.total_syms, syms.len() as u64);
        assert!(info.local_tables >= 1, "size gate never engaged on a non-stationary stream");
        assert!(info.segments > info.n_chunks, "no chunk carried a gap array");
        // the local tables must pay for themselves vs the shared-table form
        let huf2 = compress_u16_chunked(&syms, 1024, None);
        assert!(blob.len() < huf2.len(), "huf3 {} >= huf2 {}", blob.len(), huf2.len());
    }

    #[test]
    fn huf3_stationary_stream_keeps_the_shared_table() {
        let syms = skewed_codes(2 * CHUNK_SYMS + 99, 43);
        let blob = compress_u16_framed(&syms, 1024, None, &EntropyOptions::default());
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
        let info = inspect_payload(&blob).unwrap();
        assert_eq!(info.local_tables, 0, "local table carried where it cannot pay");
    }

    #[test]
    fn huf3_single_chunk_decodes_segment_parallel_bit_identical() {
        // the acceptance case: ONE chunk, yet the gap array lets the pool
        // split its bitstream — output must match the serial decode at
        // 1, 2 and 7 threads exactly
        let syms = skewed_codes(CHUNK_SYMS, 45);
        let blob = compress_u16_framed(&syms, 1024, None, &EntropyOptions::default());
        let info = inspect_payload(&blob).unwrap();
        assert_eq!(info.n_chunks, 1);
        assert_eq!(info.segments, CHUNK_SYMS.div_ceil(GAP_INTERVAL_SYMS));
        let serial = decompress_u16(&blob).unwrap();
        assert_eq!(serial, syms);
        for nthreads in [1usize, 2, 7] {
            let pool = ThreadPool::new(nthreads);
            assert_eq!(
                decompress_u16_pooled(&blob, Some(&pool)).unwrap(),
                serial,
                "{nthreads} threads diverged from the serial decode"
            );
        }
    }

    #[test]
    fn huf3_encode_is_thread_count_deterministic() {
        let syms = nonstationary_codes(2 * CHUNK_SYMS + 777, 47);
        let serial = compress_u16_framed(&syms, 1024, None, &EntropyOptions::default());
        for nthreads in [2usize, 7] {
            let pool = ThreadPool::new(nthreads);
            let par = compress_u16_framed(&syms, 1024, Some(&pool), &EntropyOptions::default());
            assert_eq!(serial, par, "{nthreads} workers changed the payload bytes");
        }
    }

    #[test]
    fn huf3_empty_tiny_and_option_edge_streams() {
        let blob = compress_u16_framed(&[], 16, None, &EntropyOptions::default());
        assert_eq!(decompress_u16(&blob).unwrap(), Vec::<u16>::new());
        let syms = vec![7u16; 3];
        let blob = compress_u16_framed(&syms, 16, None, &EntropyOptions::default());
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
        // gap arrays off, local tables off: plain chunked layout under HUF3
        let syms = skewed_codes(CHUNK_SYMS + 50, 49);
        let opts = EntropyOptions { gap_interval: 0, per_chunk_tables: false };
        let blob = compress_u16_framed(&syms, 1024, None, &opts);
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
        let info = inspect_payload(&blob).unwrap();
        assert_eq!((info.local_tables, info.segments), (0, 2));
        // odd interval rounds up to even (pair alignment) and still decodes
        let opts = EntropyOptions { gap_interval: 4097, per_chunk_tables: true };
        let blob = compress_u16_framed(&syms, 1024, None, &opts);
        assert_eq!(decompress_u16(&blob).unwrap(), syms);
    }

    #[test]
    fn huf3_gap_interval_shares_the_interleave_floor() {
        // the gap segment size and the interleaved-histogram floor are the
        // same measured tipping point — pin the tie so one cannot drift
        // from the other silently
        assert_eq!(GAP_INTERVAL_SYMS, UNROLL_HIST_MIN);
        // boundary equivalence: exactly at the shared constant the two
        // subsystems flip together — the histogram switches to the
        // interleaved path and the chunk stops being splittable
        for n in [UNROLL_HIST_MIN - 1, UNROLL_HIST_MIN, UNROLL_HIST_MIN + 1] {
            let syms = skewed_codes(n, 51);
            let mut reference = vec![0u64; 1024];
            for &s in &syms {
                reference[s as usize] += 1;
            }
            assert_eq!(histogram(&syms, 1024), reference, "histogram diverged at n={n}");
            let blob = compress_u16_framed(&syms, 1024, None, &EntropyOptions::default());
            let info = inspect_payload(&blob).unwrap();
            let want_segs = if n > GAP_INTERVAL_SYMS { 2 } else { 1 };
            assert_eq!(info.segments, want_segs, "gap split diverged at n={n}");
            assert_eq!(decompress_u16(&blob).unwrap(), syms);
        }
    }

    #[test]
    fn huf3_gap_array_corruption_always_rejected() {
        let syms = skewed_codes(CHUNK_SYMS, 53);
        let blob = compress_u16_framed(&syms, 1024, None, &EntropyOptions::default());
        let h = parse_huf3_header(&blob).unwrap();
        assert_eq!(h.entries.len(), 1);
        let gap_lo = h.payload_start + h.entries[0].table_len;
        let gap_hi = gap_lo + h.entries[0].gap_len;
        assert!(h.entries[0].gap_len >= 5, "fixture chunk lost its gap array");
        // every byte of the side index is under the CRC (or is the CRC):
        // any flip must be rejected, never panic, never mis-decode
        for at in gap_lo..gap_hi {
            let mut bad = blob.clone();
            bad[at] ^= 0xA5;
            assert!(decompress_u16(&bad).is_err(), "gap-blob flip at {at} accepted");
        }
    }

    #[test]
    fn huf3_corruption_sweep_over_header_never_panics() {
        // same contract as the HUF2 sweep: flips over the header + entry
        // table must error or keep the symbol count (content integrity is
        // the container CRC's job, one layer up)
        let syms = nonstationary_codes(2 * CHUNK_SYMS + 500, 55);
        let blob = compress_u16_framed(&syms, 1024, None, &EntropyOptions::default());
        let header_end = parse_huf3_header(&blob).unwrap().payload_start;
        for at in 0..header_end {
            let mut bad = blob.clone();
            bad[at] ^= 0xA5;
            match decompress_u16(&bad) {
                Err(_) => {}
                Ok(out) => assert_eq!(
                    out.len(),
                    syms.len(),
                    "flip at {at} silently changed the symbol count"
                ),
            }
        }
        for cut in [0, 2, 5, header_end - 1, header_end, blob.len() / 2, blob.len() - 1] {
            assert!(decompress_u16(&blob[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn prop_roundtrip_framed_matches_input() {
        check("huffman-huf3-roundtrip", 40, |g| {
            let n = g.len() * 50;
            let alphabet = *g.choose(&[2usize, 17, 256, 1024]);
            let syms: Vec<u16> = (0..n)
                .map(|_| {
                    let u = g.rng.next_f32();
                    ((u * u * (alphabet as f32 - 1.0)) as u16).min(alphabet as u16 - 1)
                })
                .collect();
            let gap = *g.choose(&[0usize, 2, 64, GAP_INTERVAL_SYMS]);
            let per_chunk_tables = g.rng.bounded(2) == 0;
            let opts = EntropyOptions { gap_interval: gap, per_chunk_tables };
            let blob = compress_u16_framed(&syms, alphabet, None, &opts);
            let back = decompress_u16(&blob).map_err(|e| e.to_string())?;
            if back == syms {
                Ok(())
            } else {
                Err("framed roundtrip mismatch".into())
            }
        });
    }
}

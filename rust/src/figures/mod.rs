//! Figure/table harness: regenerates every table and figure of the paper's
//! evaluation section (§V) on this testbed. Each generator prints the
//! series the paper plots and writes `results/<id>.csv`.
//!
//! See DESIGN.md §3 for the experiment index and EXPERIMENTS.md for
//! paper-vs-measured records (including the documented substitutions: one
//! host models the paper's two CPUs as lane-width configs; thread scaling
//! beyond this host's cores is reported from the calibrated Amdahl model
//! next to the measured points).

use std::sync::OnceLock;

use crate::autotune::{autotune, exhaustive_full, top_k_stability, TuneSettings};
use crate::bench::{bench, BenchOpts, CsvWriter};
use crate::blocks::Dims;
use crate::compressor::{compress, pq_stage, BackendChoice, Config, EbMode};
use crate::data::{all_suites, Field, Scale};
use crate::error::Result;
use crate::metrics::distortion;
use crate::padding::{study_policies, PadGranularity, PadValue, PaddingPolicy};
use crate::roofline::{
    dualquant_gflops, evaluate, host_info, measure_ceilings, oi_model, Ceilings,
};

/// The two "machines" of the paper, modeled as lane-width configs on this
/// host (see DESIGN.md §Substitutions).
#[derive(Clone, Copy, Debug)]
pub struct CpuModel {
    pub name: &'static str,
    pub widths: &'static [usize],
    /// Paper-testbed core counts for the scaling model (Fig 8/9).
    pub physical_cores: usize,
    pub hw_threads: usize,
}

pub const ROME_CLASS: CpuModel =
    CpuModel { name: "rome-class(w8)", widths: &[8], physical_cores: 32, hw_threads: 64 };
pub const GOLD_CLASS: CpuModel =
    CpuModel { name: "gold-class(w16)", widths: &[8, 16], physical_cores: 16, hw_threads: 64 };

/// Representative field per suite (first field), subsampled in quick mode.
fn field_set(quick: bool) -> &'static Vec<(String, Field, f64)> {
    static CACHE: OnceLock<Vec<(String, Field, f64)>> = OnceLock::new();
    static CACHE_QUICK: OnceLock<Vec<(String, Field, f64)>> = OnceLock::new();
    let cell = if quick { &CACHE_QUICK } else { &CACHE };
    cell.get_or_init(|| {
        all_suites(Scale::Small, 0xDA7A)
            .into_iter()
            .map(|ds| {
                let mut f = ds.fields.into_iter().next().unwrap();
                if quick {
                    f = subsample(&f, 1 << 18);
                }
                // paper §V-B: absolute eb 1e-5 for CESM, 1e-4 elsewhere —
                // as value-range-relative equivalents for our synthetic
                // ranges (documented substitution).
                let eb = ds.default_eb;
                (ds.name, f, eb)
            })
            .collect()
    })
}

/// Prefix-slice a field, preserving dimensionality.
pub fn subsample(field: &Field, max_elems: usize) -> Field {
    let d = field.dims;
    if d.len() <= max_elems {
        return field.clone();
    }
    match d.ndim {
        1 => Field::new(field.name.clone(), Dims::d1(max_elems), field.data[..max_elems].to_vec()),
        2 => {
            let rows = (max_elems / d.shape[1]).max(8).min(d.shape[0]);
            Field::new(
                field.name.clone(),
                Dims::d2(rows, d.shape[1]),
                field.data[..rows * d.shape[1]].to_vec(),
            )
        }
        _ => {
            let planes = (max_elems / (d.shape[1] * d.shape[2])).max(8).min(d.shape[0]);
            Field::new(
                field.name.clone(),
                Dims::d3(planes, d.shape[1], d.shape[2]),
                field.data[..planes * d.shape[1] * d.shape[2]].to_vec(),
            )
        }
    }
}

fn eb_for(field: &Field, eb_paper: f64) -> f64 {
    // Our synthetic fields are rougher at fine scales than SDRBench's, so
    // transplanting the paper's absolute bounds verbatim would push the
    // outlier rate far outside the regime the paper operates in (sub-1%,
    // §V-I) and make the lossless outlier pass dominate the profile.
    // Instead we keep the paper's per-dataset bound as a *value-range
    // relative* bound (CESM 1e-5, others 1e-4), which reproduces the
    // paper's outlier/compression regime on these suites (documented in
    // EXPERIMENTS.md).
    let range = crate::metrics::value_range(&field.data);
    eb_paper * range.max(1e-30)
}

/// P&Q bandwidth of one (backend, block size, threads) point.
fn pq_mbs(field: &Field, backend: BackendChoice, bs: usize, eb: f64, threads: usize, opts: BenchOpts) -> f64 {
    let cfg = Config {
        eb: EbMode::Abs(eb),
        block_size: bs,
        backend,
        threads,
        ..Config::default()
    };
    let be = backend.instantiate();
    let stats = bench(
        &format!("{:?}", backend),
        field.data.len() * 4,
        opts,
        || {
            let _ = pq_stage(field, &cfg, be.as_ref());
        },
    );
    stats.best_mb_s()
}

fn ceilings(quick: bool) -> Ceilings {
    static C: OnceLock<Ceilings> = OnceLock::new();
    *C.get_or_init(|| measure_ceilings(quick))
}

// ---------------------------------------------------------------- table 1

pub fn table1(out_dir: &str, quick: bool) -> Result<()> {
    let h = host_info();
    let c = ceilings(quick);
    println!("TABLE I — testbed (paper: AMD EPYC 7452 / Intel Xeon Gold 6142)");
    println!("  model        : {}", h.model);
    println!("  cores        : {}", h.cores);
    println!("  cache        : {} KB", h.cache_kb);
    println!("  AVX2 / AVX512: {} / {}", h.has_avx2, h.has_avx512);
    println!("  stream triad : {:.2} GB/s", c.dram_gb_s);
    println!("  peak f32 FMA : {:.2} GFLOP/s", c.peak_gflop_s);
    println!("  modeled CPUs : {} and {} (lane-width analogs)", ROME_CLASS.name, GOLD_CLASS.name);
    let mut w = CsvWriter::new(format!("{out_dir}/table1.csv"), "key,value");
    w.row(&["model".into(), h.model.clone()]);
    w.row(&["cores".into(), h.cores.to_string()]);
    w.row(&["cache_kb".into(), h.cache_kb.to_string()]);
    w.row(&["avx2".into(), h.has_avx2.to_string()]);
    w.row(&["avx512".into(), h.has_avx512.to_string()]);
    w.row(&["stream_gb_s".into(), format!("{:.3}", c.dram_gb_s)]);
    w.row(&["peak_gflop_s".into(), format!("{:.3}", c.peak_gflop_s)]);
    w.finish()?;
    Ok(())
}

// ---------------------------------------------------------------- table 2

pub fn table2(out_dir: &str, _quick: bool) -> Result<()> {
    println!("TABLE II — synthetic suite attributes (paper dims in DESIGN.md)");
    println!("{:<11} {:<10} {:>6} {:>24} {:>10}", "dataset", "domain", "fields", "dims", "size(MB)");
    let mut w = CsvWriter::new(format!("{out_dir}/table2.csv"), "dataset,domain,fields,dims,mb");
    let domains = ["Cosmology", "Climate", "Climate", "Cosmology", "Quantum"];
    for (ds, dom) in all_suites(Scale::Small, 0xDA7A).iter().zip(domains) {
        let d = &ds.fields[0].dims;
        let dims_s = match d.ndim {
            1 => format!("{}", d.shape[0]),
            2 => format!("{}x{}", d.shape[0], d.shape[1]),
            _ => format!("{}x{}x{}", d.shape[0], d.shape[1], d.shape[2]),
        };
        let mb = ds.total_bytes() as f64 / 1e6;
        println!("{:<11} {:<10} {:>6} {:>24} {:>10.2}", ds.name, dom, ds.fields.len(), dims_s, mb);
        w.row(&[ds.name.clone(), dom.into(), ds.fields.len().to_string(), dims_s, format!("{mb:.2}")]);
    }
    w.finish()?;
    Ok(())
}

// ------------------------------------------------------------------ fig 1

pub fn fig1(out_dir: &str, quick: bool) -> Result<()> {
    let c = ceilings(quick);
    println!("FIG 1 — roofline, sequential pSZ dual-quant (per dimensionality)");
    println!("ceilings: DRAM {:.1} GB/s, peak {:.1} GFLOP/s", c.dram_gb_s, c.peak_gflop_s);
    let mut w = CsvWriter::new(
        format!("{out_dir}/fig1.csv"),
        "ndim,dataset,oi_cons,oi_len,gflops_cons,gflops_len,frac_roof_cons,pct_peak_paper_range",
    );
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::from_env() };
    for (name, field, eb_p) in field_set(quick) {
        let ndim = field.dims.ndim;
        let eb = eb_for(field, *eb_p);
        let cfg = Config { eb: EbMode::Abs(eb), backend: BackendChoice::Psz, ..Config::default() };
        let be = cfg.backend.instantiate();
        let s = bench("psz", field.data.len() * 4, opts, || {
            let _ = pq_stage(field, &cfg, be.as_ref());
        });
        let m = oi_model(ndim);
        let g_cons = dualquant_gflops(ndim, field.data.len(), s.min_s, false);
        let g_len = dualquant_gflops(ndim, field.data.len(), s.min_s, true);
        let p = evaluate(c, m.oi_conservative(), g_cons);
        println!(
            "  {name:<10} {ndim}D  OI=[{:.2},{:.2}]  {:.2}-{:.2} GFLOP/s  {:.0}% of roof ({})",
            m.oi_conservative(),
            m.oi_lenient(),
            g_cons,
            g_len,
            100.0 * p.fraction_of_roof,
            if p.memory_bound { "memory-bound" } else { "compute-bound" }
        );
        w.row(&[
            ndim.to_string(),
            name.clone(),
            format!("{:.4}", m.oi_conservative()),
            format!("{:.4}", m.oi_lenient()),
            format!("{:.3}", g_cons),
            format!("{:.3}", g_len),
            format!("{:.4}", p.fraction_of_roof),
            format!("{:.1}", 100.0 * p.fraction_of_roof),
        ]);
    }
    println!("  (paper: sequential dual-quant reaches 10-25% of peak)");
    w.finish()?;
    Ok(())
}

// ------------------------------------------------------------------ fig 3

pub fn fig3(out_dir: &str, quick: bool) -> Result<()> {
    println!("FIG 3 — P&Q bandwidth (MB/s): SZ-1.4 vs pSZ vs vecSZ (best config)");
    let mut w = CsvWriter::new(
        format!("{out_dir}/fig3.csv"),
        "cpu_model,dataset,sz14_mbs,psz_mbs,vecsz_mbs,vec_bs,vec_backend,speedup_vs_sz14,speedup_vs_psz",
    );
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::from_env() };
    for cpu in [ROME_CLASS, GOLD_CLASS] {
        println!("-- {}", cpu.name);
        println!(
            "{:<11} {:>10} {:>10} {:>10}  {:>9} {:>14}",
            "dataset", "SZ-1.4", "pSZ", "vecSZ", "best cfg", "speedup(sz14)"
        );
        for (name, field, eb_p) in field_set(quick) {
            let eb = eb_for(field, *eb_p);
            let bs0 = crate::compressor::default_block_size(field.dims.ndim);
            let sz14 = pq_mbs(field, BackendChoice::Sz14, bs0, eb, 1, opts);
            let psz = pq_mbs(field, BackendChoice::Psz, bs0, eb, 1, opts);
            // best (bs, width) for this cpu model from the exhaustive grid
            let grid = exhaustive_full(field, eb, 512, PaddingPolicy::ZERO, cpu.widths, 1);
            let best = grid.iter().max_by(|a, b| a.mb_per_s.total_cmp(&b.mb_per_s)).unwrap();
            let vec_mbs =
                pq_mbs(field, best.config.backend_choice(), best.config.block_size, eb, 1, opts);
            println!(
                "{:<11} {:>10.0} {:>10.0} {:>10.0}  bs{:<3} {:<6} {:>8.1}x",
                name, sz14, psz, vec_mbs, best.config.block_size, best.config.backend_label(),
                vec_mbs / sz14.max(1e-9)
            );
            w.row(&[
                cpu.name.into(),
                name.clone(),
                format!("{sz14:.1}"),
                format!("{psz:.1}"),
                format!("{vec_mbs:.1}"),
                best.config.block_size.to_string(),
                best.config.backend_label(),
                format!("{:.2}", vec_mbs / sz14.max(1e-9)),
                format!("{:.2}", vec_mbs / psz.max(1e-9)),
            ]);
        }
    }
    w.finish()?;
    Ok(())
}

// ------------------------------------------------------------------ fig 4

pub fn fig4(out_dir: &str, quick: bool) -> Result<()> {
    let c = ceilings(quick);
    println!("FIG 4 — roofline with vecSZ (O3+vec) vs pSZ (O3) points");
    let mut w = CsvWriter::new(
        format!("{out_dir}/fig4.csv"),
        "dataset,ndim,psz_gflops,vec_gflops,improvement,psz_frac_roof,vec_frac_roof",
    );
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::from_env() };
    for (name, field, eb_p) in field_set(quick) {
        let ndim = field.dims.ndim;
        let eb = eb_for(field, *eb_p);
        let bs0 = crate::compressor::default_block_size(ndim);
        let time_of = |backend| {
            let cfg = Config { eb: EbMode::Abs(eb), block_size: bs0, backend, ..Config::default() };
            let be: Box<dyn crate::quant::PqBackend> = cfg.backend.instantiate();
            bench("x", field.data.len() * 4, opts, || {
                let _ = pq_stage(field, &cfg, be.as_ref());
            })
            .min_s
        };
        let t_psz = time_of(BackendChoice::Psz);
        let t_vec = time_of(BackendChoice::Vec { width: 16 });
        let m = oi_model(ndim);
        let g_psz = dualquant_gflops(ndim, field.data.len(), t_psz, false);
        let g_vec = dualquant_gflops(ndim, field.data.len(), t_vec, false);
        let p_psz = evaluate(c, m.oi_conservative(), g_psz);
        let p_vec = evaluate(c, m.oi_conservative(), g_vec);
        println!(
            "  {name:<10} pSZ {:.2} GF/s ({:.0}% roof) -> vecSZ {:.2} GF/s ({:.0}% roof)  {:.1}x",
            g_psz,
            100.0 * p_psz.fraction_of_roof,
            g_vec,
            100.0 * p_vec.fraction_of_roof,
            g_vec / g_psz.max(1e-12)
        );
        w.row(&[
            name.clone(),
            ndim.to_string(),
            format!("{g_psz:.3}"),
            format!("{g_vec:.3}"),
            format!("{:.2}", g_vec / g_psz.max(1e-12)),
            format!("{:.4}", p_psz.fraction_of_roof),
            format!("{:.4}", p_vec.fraction_of_roof),
        ]);
    }
    println!("  (paper: vecSZ reaches 47-61% of DRAM roof on AMD, 57-107% on Intel)");
    w.finish()?;
    Ok(())
}

// ------------------------------------------------------------------ fig 5

pub fn fig5(out_dir: &str, quick: bool) -> Result<()> {
    println!("FIG 5 — P&Q bandwidth vs (block size x vector length)");
    let mut w =
        CsvWriter::new(format!("{out_dir}/fig5.csv"), "dataset,block_size,backend,mb_per_s");
    for (name, field, eb_p) in field_set(quick) {
        let eb = eb_for(field, *eb_p);
        let pts = exhaustive_full(field, eb, 512, PaddingPolicy::ZERO, &[8, 16], 1);
        println!("-- {name}");
        for p in &pts {
            println!(
                "   bs={:<3} {:<6} {:>9.0} MB/s",
                p.config.block_size,
                p.config.backend_label(),
                p.mb_per_s
            );
            w.row(&[
                name.clone(),
                p.config.block_size.to_string(),
                p.config.backend_label(),
                format!("{:.1}", p.mb_per_s),
            ]);
        }
        let best = pts.iter().max_by(|a, b| a.mb_per_s.total_cmp(&b.mb_per_s)).unwrap();
        let worst = pts.iter().min_by(|a, b| a.mb_per_s.total_cmp(&b.mb_per_s)).unwrap();
        println!(
            "   spread: best bs{} w{} / worst bs{} w{} = {:.0}%",
            best.config.block_size,
            best.config.width,
            worst.config.block_size,
            worst.config.width,
            100.0 * (best.mb_per_s / worst.mb_per_s - 1.0)
        );
    }
    w.finish()?;
    Ok(())
}

// -------------------------------------------------------------- figs 6, 7

pub fn fig6_7(out_dir: &str, quick: bool) -> Result<()> {
    println!("FIG 6/7 — autotuning: % of peak achieved and % runtime spent tuning");
    let sample_pcts: &[f64] = if quick { &[5.0, 20.0] } else { &[1.0, 5.0, 10.0, 20.0] };
    let iterations: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let mut w6 = CsvWriter::new(
        format!("{out_dir}/fig6.csv"),
        "cpu_model,sample_pct,iterations,pct_of_peak",
    );
    let mut w7 = CsvWriter::new(
        format!("{out_dir}/fig7.csv"),
        "cpu_model,sample_pct,iterations,pct_runtime_tuning",
    );
    for cpu in [ROME_CLASS, GOLD_CLASS] {
        println!("-- {}", cpu.name);
        println!("{:>8} {:>6} {:>12} {:>16}", "sample%", "iters", "% of peak", "% runtime tune");
        for &sp in sample_pcts {
            for &it in iterations {
                let mut pct_sum = 0.0;
                let mut overhead_sum = 0.0;
                let mut n = 0.0;
                for (_, field, eb_p) in field_set(quick) {
                    let eb = eb_for(field, *eb_p);
                    // ground truth: full-field bandwidth of each config
                    let full = exhaustive_full(field, eb, 512, PaddingPolicy::ZERO, cpu.widths, 1);
                    let peak =
                        full.iter().map(|p| p.mb_per_s).fold(f64::MIN, f64::max);
                    let r = autotune(
                        field,
                        eb,
                        512,
                        PaddingPolicy::ZERO,
                        cpu.widths,
                        TuneSettings { sample_pct: sp, iterations: it, seed: 7 },
                    );
                    let chosen = full
                        .iter()
                        .find(|p| p.config == r.best)
                        .map(|p| p.mb_per_s)
                        .unwrap_or(0.0);
                    let optimal_runtime = field.data.len() as f64 * 4.0 / 1e6 / peak;
                    pct_sum += 100.0 * chosen / peak;
                    overhead_sum += 100.0 * r.tune_seconds / (r.tune_seconds + optimal_runtime);
                    n += 1.0;
                }
                let pct = pct_sum / n;
                let ovh = overhead_sum / n;
                println!("{:>8} {:>6} {:>11.1}% {:>15.1}%", sp, it, pct, ovh);
                w6.row(&[cpu.name.into(), sp.to_string(), it.to_string(), format!("{pct:.2}")]);
                w7.row(&[cpu.name.into(), sp.to_string(), it.to_string(), format!("{ovh:.2}")]);
            }
        }
    }
    w6.finish()?;
    w7.finish()?;
    Ok(())
}

// -------------------------------------------------------------- figs 8, 9

/// Calibrated scaling model (see DESIGN.md §Substitutions): the P&Q stage
/// is block-parallel (p ~= 1) with per-thread dispatch overhead; SMT lanes
/// contribute ~35% of a physical core (the paper's 32->64 downtick).
pub fn modeled_speedup(threads: usize, cpu: CpuModel) -> f64 {
    let p = 0.99;
    let o = 0.004; // per-thread sync overhead
    let phys = cpu.physical_cores.min(threads) as f64;
    let smt = (threads.min(cpu.hw_threads).saturating_sub(cpu.physical_cores)) as f64;
    let eff = if threads <= cpu.physical_cores {
        threads as f64
    } else {
        // oversubscribed cores lose some of their base throughput to the
        // second hardware thread, netting +35% per SMT lane used
        phys - smt * 0.12 + smt * 0.35
    };
    1.0 / ((1.0 - p) + p / eff + o * (threads as f64 - 1.0).max(0.0) / 64.0)
}

pub fn fig8(out_dir: &str, quick: bool) -> Result<()> {
    println!("FIG 8 — OpenMP-analog thread scaling of the P&Q stage");
    let threads: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8, 16, 32, 64] };
    let mut w = CsvWriter::new(
        format!("{out_dir}/fig8.csv"),
        "dataset,threads,measured_speedup,model_rome,model_gold",
    );
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::from_env() };
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("  (host has {host_cores} core(s); measured beyond that is oversubscription —");
    println!("   the modeled columns replay the paper's 32c/16c testbeds, see DESIGN.md)");
    for (name, field, eb_p) in field_set(quick) {
        let eb = eb_for(field, *eb_p);
        let bs0 = crate::compressor::default_block_size(field.dims.ndim);
        let base = pq_mbs(field, BackendChoice::Vec { width: 8 }, bs0, eb, 1, opts);
        println!("-- {name} (1-thread: {base:.0} MB/s)");
        for &t in threads {
            let mbs = pq_mbs(field, BackendChoice::Vec { width: 8 }, bs0, eb, t, opts);
            let meas = mbs / base.max(1e-9);
            let mr = modeled_speedup(t, ROME_CLASS);
            let mg = modeled_speedup(t, GOLD_CLASS);
            println!(
                "   t={:<3} measured {:>5.2}x   model[rome] {:>5.2}x  model[gold] {:>5.2}x",
                t, meas, mr, mg
            );
            w.row(&[
                name.clone(),
                t.to_string(),
                format!("{meas:.3}"),
                format!("{mr:.3}"),
                format!("{mg:.3}"),
            ]);
        }
    }
    w.finish()?;
    Ok(())
}

pub fn fig9(out_dir: &str, quick: bool) -> Result<()> {
    println!("FIG 9 — threaded P&Q bandwidth: vecSZ vs SZ-1.4 (3D datasets)");
    let threads: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8, 16] };
    let mut w = CsvWriter::new(
        format!("{out_dir}/fig9.csv"),
        "dataset,threads,vecsz_mbs,sz14_mbs,ratio",
    );
    let opts = if quick { BenchOpts::quick() } else { BenchOpts::from_env() };
    for (name, field, eb_p) in field_set(quick) {
        if field.dims.ndim != 3 {
            continue;
        }
        let eb = eb_for(field, *eb_p);
        println!("-- {name}");
        for &t in threads {
            let v = pq_mbs(field, BackendChoice::Vec { width: 8 }, 8, eb, t, opts);
            let s = pq_mbs(field, BackendChoice::Sz14, 8, eb, t, opts);
            println!("   t={:<3} vecSZ {:>8.0} MB/s   SZ-1.4 {:>8.0} MB/s   {:>5.2}x", t, v, s, v / s.max(1e-9));
            w.row(&[name.clone(), t.to_string(), format!("{v:.1}"), format!("{s:.1}"), format!("{:.2}", v / s.max(1e-9))]);
        }
    }
    w.finish()?;
    Ok(())
}

// ----------------------------------------------------------------- fig 10

pub fn fig10(out_dir: &str, quick: bool) -> Result<()> {
    println!("FIG 10 — rate-distortion: vecSZ (avg-global padding) vs SZ-1.4 (zero)");
    let rel_ebs: &[f64] =
        if quick { &[1e-2, 1e-4] } else { &[1e-1, 1e-2, 1e-3, 1e-4, 1e-5, 1e-6] };
    let mut w = CsvWriter::new(
        format!("{out_dir}/fig10.csv"),
        "dataset,rel_eb,variant,bit_rate,psnr_db",
    );
    for (name, field, _) in field_set(quick) {
        println!("-- {name}");
        for &rel in rel_ebs {
            for (variant, backend, padding) in [
                ("vecSZ", BackendChoice::Vec { width: 8 },
                 PaddingPolicy::new(PadValue::Avg, PadGranularity::Global)),
                ("SZ-1.4", BackendChoice::Sz14, PaddingPolicy::ZERO),
            ] {
                let cfg = Config { eb: EbMode::Rel(rel), backend, padding, ..Config::default() };
                let (bytes, stats) = compress(field, &cfg)?;
                let rec = crate::compressor::decompress(&bytes, 1)?;
                let d = distortion(&field.data, &rec.data);
                println!(
                    "   rel={rel:<8e} {variant:<7} rate {:>6.3} bits  PSNR {:>7.2} dB  (CR {:>7.1}x)",
                    stats.size.bit_rate(),
                    d.psnr_db,
                    stats.size.ratio()
                );
                w.row(&[
                    name.clone(),
                    format!("{rel:e}"),
                    variant.into(),
                    format!("{:.4}", stats.size.bit_rate()),
                    format!("{:.3}", d.psnr_db),
                ]);
            }
        }
    }
    w.finish()?;
    Ok(())
}

// ----------------------------------------------------- padding study §V-I

pub fn padding_study(out_dir: &str, quick: bool) -> Result<()> {
    println!("PADDING STUDY (§V-I) — outliers per policy (reduction vs zero)");
    let mut w = CsvWriter::new(
        format!("{out_dir}/padding.csv"),
        "dataset,policy,outliers,reduction_pct,extra_scalars",
    );
    for (name, field, eb_p) in field_set(quick) {
        let eb = eb_for(field, *eb_p) * 10.0; // generous bound: border-dominated outliers
        println!("-- {name}");
        let mut zero_outliers = None;
        for policy in study_policies() {
            let cfg = Config {
                eb: EbMode::Abs(eb),
                padding: policy,
                backend: BackendChoice::Vec { width: 8 },
                ..Config::default()
            };
            let (_, stats) = compress(field, &cfg)?;
            let z = *zero_outliers.get_or_insert(stats.n_outliers);
            let red = if z == 0 {
                0.0
            } else {
                100.0 * (z as f64 - stats.n_outliers as f64) / z as f64
            };
            let scalars = crate::padding::compute_scalars(
                &field.data,
                &field.dims,
                stats.block_size,
                policy,
            )
            .storage_values();
            println!(
                "   {:<11} outliers {:>9}  reduction {:>6.1}%  (+{} scalars)",
                policy.name(),
                stats.n_outliers,
                red,
                scalars
            );
            w.row(&[
                name.clone(),
                policy.name(),
                stats.n_outliers.to_string(),
                format!("{red:.2}"),
                scalars.to_string(),
            ]);
        }
    }
    w.finish()?;
    Ok(())
}

// ---------------------------------------------------------------- table 3

pub fn table3(out_dir: &str, quick: bool) -> Result<()> {
    println!("TABLE III — Amdahl: dual-quant share, theoretical vs actual speedup");
    let mut w = CsvWriter::new(
        format!("{out_dir}/table3.csv"),
        "cpu_model,dq_pct_of_runtime,theoretical,actual,pct_of_theoretical",
    );
    for (cpu, s_lanes) in [(ROME_CLASS, 8.0f64), (GOLD_CLASS, 16.0f64)] {
        let mut frac_sum = 0.0;
        let mut actual_sum = 0.0;
        let mut n = 0.0;
        for (_, field, eb_p) in field_set(quick) {
            let eb = eb_for(field, *eb_p);
            let run = |backend| {
                let cfg = Config { eb: EbMode::Abs(eb), backend, ..Config::default() };
                compress(field, &cfg).unwrap().1
            };
            let base = run(BackendChoice::Psz);
            let vec = run(BackendChoice::Vec { width: s_lanes as usize });
            frac_sum += base.profile.fraction("pq");
            actual_sum += base.profile.total() / vec.profile.total();
            n += 1.0;
        }
        let p = frac_sum / n;
        let theoretical = 1.0 / ((1.0 - p) + p / s_lanes);
        let actual = actual_sum / n;
        let pct = 100.0 * actual / theoretical;
        println!(
            "  {:<16} dual-quant {:>5.1}% of runtime  theo {:.2}x  actual {:.2}x  ({:.1}% of theo)",
            cpu.name,
            100.0 * p,
            theoretical,
            actual,
            pct
        );
        w.row(&[
            cpu.name.into(),
            format!("{:.2}", 100.0 * p),
            format!("{theoretical:.3}"),
            format!("{actual:.3}"),
            format!("{pct:.1}"),
        ]);
    }
    println!("  (paper: 46.9%/42.9% of runtime, theo 1.70x/1.67x, actual 1.51x/1.47x)");
    w.finish()?;
    Ok(())
}

// --------------------------------------------------------- V-F stability

pub fn stability(out_dir: &str, quick: bool) -> Result<()> {
    println!("§V-F — autotune stability across time-steps (top-2 coverage)");
    let steps = if quick { 4 } else { 16 };
    let mut w = CsvWriter::new(format!("{out_dir}/stability.csv"), "dataset,steps,top1,top2");
    for (name, field, eb_p) in field_set(quick) {
        let eb = eb_for(field, *eb_p);
        let runs: Vec<_> = (0..steps)
            .map(|s| {
                // time-step analog: identical field, fresh sampling each step
                autotune(
                    field,
                    eb,
                    512,
                    PaddingPolicy::ZERO,
                    &[8, 16],
                    TuneSettings { sample_pct: 5.0, iterations: 1, seed: 1000 + s as u64 },
                )
            })
            .collect();
        let t1 = top_k_stability(&runs, 1);
        let t2 = top_k_stability(&runs, 2);
        println!("  {name:<11} top-1 {:>5.0}%  top-2 {:>5.0}%", t1 * 100.0, t2 * 100.0);
        w.row(&[name.clone(), steps.to_string(), format!("{:.3}", t1), format!("{:.3}", t2)]);
    }
    println!("  (paper: ~80% of Hurricane time-step runs land in the top-2 configs)");
    w.finish()?;
    Ok(())
}

/// Dispatch by figure id.
pub fn run(id: &str, out_dir: &str, quick: bool) -> Result<bool> {
    match id {
        "table1" => table1(out_dir, quick)?,
        "table2" => table2(out_dir, quick)?,
        "fig1" => fig1(out_dir, quick)?,
        "fig3" => fig3(out_dir, quick)?,
        "fig4" => fig4(out_dir, quick)?,
        "fig5" => fig5(out_dir, quick)?,
        "fig6" | "fig7" | "fig6_7" => fig6_7(out_dir, quick)?,
        "fig8" => fig8(out_dir, quick)?,
        "fig9" => fig9(out_dir, quick)?,
        "fig10" => fig10(out_dir, quick)?,
        "padding" => padding_study(out_dir, quick)?,
        "table3" => table3(out_dir, quick)?,
        "stability" => stability(out_dir, quick)?,
        "all" => {
            for f in ALL_IDS {
                if *f != "all" {
                    println!();
                    run(f, out_dir, quick)?;
                }
            }
        }
        _ => return Ok(false),
    }
    Ok(true)
}

pub const ALL_IDS: &[&str] = &[
    "table1", "table2", "fig1", "fig3", "fig4", "fig5", "fig6_7", "fig8", "fig9", "fig10",
    "padding", "table3", "stability",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_speedup_has_paper_shape() {
        // near-linear at low counts
        assert!(modeled_speedup(2, ROME_CLASS) > 1.8);
        assert!(modeled_speedup(4, ROME_CLASS) > 3.4);
        // plateaus by core count
        let s32 = modeled_speedup(32, ROME_CLASS);
        let s16 = modeled_speedup(16, ROME_CLASS);
        assert!(s32 > s16);
        // SMT downtick: 64 threads on 32 cores <= peak x 1.2 and shows the
        // paper's "downtick vs linear" shape
        let s64 = modeled_speedup(64, ROME_CLASS);
        assert!(s64 < s32 * 1.5);
        // paper: max ~24x at 64 threads
        assert!(s64 > 10.0 && s64 < 40.0, "s64 = {s64}");
    }

    #[test]
    fn subsample_preserves_ndim() {
        let f = Field::new("x", Dims::d3(10, 10, 10), vec![0.0; 1000]);
        let s = subsample(&f, 500);
        assert_eq!(s.dims.ndim, 3);
        assert!(s.data.len() <= 1000);
    }

    #[test]
    fn run_rejects_unknown_id() {
        assert!(!run("nope", "/tmp/vecsz_results_test", true).unwrap());
    }
}

//! General-purpose lossless byte compressor (substrate).
//!
//! SZ's final stage passes outlier values and auxiliary streams through a
//! dictionary coder (GZip/Zstd in the paper). We implement our own
//! "deflate-lite": LZSS with a hash-chain match finder, optionally followed
//! by an order-0 Huffman pass over the token bytes, plus an RLE mode and a
//! stored mode. `compress` picks whichever mode is smallest, so it never
//! expands input by more than the 6-byte header.
//!
//! Container: `tag u8 | uvarint raw_len | payload`.

use crate::bitio::{get_uvarint, put_uvarint};
use crate::error::{Result, VszError};
use crate::huffman;

const TAG_STORE: u8 = 0;
const TAG_RLE: u8 = 1;
const TAG_LZSS: u8 = 2;
const TAG_LZSS_HUFF: u8 = 3;

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 258;
const WINDOW: usize = 1 << 16;
const HASH_BITS: u32 = 15;
const MAX_CHAIN: usize = 48;

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    (v.wrapping_mul(0x9E3779B1) >> (32 - HASH_BITS)) as usize
}

/// Raw LZSS token stream:
///   literal run : uvarint (len << 1) | 0, then `len` raw bytes
///   match       : uvarint ((len - MIN_MATCH) << 1) | 1, then uvarint dist
fn lzss_tokens(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    let n = data.len();
    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut prev = vec![usize::MAX; n.max(1)];
    let mut lit_start = 0usize;
    let mut i = 0usize;

    let flush_literals = |out: &mut Vec<u8>, from: usize, to: usize| {
        let mut s = from;
        while s < to {
            let run = (to - s).min(1 << 20);
            put_uvarint(out, (run as u64) << 1);
            out.extend_from_slice(&data[s..s + run]);
            s += run;
        }
    };

    while i + MIN_MATCH <= n {
        let h = hash4(data, i);
        let mut cand = head[h];
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        let limit = i.saturating_sub(WINDOW - 1);
        let mut chain = 0usize;
        while cand != usize::MAX && cand >= limit && chain < MAX_CHAIN {
            // extend match
            let max_len = (n - i).min(MAX_MATCH);
            let mut l = 0usize;
            while l < max_len && data[cand + l] == data[i + l] {
                l += 1;
            }
            if l > best_len {
                best_len = l;
                best_dist = i - cand;
                if l >= max_len {
                    break;
                }
            }
            cand = prev[cand];
            chain += 1;
        }
        if best_len >= MIN_MATCH {
            flush_literals(&mut out, lit_start, i);
            put_uvarint(&mut out, (((best_len - MIN_MATCH) as u64) << 1) | 1);
            put_uvarint(&mut out, best_dist as u64);
            // index all covered positions (cheap skip for long matches)
            let end = (i + best_len).min(n.saturating_sub(MIN_MATCH - 1));
            let step = if best_len > 64 { 4 } else { 1 };
            let mut j = i;
            while j < end {
                let hj = hash4(data, j);
                prev[j] = head[hj];
                head[hj] = j;
                j += step;
            }
            i += best_len;
            lit_start = i;
        } else {
            prev[i] = head[h];
            head[h] = i;
            i += 1;
        }
    }
    flush_literals(&mut out, lit_start, n);
    out
}

fn lzss_expand(tokens: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    // cap the pre-allocation: a forged raw_len must not abort on reserve
    let mut out = Vec::with_capacity(raw_len.min(1 << 26));
    let mut pos = 0usize;
    let err = || VszError::format("lzss: truncated token stream");
    while out.len() < raw_len {
        let (ctrl, n) = get_uvarint(&tokens[pos..]).ok_or_else(err)?;
        pos += n;
        if ctrl & 1 == 0 {
            let run = (ctrl >> 1) as usize;
            if pos + run > tokens.len() || out.len() + run > raw_len {
                return Err(VszError::format("lzss: literal run out of range"));
            }
            out.extend_from_slice(&tokens[pos..pos + run]);
            pos += run;
        } else {
            let len = (ctrl >> 1) as usize + MIN_MATCH;
            let (dist, n2) = get_uvarint(&tokens[pos..]).ok_or_else(err)?;
            pos += n2;
            let dist = dist as usize;
            if dist == 0 || dist > out.len() || out.len() + len > raw_len {
                return Err(VszError::format("lzss: bad match"));
            }
            let start = out.len() - dist;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
    }
    Ok(out)
}

fn rle_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < data.len() {
        let b = data[i];
        let mut run = 1usize;
        while i + run < data.len() && data[i + run] == b && run < (1 << 24) {
            run += 1;
        }
        put_uvarint(&mut out, run as u64);
        out.push(b);
        i += run;
    }
    out
}

fn rle_decode(data: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    // cap the pre-allocation: a forged raw_len must not abort on reserve
    let mut out = Vec::with_capacity(raw_len.min(1 << 26));
    let mut pos = 0usize;
    while out.len() < raw_len {
        let (run, n) =
            get_uvarint(&data[pos..]).ok_or_else(|| VszError::format("rle: truncated"))?;
        pos += n;
        let b = *data.get(pos).ok_or_else(|| VszError::format("rle: truncated"))?;
        pos += 1;
        if out.len() + run as usize > raw_len {
            return Err(VszError::format("rle: run exceeds length"));
        }
        out.extend(std::iter::repeat(b).take(run as usize));
    }
    Ok(out)
}

fn huff_bytes(data: &[u8]) -> Vec<u8> {
    let syms: Vec<u16> = data.iter().map(|&b| b as u16).collect();
    huffman::compress_u16(&syms, 256)
}

fn unhuff_bytes(data: &[u8]) -> Result<Vec<u8>> {
    Ok(huffman::decompress_u16(data)?.into_iter().map(|s| s as u8).collect())
}

/// Compress `data`, choosing the smallest of {store, rle, lzss, lzss+huff}.
/// The winner is picked by length first; the STORE copy of the input is
/// only materialized when it actually wins, instead of cloning the whole
/// input up front (which doubled peak memory on incompressible streams).
/// Ties resolve exactly as the old candidate ordering did: store, then
/// rle, then lzss+huff, then lzss.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut best: Option<(u8, Vec<u8>)> = None;
    let mut best_len = data.len(); // the implicit STORE candidate
    let rle = rle_encode(data);
    if rle.len() < best_len {
        best_len = rle.len();
        best = Some((TAG_RLE, rle));
    }
    if data.len() >= MIN_MATCH {
        let tokens = lzss_tokens(data);
        let hufftok = huff_bytes(&tokens);
        if hufftok.len() < tokens.len() && hufftok.len() < best_len {
            best = Some((TAG_LZSS_HUFF, hufftok));
        } else if tokens.len() < best_len {
            best = Some((TAG_LZSS, tokens));
        }
    }
    let (tag, payload) = best.unwrap_or_else(|| (TAG_STORE, data.to_vec()));
    let mut out = Vec::with_capacity(payload.len() + 6);
    out.push(tag);
    put_uvarint(&mut out, data.len() as u64);
    out.extend_from_slice(&payload);
    out
}

/// Inverse of [`compress`].
pub fn decompress(blob: &[u8]) -> Result<Vec<u8>> {
    if blob.is_empty() {
        return Err(VszError::format("lossless: empty blob"));
    }
    let tag = blob[0];
    let (raw_len, n) =
        get_uvarint(&blob[1..]).ok_or_else(|| VszError::format("lossless: bad header"))?;
    let raw_len = raw_len as usize;
    let payload = &blob[1 + n..];
    match tag {
        TAG_STORE => {
            if payload.len() != raw_len {
                return Err(VszError::format("store: length mismatch"));
            }
            Ok(payload.to_vec())
        }
        TAG_RLE => rle_decode(payload, raw_len),
        TAG_LZSS => lzss_expand(payload, raw_len),
        TAG_LZSS_HUFF => {
            let tokens = unhuff_bytes(payload)?;
            lzss_expand(&tokens, raw_len)
        }
        _ => Err(VszError::format(format!("lossless: unknown tag {tag}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let blob = compress(data);
        decompress(&blob).unwrap()
    }

    #[test]
    fn empty_and_tiny() {
        assert_eq!(roundtrip(b""), b"");
        assert_eq!(roundtrip(b"a"), b"a");
        assert_eq!(roundtrip(b"abc"), b"abc");
    }

    #[test]
    fn constant_buffer_uses_rle_or_better() {
        let data = vec![42u8; 100_000];
        let blob = compress(&data);
        assert!(blob.len() < 200, "constant run should collapse, got {}", blob.len());
        assert_eq!(decompress(&blob).unwrap(), data);
    }

    #[test]
    fn repetitive_text_compresses() {
        let data: Vec<u8> =
            b"the quick brown fox jumps over the lazy dog. ".repeat(500).to_vec();
        let blob = compress(&data);
        assert!(blob.len() < data.len() / 5, "got {} of {}", blob.len(), data.len());
        assert_eq!(decompress(&blob).unwrap(), data);
    }

    #[test]
    fn incompressible_random_does_not_blow_up() {
        let mut rng = Pcg32::seeded(123);
        let data: Vec<u8> = (0..50_000).map(|_| rng.next_u32() as u8).collect();
        let blob = compress(&data);
        assert!(blob.len() <= data.len() + 8);
        assert_eq!(decompress(&blob).unwrap(), data);
    }

    #[test]
    fn f32_outlier_stream_shape() {
        // outlier values share exponent bytes -> lzss+huff should win space
        let mut rng = Pcg32::seeded(7);
        let vals: Vec<f32> = (0..20_000).map(|_| 100.0 + rng.next_f32()).collect();
        let bytes = crate::util::f32_as_bytes(&vals);
        let blob = compress(bytes);
        assert!(blob.len() < bytes.len(), "got {} of {}", blob.len(), bytes.len());
        assert_eq!(decompress(&blob).unwrap(), bytes);
    }

    #[test]
    fn long_matches_beyond_max_match() {
        let mut data = vec![0u8; 4096];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i % 7) as u8;
        }
        let mut doubled = data.clone();
        doubled.extend_from_slice(&data);
        assert_eq!(roundtrip(&doubled), doubled);
    }

    #[test]
    fn rejects_corrupt_blobs() {
        let blob = compress(b"hello world hello world hello world");
        let mut bad = blob.clone();
        bad[0] = 99; // unknown tag
        assert!(decompress(&bad).is_err());
        assert!(decompress(&[]).is_err());
        // truncation
        assert!(decompress(&blob[..blob.len().saturating_sub(3)]).is_err());
    }

    #[test]
    fn prop_roundtrip_mixed_content() {
        check("lossless-roundtrip", 80, |g| {
            let n = g.len() * 64;
            let mode = g.rng.bounded(3);
            let data: Vec<u8> = match mode {
                0 => g.bytes(n),
                1 => {
                    // runs
                    let mut v = Vec::with_capacity(n);
                    while v.len() < n {
                        let b = g.rng.next_u32() as u8;
                        let run = 1 + g.rng.bounded(32) as usize;
                        v.extend(std::iter::repeat(b).take(run.min(n - v.len())));
                    }
                    v
                }
                _ => {
                    // repeated motifs
                    let mlen = 1 + g.rng.bounded(24) as usize;
                    let motif = g.bytes(mlen);
                    motif.iter().cycle().take(n).copied().collect()
                }
            };
            let blob = compress(&data);
            let back = decompress(&blob).map_err(|e| e.to_string())?;
            if back == data {
                Ok(())
            } else {
                Err(format!("mismatch mode={mode} n={n}"))
            }
        });
    }
}

//! General-purpose lossless byte compressor (substrate).
//!
//! SZ's final stage passes outlier values and auxiliary streams through a
//! dictionary coder (GZip/Zstd in the paper). We implement our own
//! "deflate-lite": LZSS with a hash-chain match finder, optionally followed
//! by an order-0 Huffman pass over the token bytes, plus an RLE mode and a
//! stored mode. `compress` picks whichever mode is smallest, so it never
//! expands input by more than the 6-byte header.
//!
//! Container: `tag u8 | uvarint raw_len | payload`.
//!
//! Since entropy engine v2 the Huffman pass over a **large** LZSS token
//! stream (≥ [`FRAMED_TOKENS_MIN`] bytes) uses the chunk-framed HUF3 coder
//! under its own tag, so the big side-streams of the container (outlier
//! positions/values, pad scalars) encode and decode on the same
//! chunk/segment-parallel path as the CODES section instead of one
//! bit-serial stream. Small streams keep the legacy unframed format
//! byte-for-byte; both tags decode everywhere, so every blob ever written
//! stays readable.

use crate::bitio::{get_uvarint, put_uvarint};
use crate::coordinator::pool::ThreadPool;
use crate::error::{Result, VszError};
use crate::huffman;

const TAG_STORE: u8 = 0;
const TAG_RLE: u8 = 1;
const TAG_LZSS: u8 = 2;
const TAG_LZSS_HUFF: u8 = 3;
/// LZSS tokens entropy-coded with the framed HUF3 coder (parallel path).
const TAG_LZSS_HUF2: u8 = 4;

/// Token-stream byte floor above which the Huffman pass over the LZSS
/// tokens switches from the legacy unframed coder to the framed one: one
/// full Huffman chunk — below that the framing could not split anything
/// and would only pay header bytes.
pub const FRAMED_TOKENS_MIN: usize = huffman::CHUNK_SYMS;

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 258;
const WINDOW: usize = 1 << 16;
const HASH_BITS: u32 = 15;
const MAX_CHAIN: usize = 48;

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    (v.wrapping_mul(0x9E3779B1) >> (32 - HASH_BITS)) as usize
}

/// Raw LZSS token stream:
///   literal run : uvarint (len << 1) | 0, then `len` raw bytes
///   match       : uvarint ((len - MIN_MATCH) << 1) | 1, then uvarint dist
fn lzss_tokens(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    let n = data.len();
    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut prev = vec![usize::MAX; n.max(1)];
    let mut lit_start = 0usize;
    let mut i = 0usize;

    let flush_literals = |out: &mut Vec<u8>, from: usize, to: usize| {
        let mut s = from;
        while s < to {
            let run = (to - s).min(1 << 20);
            put_uvarint(out, (run as u64) << 1);
            out.extend_from_slice(&data[s..s + run]);
            s += run;
        }
    };

    while i + MIN_MATCH <= n {
        let h = hash4(data, i);
        let mut cand = head[h];
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        let limit = i.saturating_sub(WINDOW - 1);
        let mut chain = 0usize;
        while cand != usize::MAX && cand >= limit && chain < MAX_CHAIN {
            // extend match
            let max_len = (n - i).min(MAX_MATCH);
            let mut l = 0usize;
            while l < max_len && data[cand + l] == data[i + l] {
                l += 1;
            }
            if l > best_len {
                best_len = l;
                best_dist = i - cand;
                if l >= max_len {
                    break;
                }
            }
            cand = prev[cand];
            chain += 1;
        }
        if best_len >= MIN_MATCH {
            flush_literals(&mut out, lit_start, i);
            put_uvarint(&mut out, (((best_len - MIN_MATCH) as u64) << 1) | 1);
            put_uvarint(&mut out, best_dist as u64);
            // index all covered positions (cheap skip for long matches)
            let end = (i + best_len).min(n.saturating_sub(MIN_MATCH - 1));
            let step = if best_len > 64 { 4 } else { 1 };
            let mut j = i;
            while j < end {
                let hj = hash4(data, j);
                prev[j] = head[hj];
                head[hj] = j;
                j += step;
            }
            i += best_len;
            lit_start = i;
        } else {
            prev[i] = head[h];
            head[h] = i;
            i += 1;
        }
    }
    flush_literals(&mut out, lit_start, n);
    out
}

fn lzss_expand(tokens: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    // cap the pre-allocation: a forged raw_len must not abort on reserve
    let mut out = Vec::with_capacity(raw_len.min(1 << 26));
    let mut pos = 0usize;
    let err = || VszError::format("lzss: truncated token stream");
    while out.len() < raw_len {
        let (ctrl, n) = get_uvarint(&tokens[pos..]).ok_or_else(err)?;
        pos += n;
        if ctrl & 1 == 0 {
            let run = (ctrl >> 1) as usize;
            if pos + run > tokens.len() || out.len() + run > raw_len {
                return Err(VszError::format("lzss: literal run out of range"));
            }
            out.extend_from_slice(&tokens[pos..pos + run]);
            pos += run;
        } else {
            let len = (ctrl >> 1) as usize + MIN_MATCH;
            let (dist, n2) = get_uvarint(&tokens[pos..]).ok_or_else(err)?;
            pos += n2;
            let dist = dist as usize;
            if dist == 0 || dist > out.len() || out.len() + len > raw_len {
                return Err(VszError::format("lzss: bad match"));
            }
            let start = out.len() - dist;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
    }
    Ok(out)
}

fn rle_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < data.len() {
        let b = data[i];
        let mut run = 1usize;
        while i + run < data.len() && data[i + run] == b && run < (1 << 24) {
            run += 1;
        }
        put_uvarint(&mut out, run as u64);
        out.push(b);
        i += run;
    }
    out
}

fn rle_decode(data: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    // cap the pre-allocation: a forged raw_len must not abort on reserve
    let mut out = Vec::with_capacity(raw_len.min(1 << 26));
    let mut pos = 0usize;
    while out.len() < raw_len {
        let (run, n) =
            get_uvarint(&data[pos..]).ok_or_else(|| VszError::format("rle: truncated"))?;
        pos += n;
        let b = *data.get(pos).ok_or_else(|| VszError::format("rle: truncated"))?;
        pos += 1;
        if out.len() + run as usize > raw_len {
            return Err(VszError::format("rle: run exceeds length"));
        }
        out.extend(std::iter::repeat(b).take(run as usize));
    }
    Ok(out)
}

/// Entropy-code the LZSS token bytes: framed HUF3 above
/// [`FRAMED_TOKENS_MIN`] (parallel encode on `pool`, parallel decode later),
/// legacy unframed below. The cut is a pure function of the token length,
/// so the chosen bytes never depend on the pool width.
fn huff_tokens(tokens: &[u8], pool: Option<&ThreadPool>) -> (u8, Vec<u8>) {
    let syms: Vec<u16> = tokens.iter().map(|&b| b as u16).collect();
    if tokens.len() >= FRAMED_TOKENS_MIN {
        let opts = huffman::EntropyOptions::default();
        (TAG_LZSS_HUF2, huffman::compress_u16_framed(&syms, 256, pool, &opts))
    } else {
        (TAG_LZSS_HUFF, huffman::compress_u16(&syms, 256))
    }
}

fn unhuff_bytes(data: &[u8], pool: Option<&ThreadPool>) -> Result<Vec<u8>> {
    Ok(huffman::decompress_u16_pooled(data, pool)?.into_iter().map(|s| s as u8).collect())
}

/// Does this blob carry a framed (chunk/segment-parallel) token stream —
/// i.e. would [`decompress_pooled`] actually fan out on a pool?
pub fn is_framed(blob: &[u8]) -> bool {
    blob.first() == Some(&TAG_LZSS_HUF2)
}

/// Compress `data`, choosing the smallest of {store, rle, lzss, lzss+huff}.
/// The winner is picked by length first; the STORE copy of the input is
/// only materialized when it actually wins, instead of cloning the whole
/// input up front (which doubled peak memory on incompressible streams).
/// Ties resolve exactly as the old candidate ordering did: store, then
/// rle, then lzss+huff, then lzss.
pub fn compress(data: &[u8]) -> Vec<u8> {
    compress_pooled(data, None)
}

/// [`compress`] with the Huffman pass over a large token stream encoded
/// concurrently on `pool`. Output bytes are identical for every pool width
/// (including `None`).
pub fn compress_pooled(data: &[u8], pool: Option<&ThreadPool>) -> Vec<u8> {
    let mut best: Option<(u8, Vec<u8>)> = None;
    let mut best_len = data.len(); // the implicit STORE candidate
    let rle = rle_encode(data);
    if rle.len() < best_len {
        best_len = rle.len();
        best = Some((TAG_RLE, rle));
    }
    if data.len() >= MIN_MATCH {
        let tokens = lzss_tokens(data);
        let (htag, hufftok) = huff_tokens(&tokens, pool);
        if hufftok.len() < tokens.len() && hufftok.len() < best_len {
            best = Some((htag, hufftok));
        } else if tokens.len() < best_len {
            best = Some((TAG_LZSS, tokens));
        }
    }
    let (tag, payload) = best.unwrap_or_else(|| (TAG_STORE, data.to_vec()));
    let mut out = Vec::with_capacity(payload.len() + 6);
    out.push(tag);
    put_uvarint(&mut out, data.len() as u64);
    out.extend_from_slice(&payload);
    out
}

/// Inverse of [`compress`].
pub fn decompress(blob: &[u8]) -> Result<Vec<u8>> {
    decompress_pooled(blob, None)
}

/// [`decompress`] with framed token streams decoded concurrently on
/// `pool` (all other tags are serial by nature and ignore it).
pub fn decompress_pooled(blob: &[u8], pool: Option<&ThreadPool>) -> Result<Vec<u8>> {
    if blob.is_empty() {
        return Err(VszError::format("lossless: empty blob"));
    }
    let tag = blob[0];
    let (raw_len, n) =
        get_uvarint(&blob[1..]).ok_or_else(|| VszError::format("lossless: bad header"))?;
    let raw_len = raw_len as usize;
    let payload = &blob[1 + n..];
    match tag {
        TAG_STORE => {
            if payload.len() != raw_len {
                return Err(VszError::format("store: length mismatch"));
            }
            Ok(payload.to_vec())
        }
        TAG_RLE => rle_decode(payload, raw_len),
        TAG_LZSS => lzss_expand(payload, raw_len),
        TAG_LZSS_HUFF | TAG_LZSS_HUF2 => {
            let tokens = unhuff_bytes(payload, pool)?;
            lzss_expand(&tokens, raw_len)
        }
        _ => Err(VszError::format(format!("lossless: unknown tag {tag}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::prng::Pcg32;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let blob = compress(data);
        decompress(&blob).unwrap()
    }

    #[test]
    fn empty_and_tiny() {
        assert_eq!(roundtrip(b""), b"");
        assert_eq!(roundtrip(b"a"), b"a");
        assert_eq!(roundtrip(b"abc"), b"abc");
    }

    #[test]
    fn constant_buffer_uses_rle_or_better() {
        let data = vec![42u8; 100_000];
        let blob = compress(&data);
        assert!(blob.len() < 200, "constant run should collapse, got {}", blob.len());
        assert_eq!(decompress(&blob).unwrap(), data);
    }

    #[test]
    fn repetitive_text_compresses() {
        let data: Vec<u8> =
            b"the quick brown fox jumps over the lazy dog. ".repeat(500).to_vec();
        let blob = compress(&data);
        assert!(blob.len() < data.len() / 5, "got {} of {}", blob.len(), data.len());
        assert_eq!(decompress(&blob).unwrap(), data);
    }

    #[test]
    fn incompressible_random_does_not_blow_up() {
        let mut rng = Pcg32::seeded(123);
        let data: Vec<u8> = (0..50_000).map(|_| rng.next_u32() as u8).collect();
        let blob = compress(&data);
        assert!(blob.len() <= data.len() + 8);
        assert_eq!(decompress(&blob).unwrap(), data);
    }

    #[test]
    fn f32_outlier_stream_shape() {
        // outlier values share exponent bytes -> lzss+huff should win space
        let mut rng = Pcg32::seeded(7);
        let vals: Vec<f32> = (0..20_000).map(|_| 100.0 + rng.next_f32()).collect();
        let bytes = crate::util::f32_as_bytes(&vals);
        let blob = compress(bytes);
        assert!(blob.len() < bytes.len(), "got {} of {}", blob.len(), bytes.len());
        assert_eq!(decompress(&blob).unwrap(), bytes);
    }

    #[test]
    fn long_matches_beyond_max_match() {
        let mut data = vec![0u8; 4096];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i % 7) as u8;
        }
        let mut doubled = data.clone();
        doubled.extend_from_slice(&data);
        assert_eq!(roundtrip(&doubled), doubled);
    }

    #[test]
    fn rejects_corrupt_blobs() {
        let blob = compress(b"hello world hello world hello world");
        let mut bad = blob.clone();
        bad[0] = 99; // unknown tag
        assert!(decompress(&bad).is_err());
        assert!(decompress(&[]).is_err());
        // truncation
        assert!(decompress(&blob[..blob.len().saturating_sub(3)]).is_err());
    }

    #[test]
    fn huf3_framed_side_stream_roundtrips_and_scales() {
        // big run-free stream of ~6-bit-entropy bytes: RLE expands (every
        // run has length ~1) and LZSS stays literal-heavy, so the token
        // stream dwarfs FRAMED_TOKENS_MIN while the Huffman pass (~6 bits
        // per token byte) beats the store candidate outright — the huff
        // candidate must carry the framed tag and decode identically on
        // any pool width
        let mut rng = Pcg32::seeded(61);
        let data: Vec<u8> = (0..600_000).map(|_| rng.bounded(64) as u8).collect();
        let blob = compress(&data);
        assert!(is_framed(&blob), "large token stream did not take the framed path");
        assert_eq!(decompress(&blob).unwrap(), data);
        for nthreads in [2usize, 7] {
            let pool = ThreadPool::new(nthreads);
            // decode fans out over the pool, output identical
            assert_eq!(decompress_pooled(&blob, Some(&pool)).unwrap(), data);
            // encode over the pool is byte-identical
            assert_eq!(compress_pooled(&data, Some(&pool)), blob);
        }
    }

    #[test]
    fn huf3_small_streams_keep_the_legacy_unframed_bytes() {
        // below the cut nothing may change: the pre-v2 encoder's exact
        // bytes (legacy unframed huff tag) must still come out. 20 KB of
        // run-free 4-bit-entropy bytes keeps the token stream well under
        // FRAMED_TOKENS_MIN yet big enough that the Huffman pass clearly
        // beats both the raw tokens and the store candidate.
        let mut rng = Pcg32::seeded(62);
        let data: Vec<u8> = (0..20_000).map(|_| rng.bounded(16) as u8).collect();
        let blob = compress(&data);
        assert_eq!(blob[0], TAG_LZSS_HUFF, "small stream left the legacy format");
        assert!(!is_framed(&blob));
        // and a hand-built legacy blob decodes through the same entry point
        let tokens = lzss_tokens(&data);
        let syms: Vec<u16> = tokens.iter().map(|&b| b as u16).collect();
        let mut legacy = vec![TAG_LZSS_HUFF];
        put_uvarint(&mut legacy, data.len() as u64);
        legacy.extend_from_slice(&huffman::compress_u16(&syms, 256));
        assert_eq!(decompress(&legacy).unwrap(), data);
    }

    #[test]
    fn prop_roundtrip_mixed_content() {
        check("lossless-roundtrip", 80, |g| {
            let n = g.len() * 64;
            let mode = g.rng.bounded(3);
            let data: Vec<u8> = match mode {
                0 => g.bytes(n),
                1 => {
                    // runs
                    let mut v = Vec::with_capacity(n);
                    while v.len() < n {
                        let b = g.rng.next_u32() as u8;
                        let run = 1 + g.rng.bounded(32) as usize;
                        v.extend(std::iter::repeat(b).take(run.min(n - v.len())));
                    }
                    v
                }
                _ => {
                    // repeated motifs
                    let mlen = 1 + g.rng.bounded(24) as usize;
                    let motif = g.bytes(mlen);
                    motif.iter().cycle().take(n).copied().collect()
                }
            };
            let blob = compress(&data);
            let back = decompress(&blob).map_err(|e| e.to_string())?;
            if back == data {
                Ok(())
            } else {
                Err(format!("mismatch mode={mode} n={n}"))
            }
        });
    }
}

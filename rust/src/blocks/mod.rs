//! Block decomposition: fields ⇄ fixed-size compression blocks.
//!
//! SZ decomposes a field into `bs^d` blocks compressed independently
//! (§II-B). Blocks that straddle the field boundary are padded with the
//! block's padding scalar — matching the paper's vectorization strategy of
//! computing on out-of-bounds lanes instead of branching per element
//! (§III-C).
//!
//! [`HaloBlock`] is the kernel-facing layout: a `(bs+1)^d` buffer whose
//! low-side halo planes hold the (pre-quantized) padding scalars, so the
//! Lorenzo neighbour reads `[i-1]` never branch.

/// Field dimensionality + shape. `shape[0..ndim]` are significant; unused
/// trailing entries are 1 so `len()` is always the plain product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dims {
    pub shape: [usize; 3],
    pub ndim: usize,
}

impl Dims {
    pub fn d1(n: usize) -> Self {
        Self { shape: [n, 1, 1], ndim: 1 }
    }
    pub fn d2(rows: usize, cols: usize) -> Self {
        Self { shape: [rows, cols, 1], ndim: 2 }
    }
    pub fn d3(planes: usize, rows: usize, cols: usize) -> Self {
        Self { shape: [planes, rows, cols], ndim: 3 }
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.shape[0] * self.shape[1] * self.shape[2]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocks along each axis for block size `bs` (ceil division).
    pub fn block_grid(&self, bs: usize) -> [usize; 3] {
        let mut g = [1usize; 3];
        for a in 0..self.ndim {
            g[a] = self.shape[a].div_ceil(bs);
        }
        g
    }

    /// Total number of blocks.
    pub fn num_blocks(&self, bs: usize) -> usize {
        let g = self.block_grid(bs);
        g[0] * g[1] * g[2]
    }

    /// Linear block index -> block coordinates in the block grid.
    pub fn block_coords(&self, bs: usize, b: usize) -> [usize; 3] {
        let g = self.block_grid(bs);
        match self.ndim {
            1 => [b, 0, 0],
            2 => [b / g[1], b % g[1], 0],
            3 => [b / (g[1] * g[2]), (b / g[2]) % g[1], b % g[2]],
            _ => unreachable!("ndim must be 1..=3"),
        }
    }

    /// Row-major linear index of an element coordinate.
    #[inline]
    pub fn linear(&self, c: [usize; 3]) -> usize {
        match self.ndim {
            1 => c[0],
            2 => c[0] * self.shape[1] + c[1],
            3 => (c[0] * self.shape[1] + c[1]) * self.shape[2] + c[2],
            _ => unreachable!(),
        }
    }
}

/// Per-(ndim, bs) block geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockShape {
    pub ndim: usize,
    pub bs: usize,
}

impl BlockShape {
    pub fn new(ndim: usize, bs: usize) -> Self {
        assert!((1..=3).contains(&ndim), "ndim must be 1..=3");
        assert!(bs >= 2, "block size must be >= 2");
        Self { ndim, bs }
    }

    /// Elements per block.
    pub fn elems(&self) -> usize {
        self.bs.pow(self.ndim as u32)
    }

    /// Halo-buffer side length and total size.
    pub fn halo_side(&self) -> usize {
        self.bs + 1
    }
    pub fn halo_elems(&self) -> usize {
        self.halo_side().pow(self.ndim as u32)
    }
}

/// Gather block `b` of `field` into `out` (length `bs^d`, row-major within
/// the block). Out-of-field elements are filled with `fill`.
pub fn gather_block(field: &[f32], dims: &Dims, bs: usize, b: usize, fill: f32, out: &mut [f32]) {
    let shape = BlockShape::new(dims.ndim, bs);
    debug_assert_eq!(out.len(), shape.elems());
    let bc = dims.block_coords(bs, b);
    match dims.ndim {
        1 => {
            let base = bc[0] * bs;
            let n = dims.shape[0];
            let valid = n.saturating_sub(base).min(bs);
            out[..valid].copy_from_slice(&field[base..base + valid]);
            out[valid..].fill(fill);
        }
        2 => {
            let (r0, c0) = (bc[0] * bs, bc[1] * bs);
            let (nr, nc) = (dims.shape[0], dims.shape[1]);
            for i in 0..bs {
                let row = &mut out[i * bs..(i + 1) * bs];
                let r = r0 + i;
                if r >= nr {
                    row.fill(fill);
                    continue;
                }
                let valid = nc.saturating_sub(c0).min(bs);
                let src = r * nc + c0;
                row[..valid].copy_from_slice(&field[src..src + valid]);
                row[valid..].fill(fill);
            }
        }
        3 => {
            let (p0, r0, c0) = (bc[0] * bs, bc[1] * bs, bc[2] * bs);
            let (np, nr, nc) = (dims.shape[0], dims.shape[1], dims.shape[2]);
            for k in 0..bs {
                for i in 0..bs {
                    let row = &mut out[(k * bs + i) * bs..(k * bs + i + 1) * bs];
                    let (p, r) = (p0 + k, r0 + i);
                    if p >= np || r >= nr {
                        row.fill(fill);
                        continue;
                    }
                    let valid = nc.saturating_sub(c0).min(bs);
                    let src = (p * nr + r) * nc + c0;
                    row[..valid].copy_from_slice(&field[src..src + valid]);
                    row[valid..].fill(fill);
                }
            }
        }
        _ => unreachable!(),
    }
}

/// Scatter block `b` back into `field`, skipping out-of-field elements.
pub fn scatter_block(block: &[f32], dims: &Dims, bs: usize, b: usize, field: &mut [f32]) {
    let bc = dims.block_coords(bs, b);
    match dims.ndim {
        1 => {
            let base = bc[0] * bs;
            let n = dims.shape[0];
            let valid = n.saturating_sub(base).min(bs);
            field[base..base + valid].copy_from_slice(&block[..valid]);
        }
        2 => {
            let (r0, c0) = (bc[0] * bs, bc[1] * bs);
            let (nr, nc) = (dims.shape[0], dims.shape[1]);
            for i in 0..bs {
                let r = r0 + i;
                if r >= nr {
                    break;
                }
                let valid = nc.saturating_sub(c0).min(bs);
                let dst = r * nc + c0;
                field[dst..dst + valid].copy_from_slice(&block[i * bs..i * bs + valid]);
            }
        }
        3 => {
            let (p0, r0, c0) = (bc[0] * bs, bc[1] * bs, bc[2] * bs);
            let (np, nr, nc) = (dims.shape[0], dims.shape[1], dims.shape[2]);
            for k in 0..bs {
                let p = p0 + k;
                if p >= np {
                    break;
                }
                for i in 0..bs {
                    let r = r0 + i;
                    if r >= nr {
                        break;
                    }
                    let valid = nc.saturating_sub(c0).min(bs);
                    let dst = (p * nr + r) * nc + c0;
                    field[dst..dst + valid]
                        .copy_from_slice(&block[(k * bs + i) * bs..(k * bs + i) * bs + valid]);
                }
            }
        }
        _ => unreachable!(),
    }
}

/// `(bs+1)^d` working buffer whose low-side halo planes carry padding
/// scalars; the interior holds the block payload. Neighbour reads in the
/// Lorenzo predictor are then branch-free.
pub struct HaloBlock {
    pub buf: Vec<f32>,
    pub shape: BlockShape,
}

impl HaloBlock {
    pub fn new(shape: BlockShape) -> Self {
        Self { buf: vec![0.0; shape.halo_elems()], shape }
    }

    /// Halo-buffer strides (row-major over side `bs+1`).
    #[inline]
    pub fn strides(&self) -> [usize; 3] {
        let s = self.shape.halo_side();
        match self.shape.ndim {
            1 => [1, 0, 0],
            2 => [s, 1, 0],
            3 => [s * s, s, 1],
            _ => unreachable!(),
        }
    }

    /// Linear halo index of interior element coordinates (each +1 shifted).
    #[inline]
    pub fn interior_index(&self, c: [usize; 3]) -> usize {
        let st = self.strides();
        match self.shape.ndim {
            1 => c[0] + 1,
            2 => (c[0] + 1) * st[0] + (c[1] + 1),
            3 => (c[0] + 1) * st[0] + (c[1] + 1) * st[1] + (c[2] + 1),
            _ => unreachable!(),
        }
    }

    /// Fill every halo plane. `edge_pad(axis)` supplies the scalar for the
    /// low-side plane orthogonal to `axis`; planes are written in ascending
    /// axis order, so shared halo cells (corners/edges) take the scalar of
    /// the **highest-numbered axis** — the decompressor uses the identical
    /// rule, so prediction is reproducible.
    pub fn fill_halo(&mut self, edge_pad: impl Fn(usize) -> f32) {
        let side = self.shape.halo_side();
        match self.shape.ndim {
            1 => self.buf[0] = edge_pad(0),
            2 => {
                let p0 = edge_pad(0);
                for j in 0..side {
                    self.buf[j] = p0; // row 0
                }
                let p1 = edge_pad(1);
                for i in 0..side {
                    self.buf[i * side] = p1; // col 0
                }
            }
            3 => {
                let p0 = edge_pad(0);
                for i in 0..side * side {
                    self.buf[i] = p0; // plane k=0
                }
                let p1 = edge_pad(1);
                for k in 0..side {
                    for j in 0..side {
                        self.buf[k * side * side + j] = p1; // row i=0 per plane
                    }
                }
                let p2 = edge_pad(2);
                for k in 0..side {
                    for i in 0..side {
                        self.buf[(k * side + i) * side] = p2; // col j=0
                    }
                }
            }
            _ => unreachable!(),
        }
    }

    /// Copy a gathered `bs^d` block into the interior, applying `f` to each
    /// element (used to pre-quantize during the copy).
    pub fn load_interior(&mut self, block: &[f32], f: impl Fn(f32) -> f32) {
        let bs = self.shape.bs;
        let side = self.shape.halo_side();
        match self.shape.ndim {
            1 => {
                for i in 0..bs {
                    self.buf[i + 1] = f(block[i]);
                }
            }
            2 => {
                for i in 0..bs {
                    let src = &block[i * bs..(i + 1) * bs];
                    let dst = (i + 1) * side + 1;
                    for j in 0..bs {
                        self.buf[dst + j] = f(src[j]);
                    }
                }
            }
            3 => {
                for k in 0..bs {
                    for i in 0..bs {
                        let src = &block[(k * bs + i) * bs..(k * bs + i + 1) * bs];
                        let dst = ((k + 1) * side + i + 1) * side + 1;
                        for j in 0..bs {
                            self.buf[dst + j] = f(src[j]);
                        }
                    }
                }
            }
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn dims_basics() {
        let d = Dims::d2(10, 7);
        assert_eq!(d.len(), 70);
        assert_eq!(d.block_grid(4), [3, 2, 1]);
        assert_eq!(d.num_blocks(4), 6);
        assert_eq!(d.block_coords(4, 5), [2, 1, 0]);
        assert_eq!(d.linear([2, 3, 0]), 17);
    }

    #[test]
    fn dims_3d_coords_roundtrip() {
        let d = Dims::d3(5, 6, 7);
        let bs = 4;
        let g = d.block_grid(bs);
        assert_eq!(g, [2, 2, 2]);
        for b in 0..d.num_blocks(bs) {
            let c = d.block_coords(bs, b);
            let lin = (c[0] * g[1] + c[1]) * g[2] + c[2];
            assert_eq!(lin, b);
        }
    }

    #[test]
    fn gather_exact_block() {
        // 4x4 field, bs=2, block 3 = bottom-right
        let field: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let dims = Dims::d2(4, 4);
        let mut out = [0.0f32; 4];
        gather_block(&field, &dims, 2, 3, -1.0, &mut out);
        assert_eq!(out, [10.0, 11.0, 14.0, 15.0]);
    }

    #[test]
    fn gather_pads_boundary_blocks() {
        // 3x3 field, bs=2 -> grid 2x2; block 3 covers only element (2,2)
        let field: Vec<f32> = (0..9).map(|x| x as f32).collect();
        let dims = Dims::d2(3, 3);
        let mut out = [0.0f32; 4];
        gather_block(&field, &dims, 2, 3, 99.0, &mut out);
        assert_eq!(out, [8.0, 99.0, 99.0, 99.0]);
    }

    #[test]
    fn prop_gather_scatter_identity() {
        check("gather-scatter", 100, |g| {
            let ndim = 1 + g.rng.bounded(3) as usize;
            let bs = *g.choose(&[2usize, 3, 4, 8]);
            let mut shape = [1usize; 3];
            for a in shape.iter_mut().take(ndim) {
                *a = 1 + g.rng.bounded(17) as usize;
            }
            let dims = Dims { shape, ndim };
            let field = g.f32_vec(dims.len(), 10.0);
            let mut rebuilt = vec![f32::NAN; dims.len()];
            let mut block = vec![0.0f32; bs.pow(ndim as u32)];
            for b in 0..dims.num_blocks(bs) {
                gather_block(&field, &dims, bs, b, 0.0, &mut block);
                scatter_block(&block, &dims, bs, b, &mut rebuilt);
            }
            if rebuilt == field {
                Ok(())
            } else {
                Err(format!("mismatch ndim={ndim} bs={bs} shape={shape:?}"))
            }
        });
    }

    #[test]
    fn halo_fill_and_interior_2d() {
        let shape = BlockShape::new(2, 3);
        let mut h = HaloBlock::new(shape);
        h.fill_halo(|axis| if axis == 0 { 1.0 } else { 2.0 });
        // corner (0,0) written by axis 1 last
        assert_eq!(h.buf[0], 2.0);
        assert_eq!(h.buf[1], 1.0); // row 0 body
        assert_eq!(h.buf[4], 2.0); // col 0 body
        let block = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        h.load_interior(&block, |x| x * 2.0);
        assert_eq!(h.buf[h.interior_index([0, 0, 0])], 2.0);
        assert_eq!(h.buf[h.interior_index([2, 2, 0])], 18.0);
    }

    #[test]
    fn halo_fill_3d_precedence() {
        let shape = BlockShape::new(3, 2);
        let mut h = HaloBlock::new(shape);
        h.fill_halo(|axis| axis as f32);
        let side = shape.halo_side();
        // cell (0,0,0): written by plane-0 (axis0), then row (axis1), then col (axis2)
        assert_eq!(h.buf[0], 2.0);
        // cell (0, 1, 1): only in plane k=0 -> axis 0 scalar
        assert_eq!(h.buf[side + 1], 0.0);
        // cell (1, 0, 1): row halo of plane 1 -> axis 1
        assert_eq!(h.buf[side * side + 1], 1.0);
        // cell (1, 1, 0): col halo -> axis 2
        assert_eq!(h.buf[side * side + side], 2.0);
    }
}

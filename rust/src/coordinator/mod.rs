//! Layer-3 coordination, split into three layers (bottom up):
//!
//! * [`pool`] — raw substrate: thread pool / parallel-for (the paper's
//!   OpenMP analog), a FIFO injector with no job identity.
//! * [`exec`] — job-graph executor on top of the pool: dependencies,
//!   priorities, cancellation, bounded submission and a completion-ordered
//!   result channel. `scatter_gather` is a thin wrapper over it.
//! * [`sched`] — two-level (fields × chunks) scheduler on top of the
//!   executor, interleaving chunk jobs from many fields across the whole
//!   pool and feeding an asynchronous [`sched::OrderedWriter`] sink.
//!
//! [`pipeline`] (the time-step streaming driver and the batch driver)
//! sits above all three.

pub mod exec;
pub mod pipeline;
pub mod pool;
pub mod sched;

//! Layer-3 coordination: thread pool / parallel-for (the paper's OpenMP
//! analog) and the streaming compression pipeline (see `pipeline`).

pub mod pipeline;
pub mod pool;

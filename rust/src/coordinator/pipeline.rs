//! Streaming compression pipeline — the Layer-3 coordinator proper.
//!
//! Scientific simulations emit one field-set per time-step; the pipeline
//! overlaps production (I/O / simulation), compression (CPU-parallel) and
//! the sink (storage) with bounded queues for backpressure, and autotunes
//! the (block size × lane width) configuration on the first step, re-tuning
//! every `retune_every` steps (§V-F: the winning configuration is stable
//! across time-steps, so tuning amortizes).

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;

use crate::autotune::{autotune, TuneConfig, TuneSettings};
use crate::compressor::{
    compress, default_block_size, Config, CompressStats, EbMode,
};
use crate::coordinator::exec::{Executor, JobSpec, JobStatus};
use crate::coordinator::pool::ThreadPool;
use crate::coordinator::sched::{self, FieldSpec};
use crate::data::Field;
use crate::error::{Result, VszError};
use crate::metrics::{CompressionStats, SizeStats};
use crate::stream;
use crate::util::timer::{StageProfile, Timer};

/// Pipeline configuration.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub base: Config,
    /// Autotune on step 0 and every `retune_every` steps (0 = never tune).
    pub retune_every: usize,
    pub tune: TuneSettings,
    /// Lane widths to consider (host capability).
    pub widths: [usize; 2],
    /// Bounded queue depth between stages (backpressure).
    pub queue_depth: usize,
    /// `Some(span)`: write each step as an indexed (VSZ3) chunked
    /// streaming container with this leading-dim chunk span (0 = default
    /// span) — the path for time-step fields larger than RAM. `None`:
    /// monolithic v1 containers.
    pub chunked: Option<usize>,
    /// With `chunked`: re-run the autotuner on each chunk's slab instead
    /// of once per step, so the configuration tracks non-stationary
    /// fields. The per-step whole-field tune is skipped then.
    pub chunk_autotune: bool,
    /// Decode every compressed step back through the decode engine (the
    /// SIMD reverse-Lorenzo wavefront on the active ISA) and verify the
    /// error bound before handing the bytes to the sink — the production
    /// integrity guard for archival pipelines. Errors abort the run.
    pub verify: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            base: Config::default(),
            retune_every: 16,
            tune: TuneSettings::default(),
            widths: [8, 16],
            queue_depth: 2,
            chunked: None,
            chunk_autotune: false,
            verify: false,
        }
    }
}

/// Per-time-step report.
#[derive(Clone, Debug)]
pub struct StepReport {
    pub step: usize,
    pub field_name: String,
    pub stats: CompressStats,
    pub tuned: Option<TuneConfig>,
    pub tune_seconds: f64,
    /// Seconds the compressor stage waited for input (pipeline bubble).
    pub stall_seconds: f64,
}

/// Output of a pipeline run.
#[derive(Debug, Default)]
pub struct PipelineReport {
    pub steps: Vec<StepReport>,
    pub total_seconds: f64,
}

impl PipelineReport {
    pub fn total_raw_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.stats.size.raw_bytes).sum()
    }
    pub fn total_compressed_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.stats.size.compressed_bytes).sum()
    }
    pub fn overall_ratio(&self) -> f64 {
        self.total_raw_bytes() as f64 / self.total_compressed_bytes().max(1) as f64
    }
    pub fn mean_pq_mbs(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|s| s.stats.pq_bandwidth_mbs()).sum::<f64>()
            / self.steps.len() as f64
    }
    pub fn tune_overhead_pct(&self) -> f64 {
        let tune: f64 = self.steps.iter().map(|s| s.tune_seconds).sum();
        100.0 * tune / self.total_seconds.max(f64::MIN_POSITIVE)
    }

    /// Fold the per-step numbers into the crate-wide
    /// [`CompressionStats`] aggregate (one compression op per step; the
    /// producer-wait bubble counts as queue wait).
    pub fn compression_stats(&self) -> CompressionStats {
        let mut total = CompressionStats::new();
        for s in &self.steps {
            let mut one = CompressionStats::new();
            one.record_compress(
                s.stats.size.raw_bytes,
                s.stats.size.compressed_bytes,
                s.stats.pq_seconds,
            );
            one.record_queue_wait(s.stall_seconds);
            total.merge(&one);
        }
        total
    }
}

/// Run the pipeline over a producer of time-step fields, handing each
/// compressed container to `sink`.
///
/// The producer runs on its own thread; the bounded channel gives the
/// paper-style backpressure (a slow sink throttles production instead of
/// buffering unboundedly).
pub fn run_stream(
    producer: impl FnMut(usize) -> Option<Field> + Send + 'static,
    cfg: PipelineConfig,
    mut sink: impl FnMut(usize, Vec<u8>) -> Result<()>,
) -> Result<PipelineReport> {
    let t_total = Timer::start();
    let rx = spawn_producer(producer, cfg.queue_depth);

    // one shared worker pool for every chunked step (the old path built a
    // fresh pool inside each step's streaming writer)
    let pool = cfg.chunked.map(|_| ThreadPool::new(cfg.base.threads.max(1)));
    let mut report = PipelineReport::default();
    let mut current: Option<TuneConfig> = None;
    let mut step = 0usize;
    loop {
        let t_wait = Timer::start();
        let field = match rx.recv() {
            Ok(Some(f)) => f,
            Ok(None) | Err(_) => break,
        };
        let stall_seconds = t_wait.elapsed_s();

        // resolve eb once per field for tuning purposes
        let eb = cfg.base.eb.resolve(&field.data);
        let mut tuned = None;
        let mut tune_seconds = 0.0;
        let per_chunk_tuning = cfg.chunked.is_some() && cfg.chunk_autotune;
        let retune = cfg.retune_every > 0
            && !per_chunk_tuning
            && (step % cfg.retune_every == 0 || current.is_none());
        if retune {
            let r = autotune(&field, eb, cfg.base.radius, cfg.base.padding, &cfg.widths, cfg.tune);
            tune_seconds = r.tune_seconds;
            tuned = Some(r.best);
            current = Some(r.best);
        }
        let mut c = cfg.base;
        if let Some(tc) = current {
            c.block_size = tc.block_size;
            c.backend = tc.backend_choice();
        }
        let (field, bytes, stats) = match cfg.chunked {
            Some(span) => {
                // move the field into the scheduler's shared slab; every
                // chunk job drops its handle before its status is sent, so
                // after the call the Arc is sole-owned again
                let shared = Arc::new(vec![field]);
                let pool = pool.as_ref().expect("pool exists in chunked mode");
                let (bytes, stats) =
                    compress_step_chunked(&shared, &c, eb, span, &cfg, pool)?;
                let field = Arc::try_unwrap(shared)
                    .map_err(|_| VszError::runtime("chunk job leaked a field handle"))?
                    .pop()
                    .expect("one field per step");
                (field, bytes, stats)
            }
            None => {
                let (bytes, stats) = compress(&field, &c)?;
                (field, bytes, stats)
            }
        };
        if cfg.verify {
            verify_step(step, &field, &bytes, stats.eb, c.threads)?;
        }
        sink(step, bytes)?;
        report.steps.push(StepReport {
            step,
            field_name: field.name.clone(),
            stats,
            tuned,
            tune_seconds,
            stall_seconds,
        });
        step += 1;
    }
    report.total_seconds = t_total.elapsed_s();
    Ok(report)
}

/// Decode one compressed step back (any container version, through the
/// decode backend engine) and check the error bound against the original
/// field — the [`PipelineConfig::verify`] integrity guard.
fn verify_step(step: usize, field: &Field, bytes: &[u8], eb: f64, threads: usize) -> Result<()> {
    let rec = crate::compressor::decompress(bytes, threads)?;
    if rec.data.len() != field.data.len() {
        return Err(VszError::Integrity(format!(
            "step {step}: decode verification failed ({} values decoded, expected {})",
            rec.data.len(),
            field.data.len()
        )));
    }
    let mut max_err = 0.0f64;
    for (o, r) in field.data.iter().zip(&rec.data) {
        max_err = max_err.max((*o as f64 - *r as f64).abs());
    }
    let tol = crate::metrics::roundtrip_tolerance(eb, crate::metrics::value_range(&field.data));
    if max_err > tol {
        return Err(VszError::Integrity(format!(
            "step {step}: decode verification failed (max err {max_err:.3e} > tolerance \
             {tol:.3e}, eb {eb:.3e})"
        )));
    }
    Ok(())
}

/// Compress one time-step through the indexed streaming container (the
/// out-of-core path of [`run_stream`]), scheduling its chunks on the
/// pipeline's shared pool, and map the resulting [`stream::StreamStats`]
/// onto the per-step [`CompressStats`] the report carries.
fn compress_step_chunked(
    shared: &Arc<Vec<Field>>,
    c: &Config,
    eb: f64,
    span: usize,
    cfg: &PipelineConfig,
    pool: &ThreadPool,
) -> Result<(Vec<u8>, CompressStats)> {
    let field = &shared[0];
    // the chunked writer requires an absolute bound; eb is already
    // resolved against this field
    let mut c = *c;
    c.eb = EbMode::Abs(eb);
    let opts = stream::StreamOptions {
        chunk_autotune: cfg.chunk_autotune.then_some(cfg.tune),
        tune_widths: cfg.widths,
        ..stream::StreamOptions::default()
    };
    let backend_name = c.backend.instantiate().name();
    let spec = FieldSpec { cfg: c, span, opts };
    let mut results =
        sched::compress_fields_chunked(pool, Arc::clone(shared), &[spec], None)?;
    let sched::FieldResult { bytes, stats: s } = results.pop().expect("one result per field");
    let bs = if c.block_size == 0 { default_block_size(field.dims.ndim) } else { c.block_size };
    let mut profile = StageProfile::new();
    profile.add("pq", s.pq_seconds);
    let stats = CompressStats {
        n_elements: s.n_elements,
        n_blocks: field.dims.num_blocks(bs),
        n_outliers: s.n_outliers,
        eb,
        block_size: bs,
        backend: backend_name,
        pq_seconds: s.pq_seconds,
        profile,
        size: SizeStats { raw_bytes: s.raw_bytes, compressed_bytes: s.compressed_bytes },
    };
    Ok((bytes, stats))
}

fn spawn_producer(
    mut producer: impl FnMut(usize) -> Option<Field> + Send + 'static,
    depth: usize,
) -> Receiver<Option<Field>> {
    let (tx, rx) = sync_channel::<Option<Field>>(depth.max(1));
    std::thread::spawn(move || {
        let mut i = 0usize;
        loop {
            let item = producer(i);
            let done = item.is_none();
            if tx.send(item).is_err() {
                break; // consumer gone
            }
            if done {
                break;
            }
            i += 1;
        }
    });
    rx
}

/// Convenience: compress a whole dataset (all fields) as one "time-step"
/// batch, returning per-field stats — the CLI `compress --suite` path.
pub fn compress_dataset(
    fields: &[Field],
    cfg: &Config,
) -> Result<Vec<(String, Vec<u8>, CompressStats)>> {
    fields
        .iter()
        .map(|f| {
            let (bytes, stats) = compress(f, cfg)?;
            Ok((f.name.clone(), bytes, stats))
        })
        .collect::<Result<Vec<_>>>()
        .map_err(|e: VszError| e)
}

/// One compressed field of a batch run (container bytes + the numbers the
/// batch report prints, normalized across v1 and chunked-v2 containers).
#[derive(Clone, Debug)]
pub struct BatchItem {
    pub name: String,
    pub bytes: Vec<u8>,
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
    pub n_outliers: usize,
    pub pq_seconds: f64,
    /// Chunks in the container (1 for a v1 container).
    pub n_chunks: usize,
}

impl BatchItem {
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes.max(1) as f64
    }
}

/// Multi-field batch driver over the two-level scheduler.
///
/// With `chunked = Some(chunk_span)` every field is written as an indexed
/// chunked streaming container and — unlike the old one-worker-per-field
/// driver — every field is decomposed into chunk jobs that interleave
/// across the whole pool, so a batch of mixed-size fields keeps all
/// `pool_threads` workers busy until the last chunk (range-relative bounds
/// are resolved per field first). Without `chunked`, fields compress as
/// monolithic v1 containers, one job per field, through the same executor.
/// Results come back in input order, byte-identical for any pool width.
pub fn compress_batch(
    fields: Vec<Field>,
    cfg: &Config,
    pool_threads: usize,
    chunked: Option<usize>,
) -> Result<Vec<BatchItem>> {
    compress_batch_traced(fields, cfg, pool_threads, chunked, None)
}

/// [`compress_batch`] with an optional scheduler trace hook (test
/// instrumentation for the chunk-interleaving regression test).
pub fn compress_batch_traced(
    fields: Vec<Field>,
    cfg: &Config,
    pool_threads: usize,
    chunked: Option<usize>,
    trace: Option<sched::TraceHook>,
) -> Result<Vec<BatchItem>> {
    if fields.is_empty() {
        return Ok(Vec::new());
    }
    let mut cfg = *cfg;
    cfg.threads = 1;
    let n = fields.len();
    let pool = ThreadPool::new(pool_threads.max(1));

    if let Some(span) = chunked {
        let names: Vec<String> = fields.iter().map(|f| f.name.clone()).collect();
        let specs: Vec<FieldSpec> = fields
            .iter()
            .map(|f| {
                let mut c = cfg;
                if matches!(c.eb, EbMode::Rel(_)) {
                    c.eb = EbMode::Abs(c.eb.resolve(&f.data));
                }
                FieldSpec { cfg: c, span, opts: stream::StreamOptions::default() }
            })
            .collect();
        let results = sched::compress_fields_chunked(&pool, Arc::new(fields), &specs, trace)?;
        return Ok(results
            .into_iter()
            .zip(names)
            .map(|(r, name)| BatchItem {
                name,
                raw_bytes: r.stats.raw_bytes,
                compressed_bytes: r.stats.compressed_bytes,
                n_outliers: r.stats.n_outliers,
                pq_seconds: r.stats.pq_seconds,
                n_chunks: r.stats.n_chunks,
                bytes: r.bytes,
            })
            .collect());
    }

    // v1 containers: one job per field, through the executor
    let shared = Arc::new(fields);
    let mut exec: Executor<Result<BatchItem>> = Executor::new(&pool, n);
    for i in 0..n {
        let shared = Arc::clone(&shared);
        exec.submit(JobSpec::default(), move || {
            let f = &shared[i];
            let (bytes, stats) = compress(f, &cfg)?;
            Ok(BatchItem {
                name: f.name.clone(),
                bytes,
                raw_bytes: stats.size.raw_bytes,
                compressed_bytes: stats.size.compressed_bytes,
                n_outliers: stats.n_outliers,
                pq_seconds: stats.pq_seconds,
                n_chunks: 1,
            })
        })?;
    }
    let mut out: Vec<Option<BatchItem>> = (0..n).map(|_| None).collect();
    for _ in 0..n {
        let (id, status) =
            exec.recv().ok_or_else(|| VszError::runtime("executor channel closed"))?;
        match status {
            JobStatus::Done(Ok(item)) => out[id as usize] = Some(item),
            JobStatus::Done(Err(e)) => return Err(e),
            JobStatus::Cancelled => return Err(VszError::runtime("batch job cancelled")),
            JobStatus::Failed(m) => {
                return Err(VszError::runtime(format!("batch job failed: {m}")))
            }
        }
    }
    Ok(out.into_iter().map(|o| o.expect("missing batch item")).collect())
}

/// Fold a batch run into the crate-wide [`CompressionStats`] aggregate
/// (one compression op per field).
pub fn batch_stats(items: &[BatchItem]) -> CompressionStats {
    let mut total = CompressionStats::new();
    for it in items {
        let mut one = CompressionStats::new();
        one.record_compress(it.raw_bytes, it.compressed_bytes, it.pq_seconds);
        total.merge(&one);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::Dims;
    use crate::compressor::EbMode;
    use crate::util::prng::Pcg32;

    fn step_field(step: usize) -> Field {
        // slowly-evolving time series: base field + step-dependent drift
        let dims = Dims::d2(64, 64);
        let mut rng = Pcg32::seeded(1234);
        let mut x = 0.0f32;
        let data: Vec<f32> = (0..dims.len())
            .map(|_| {
                x += (rng.next_f32() - 0.5) * 0.05;
                x + step as f32 * 0.01
            })
            .collect();
        Field::new(format!("ts{step}"), dims, data)
    }

    #[test]
    fn pipeline_compresses_all_steps_in_order() {
        let cfg = PipelineConfig {
            base: Config { eb: EbMode::Abs(1e-3), ..Config::default() },
            retune_every: 4,
            tune: TuneSettings { sample_pct: 20.0, iterations: 1, seed: 2 },
            ..PipelineConfig::default()
        };
        let mut received = Vec::new();
        let report = run_stream(
            |i| if i < 6 { Some(step_field(i)) } else { None },
            cfg,
            |step, bytes| {
                received.push((step, bytes.len()));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(report.steps.len(), 6);
        assert_eq!(received.len(), 6);
        assert!(received.windows(2).all(|w| w[0].0 + 1 == w[1].0), "in order");
        // tuned at steps 0 and 4 only
        assert!(report.steps[0].tuned.is_some());
        assert!(report.steps[1].tuned.is_none());
        assert!(report.steps[4].tuned.is_some());
        assert!(report.overall_ratio() > 1.0);
        assert!(report.tune_overhead_pct() < 100.0);
    }

    #[test]
    fn pipeline_without_tuning_uses_base_config() {
        let cfg = PipelineConfig { retune_every: 0, ..PipelineConfig::default() };
        let report = run_stream(
            |i| if i < 2 { Some(step_field(i)) } else { None },
            cfg,
            |_, _| Ok(()),
        )
        .unwrap();
        assert!(report.steps.iter().all(|s| s.tuned.is_none()));
    }

    #[test]
    fn sink_error_propagates() {
        let cfg = PipelineConfig { retune_every: 0, ..PipelineConfig::default() };
        let err = run_stream(
            |i| if i < 3 { Some(step_field(i)) } else { None },
            cfg,
            |step, _| {
                if step == 1 {
                    Err(VszError::runtime("disk full"))
                } else {
                    Ok(())
                }
            },
        );
        assert!(err.is_err());
    }

    #[test]
    fn batch_driver_preserves_order_and_content() {
        let fields: Vec<Field> = (0..6).map(step_field).collect();
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let serial = compress_batch(fields.clone(), &cfg, 1, None).unwrap();
        let parallel = compress_batch(fields.clone(), &cfg, 4, None).unwrap();
        assert_eq!(serial.len(), 6);
        for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
            assert_eq!(a.name, fields[i].name, "order changed");
            assert_eq!(a.bytes, b.bytes, "pool width changed the bitstream of {}", a.name);
            assert!(a.ratio() > 1.0);
        }
        // every container decompresses within the bound
        for (i, item) in serial.iter().enumerate() {
            let rec = crate::compressor::decompress(&item.bytes, 1).unwrap();
            for (o, r) in fields[i].data.iter().zip(&rec.data) {
                assert!((o - r).abs() <= 1e-3 + 1e-5);
            }
        }
    }

    #[test]
    fn chunked_batch_bytes_independent_of_pool_width() {
        // the hard invariant: chunk-level scheduling must not change a
        // single output byte relative to the serial streaming writer
        let fields: Vec<Field> = (0..4).map(step_field).collect();
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let serial = compress_batch(fields.clone(), &cfg, 1, Some(16)).unwrap();
        for threads in [2usize, 7] {
            let par = compress_batch(fields.clone(), &cfg, threads, Some(16)).unwrap();
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.bytes, b.bytes, "{} at {threads} threads", a.name);
            }
        }
        for (i, item) in serial.iter().enumerate() {
            let (reference, _) = stream::compress_chunked(&fields[i], &cfg, 16).unwrap();
            assert_eq!(item.bytes, reference, "{}", item.name);
        }
    }

    #[test]
    fn mixed_batch_interleaves_chunk_jobs_across_fields() {
        // worker-starvation regression: one large + one small field must
        // not serialize field-by-field — the first two chunk jobs to start
        // always come from distinct fields under round-robin submission
        let mk = |name: &str, rows: usize, seed: u64| {
            let dims = Dims::d2(rows, 64);
            let mut rng = Pcg32::seeded(seed);
            let data: Vec<f32> = (0..dims.len()).map(|_| rng.next_f32()).collect();
            Field::new(name.to_string(), dims, data)
        };
        let fields = vec![mk("big", 128, 11), mk("small", 32, 12)];
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let seen = Arc::new(std::sync::Mutex::new(Vec::<(usize, usize)>::new()));
        let hook: crate::coordinator::sched::TraceHook = {
            let seen = Arc::clone(&seen);
            Arc::new(move |f, c| seen.lock().unwrap().push((f, c)))
        };
        let traced =
            compress_batch_traced(fields.clone(), &cfg, 2, Some(16), Some(hook)).unwrap();
        let order = seen.lock().unwrap().clone();
        assert_eq!(order.len(), 8 + 2, "every chunk job traced");
        assert_ne!(order[0].0, order[1].0, "first two chunk jobs from distinct fields");
        // interleaved scheduling stays byte-identical to the serial path
        let serial = compress_batch(fields, &cfg, 1, Some(16)).unwrap();
        for (a, b) in serial.iter().zip(&traced) {
            assert_eq!(a.bytes, b.bytes, "{}", a.name);
        }
    }

    #[test]
    fn batch_and_pipeline_fill_compression_stats() {
        let fields: Vec<Field> = (0..3).map(step_field).collect();
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let items = compress_batch(fields, &cfg, 2, None).unwrap();
        let s = batch_stats(&items);
        assert_eq!(s.compress_ops, 3);
        assert_eq!(s.bytes_in as usize, items.iter().map(|i| i.raw_bytes).sum::<usize>());
        assert!(s.min_ratio > 1.0 && s.min_ratio <= s.max_ratio);
        assert!(s.mean_ratio() >= s.min_ratio && s.mean_ratio() <= s.max_ratio);

        let pcfg = PipelineConfig { retune_every: 0, ..PipelineConfig::default() };
        let report = run_stream(
            |i| if i < 2 { Some(step_field(i)) } else { None },
            pcfg,
            |_, _| Ok(()),
        )
        .unwrap();
        let ps = report.compression_stats();
        assert_eq!(ps.compress_ops, 2);
        assert!(ps.queue_wait_s >= 0.0);
    }

    #[test]
    fn batch_driver_chunked_mode_emits_indexed_containers() {
        let fields: Vec<Field> = (0..3).map(step_field).collect();
        let cfg = Config { eb: EbMode::Rel(1e-3), ..Config::default() };
        let items = compress_batch(fields.clone(), &cfg, 2, Some(16)).unwrap();
        for (i, item) in items.iter().enumerate() {
            assert!(crate::format::is_chunked_container(&item.bytes), "{}", item.name);
            assert_eq!(&item.bytes[..4], crate::format::MAGIC3, "{}", item.name);
            assert!(item.n_chunks >= 4, "{} chunks", item.n_chunks);
            let rec = crate::compressor::decompress(&item.bytes, 2).unwrap();
            assert_eq!(rec.data.len(), fields[i].data.len());
        }
    }

    #[test]
    fn run_stream_chunked_mode_emits_decodable_indexed_containers() {
        let cfg = PipelineConfig {
            base: Config { eb: EbMode::Abs(1e-3), ..Config::default() },
            retune_every: 0,
            chunked: Some(16),
            ..PipelineConfig::default()
        };
        let mut blobs = Vec::new();
        let report = run_stream(
            |i| if i < 3 { Some(step_field(i)) } else { None },
            cfg,
            |_, b| {
                blobs.push(b);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(report.steps.len(), 3);
        assert!(report.overall_ratio() > 1.0);
        for (i, b) in blobs.iter().enumerate() {
            assert_eq!(&b[..4], crate::format::MAGIC3, "step {i} not a v3 container");
            // random access works on every step's container
            let mut dec =
                crate::stream::StreamDecompressor::new(std::io::Cursor::new(&b[..])).unwrap();
            assert!(dec.load_index().unwrap().n_chunks() >= 4);
            let rec = crate::compressor::decompress(b, 2).unwrap();
            let orig = step_field(i);
            for (o, r) in orig.data.iter().zip(&rec.data) {
                assert!((o - r).abs() <= 1e-3 + 1e-5);
            }
        }
    }

    #[test]
    fn run_stream_per_chunk_autotune_smoke() {
        // per-chunk tuning replaces the per-step tune (tuned is None) and
        // the output still decodes within the bound
        let cfg = PipelineConfig {
            base: Config { eb: EbMode::Abs(1e-3), ..Config::default() },
            retune_every: 4,
            tune: TuneSettings { sample_pct: 20.0, iterations: 1, seed: 9 },
            chunked: Some(16),
            chunk_autotune: true,
            ..PipelineConfig::default()
        };
        let mut blobs = Vec::new();
        let report = run_stream(
            |i| if i < 2 { Some(step_field(i)) } else { None },
            cfg,
            |_, b| {
                blobs.push(b);
                Ok(())
            },
        )
        .unwrap();
        assert!(report.steps.iter().all(|s| s.tuned.is_none()));
        for (i, b) in blobs.iter().enumerate() {
            let rec = crate::compressor::decompress(b, 1).unwrap();
            let orig = step_field(i);
            for (o, r) in orig.data.iter().zip(&rec.data) {
                assert!((o - r).abs() <= 1e-3 + 1e-5);
            }
        }
    }

    #[test]
    fn verify_guard_passes_honest_steps_and_catches_corruption() {
        // verify: true round-trips every step through the decode engine
        // before the sink sees it — honest steps must pass unchanged
        let cfg = PipelineConfig {
            base: Config { eb: EbMode::Abs(1e-3), ..Config::default() },
            retune_every: 0,
            verify: true,
            ..PipelineConfig::default()
        };
        let mut n = 0usize;
        run_stream(
            |i| if i < 2 { Some(step_field(i)) } else { None },
            cfg,
            |_, _| {
                n += 1;
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(n, 2);
        // and the guard itself rejects a corrupted container
        let field = step_field(0);
        let (bytes, stats) =
            compress(&field, &Config { eb: EbMode::Abs(1e-3), ..Config::default() }).unwrap();
        assert!(verify_step(0, &field, &bytes, stats.eb, 1).is_ok());
        let mut bad = bytes.clone();
        let n = bad.len();
        bad[n - 2] ^= 0x55;
        assert!(verify_step(0, &field, &bad, stats.eb, 1).is_err());
    }

    #[test]
    fn decompressed_steps_respect_bound() {
        let cfg = PipelineConfig {
            base: Config { eb: EbMode::Abs(1e-3), ..Config::default() },
            retune_every: 1,
            tune: TuneSettings { sample_pct: 10.0, iterations: 1, seed: 3 },
            queue_depth: 1,
            ..PipelineConfig::default()
        };
        let mut blobs = Vec::new();
        run_stream(
            |i| if i < 2 { Some(step_field(i)) } else { None },
            cfg,
            |_, b| {
                blobs.push(b);
                Ok(())
            },
        )
        .unwrap();
        for (i, b) in blobs.iter().enumerate() {
            let rec = crate::compressor::decompress(b, 1).unwrap();
            let orig = step_field(i);
            for (o, r) in orig.data.iter().zip(&rec.data) {
                assert!((o - r).abs() <= 1e-3 + 1e-5);
            }
        }
    }
}

//! Two-level (fields × chunks) scheduler over the job-graph executor.
//!
//! The old batch driver pinned **one worker per field**, so a batch of one
//! large and three tiny fields left most of the pool idle once the tiny
//! fields finished. This layer decomposes every field into its container
//! chunks (the same geometry the streaming writer uses — see
//! `stream::plan_chunks`) and submits the chunk jobs **round-robin across
//! fields** (`f0c0, f1c0, …, f0c1, f1c1, …`). Ready jobs dispatch FIFO at
//! equal priority, so chunks of many fields are interleaved across the
//! whole pool from the first tick and a long field can never starve the
//! others — nor the reverse.
//!
//! Completed frames arrive in *completion* order on the executor channel
//! and are forwarded to an [`OrderedWriter`]: an asynchronous sink thread
//! that holds a per-field reorder buffer and assembles each container
//! (header → frames in chunk order → trailer → index footer) exactly as
//! `stream::StreamCompressor` does. Encode workers therefore never stall
//! on container-ordered I/O, and the output is **byte-identical** to the
//! sequential single-field path for any thread count — a hard invariant
//! covered by tests here and in `pipeline`.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use crate::blocks::Dims;
use crate::coordinator::exec::{CancelToken, Executor, JobSpec, JobStatus};
use crate::coordinator::pool::ThreadPool;
use crate::data::Field;
use crate::error::{Result, VszError};
use crate::format::{self, ChunkIndexEntry, ChunkMeta};
use crate::stream::{self, ChunkOut, ChunkPlan, StreamOptions, StreamStats};

/// Observation hook for scheduler job starts: called on the worker thread
/// with `(field_index, chunk_index)` immediately before a chunk encodes.
/// Test instrumentation (the interleaving regression test); `None` in
/// production paths.
pub type TraceHook = Arc<dyn Fn(usize, usize) + Send + Sync>;

/// Per-field compression request for [`compress_fields_chunked`].
#[derive(Clone, Copy, Debug)]
pub struct FieldSpec {
    /// Compression config; the error bound must already be absolute
    /// (resolve `Rel` against the field first, as the batch driver does).
    pub cfg: crate::compressor::Config,
    /// Chunk span (leading-dim extent); 0 picks the default span.
    pub span: usize,
    /// Writer options (container version, per-chunk autotuning).
    pub opts: StreamOptions,
}

/// One assembled container plus its run statistics.
#[derive(Clone, Debug)]
pub struct FieldResult {
    pub bytes: Vec<u8>,
    pub stats: StreamStats,
}

/// Message from the gather loop to the ordered sink: one encoded frame of
/// one field's container.
struct FrameMsg {
    field: usize,
    chunk: u64,
    frame: Vec<u8>,
    out: ChunkOut,
}

/// Reorder state of one field's container inside the [`OrderedWriter`].
struct Lane {
    buf: Vec<u8>,
    index: Vec<ChunkIndexEntry>,
    pending: BTreeMap<u64, (Vec<u8>, u64, ChunkMeta)>,
    next: u64,
    total: u64,
    version: u16,
    stats: StreamStats,
}

impl Lane {
    fn append(&mut self, frame: &[u8], lead_extent: u64, meta: ChunkMeta) {
        if self.version >= format::VERSION3 {
            self.index.push(ChunkIndexEntry {
                offset: self.buf.len() as u64,
                frame_len: frame.len() as u64,
                lead_extent,
                meta,
            });
        }
        self.buf.extend_from_slice(frame);
        self.stats.compressed_bytes += frame.len();
        self.next += 1;
    }

    fn finish(mut self) -> Result<FieldResult> {
        if self.next != self.total {
            return Err(VszError::runtime(format!(
                "ordered writer: {} of {} chunks written",
                self.next, self.total
            )));
        }
        let mut tail = Vec::new();
        format::write_trailer(&mut tail, self.total);
        if self.version >= format::VERSION3 {
            format::write_index_footer(&mut tail, &self.index);
        }
        self.buf.extend_from_slice(&tail);
        self.stats.compressed_bytes += tail.len();
        Ok(FieldResult { bytes: self.buf, stats: self.stats })
    }
}

/// Asynchronous completion-order → container-order sink.
///
/// Owns a dedicated writer thread: frames arrive in whatever order the
/// pool finishes them, are buffered per field until their predecessors
/// have been written, and each container is laid out byte-identically to
/// the serial streaming writer. Producers hand frames off through
/// [`sender`](Self::sender) and never block on ordering.
pub struct OrderedWriter {
    tx: Option<Sender<FrameMsg>>,
    handle: std::thread::JoinHandle<Result<Vec<FieldResult>>>,
}

impl OrderedWriter {
    /// One lane per field, seeded with the field's encoded stream header.
    fn new(lanes: Vec<Lane>) -> Self {
        let (tx, rx) = channel::<FrameMsg>();
        let handle = std::thread::spawn(move || {
            let mut lanes = lanes;
            for msg in rx {
                let lane = &mut lanes[msg.field];
                lane.stats.n_chunks += 1;
                lane.stats.n_outliers += msg.out.n_outliers;
                lane.stats.pq_seconds += msg.out.pq_seconds;
                lane.pending.insert(msg.chunk, (msg.frame, msg.out.lead_extent, msg.out.meta));
                while let Some((frame, lead, meta)) = {
                    let key = lane.next;
                    lane.pending.remove(&key)
                } {
                    lane.append(&frame, lead, meta);
                }
            }
            lanes.into_iter().map(Lane::finish).collect()
        });
        Self { tx: Some(tx), handle }
    }

    fn sender(&self) -> Sender<FrameMsg> {
        self.tx.as_ref().expect("writer already finished").clone()
    }

    /// Close the channel and collect the assembled containers.
    fn finish(mut self) -> Result<Vec<FieldResult>> {
        drop(self.tx.take());
        self.handle.join().map_err(|_| VszError::runtime("ordered writer panicked"))?
    }
}

/// Chunk dims of `dims` restricted to `extent` leading rows.
fn chunk_dims(dims: Dims, extent: usize) -> Dims {
    let mut shape = dims.shape;
    shape[0] = extent;
    Dims { shape, ndim: dims.ndim }
}

/// Compress many fields to chunked (v3 by default) containers with
/// chunk-level parallelism interleaved across fields.
///
/// The workhorse behind `pipeline::compress_batch`, the chunked
/// `run_stream` path and the `vsz serve` service. Output is byte-identical
/// to calling [`stream::compress_chunked_with`] per field, for any pool
/// width.
pub fn compress_fields_chunked(
    pool: &ThreadPool,
    fields: Arc<Vec<Field>>,
    specs: &[FieldSpec],
    trace: Option<TraceHook>,
) -> Result<Vec<FieldResult>> {
    compress_fields_chunked_with(pool, fields, specs, trace, None)
}

/// [`compress_fields_chunked`] with an optional [`CancelToken`] shared by
/// every chunk job of the batch. Cancelling the token makes queued jobs
/// complete as `Cancelled` (the executor skips them before they start) and
/// makes running jobs bail at their next cooperative check; the call then
/// returns a "chunk job cancelled" [`VszError`] instead of a container.
/// `vsz serve` uses this to tie a request deadline / client disconnect to
/// all of the request's outstanding work.
pub fn compress_fields_chunked_with(
    pool: &ThreadPool,
    fields: Arc<Vec<Field>>,
    specs: &[FieldSpec],
    trace: Option<TraceHook>,
    cancel: Option<CancelToken>,
) -> Result<Vec<FieldResult>> {
    assert_eq!(fields.len(), specs.len(), "one spec per field");
    if fields.is_empty() {
        return Ok(Vec::new());
    }
    // resolve geometry once per field (also validates every spec before
    // any work is submitted)
    let plans: Vec<ChunkPlan> = fields
        .iter()
        .zip(specs)
        .map(|(f, s)| stream::plan_chunks(f.dims, &s.cfg, s.span, s.opts))
        .collect::<Result<Vec<_>>>()?;
    let lanes: Vec<Lane> = fields
        .iter()
        .zip(specs)
        .zip(&plans)
        .map(|((f, s), p)| Lane {
            buf: p.header.clone(),
            index: Vec::new(),
            pending: BTreeMap::new(),
            next: 0,
            total: p.n_chunks(f.dims) as u64,
            version: s.opts.version,
            stats: StreamStats {
                raw_bytes: f.dims.len() * 4,
                n_elements: f.dims.len(),
                compressed_bytes: p.header.len(),
                ..StreamStats::default()
            },
        })
        .collect();
    let writer = OrderedWriter::new(lanes);
    let sink = writer.sender();

    type ChunkDone = (usize, u64, Result<(Vec<u8>, ChunkOut)>);
    // bounded submission window: enough to keep every worker fed plus a
    // small lead, small enough that slab copies stay bounded
    let mut exec: Executor<ChunkDone> = Executor::new(pool, (pool.threads() * 2).max(4));
    let n_chunks: Vec<usize> =
        plans.iter().zip(fields.iter()).map(|(p, f)| p.n_chunks(f.dims)).collect();
    let total_jobs: usize = n_chunks.iter().sum();
    let rounds = n_chunks.iter().copied().max().unwrap_or(0);

    let mut first_err: Option<VszError> = None;
    let mut received = 0usize;
    let forward = |status: JobStatus<ChunkDone>,
                   received: &mut usize,
                   first_err: &mut Option<VszError>| {
        *received += 1;
        match status {
            JobStatus::Done((fi, ci, Ok((frame, out)))) => {
                let _ = sink.send(FrameMsg { field: fi, chunk: ci, frame, out });
            }
            JobStatus::Done((_, _, Err(e))) => {
                first_err.get_or_insert(e);
            }
            JobStatus::Cancelled => {
                first_err.get_or_insert(VszError::runtime("chunk job cancelled"));
            }
            JobStatus::Failed(m) => {
                first_err.get_or_insert(VszError::runtime(format!("chunk job failed: {m}")));
            }
        }
    };

    // round-robin across fields: chunk c of every field before chunk c+1
    // of any — workers see an interleaved stream from the first tick
    for round in 0..rounds {
        for (fi, plan) in plans.iter().enumerate() {
            if round >= n_chunks[fi] {
                continue;
            }
            let (cfg, span, opts) = (plan.cfg, plan.span, specs[fi].opts);
            let fields = Arc::clone(&fields);
            let trace = trace.clone();
            let cancel_job = cancel.clone();
            let spec = JobSpec { cancel: cancel.clone(), ..JobSpec::default() };
            exec.submit(spec, move || {
                if let Some(t) = &trace {
                    t(fi, round);
                }
                // cooperative check for jobs already dequeued when the
                // token flipped: skip the encode, report cancellation
                if cancel_job.as_ref().is_some_and(|t| t.is_cancelled()) {
                    return (fi, round as u64, Err(VszError::runtime("chunk job cancelled")));
                }
                let f = &fields[fi];
                let row_elems = f.dims.shape[1] * f.dims.shape[2];
                let start = round * span;
                let extent = (f.dims.shape[0] - start).min(span);
                let data = f.data[start * row_elems..(start + extent) * row_elems].to_vec();
                let field = Field::new(format!("chunk{round}"), chunk_dims(f.dims, extent), data);
                let mut c = cfg;
                c.threads = 1; // parallelism is across chunks here
                (fi, round as u64, stream::encode_chunk(round as u64, field, c, false, opts))
            })?;
            // keep the sink fed while submitting (frames stream to the
            // writer as they finish; ordering is the writer's job)
            while let Some((_, status)) = exec.try_recv() {
                forward(status, &mut received, &mut first_err);
            }
        }
    }
    while received < total_jobs {
        let (_, status) = exec.recv().expect("executor channel closed");
        forward(status, &mut received, &mut first_err);
    }
    drop(sink);
    let results = writer.finish();
    match first_err {
        Some(e) => Err(e),
        None => results,
    }
}

/// Single-field convenience over [`compress_fields_chunked`] — the shared-
/// pool replacement for `stream::compress_chunked_with` used by the
/// chunked `run_stream` path and the server.
pub fn compress_field_chunked(
    pool: &ThreadPool,
    field: Field,
    cfg: &crate::compressor::Config,
    span: usize,
    opts: StreamOptions,
) -> Result<(Vec<u8>, StreamStats)> {
    compress_field_chunked_with(pool, field, cfg, span, opts, None)
}

/// Single-field [`compress_fields_chunked_with`]: one request, one optional
/// cancel token covering all of its chunk jobs.
pub fn compress_field_chunked_with(
    pool: &ThreadPool,
    field: Field,
    cfg: &crate::compressor::Config,
    span: usize,
    opts: StreamOptions,
    cancel: Option<CancelToken>,
) -> Result<(Vec<u8>, StreamStats)> {
    let spec = FieldSpec { cfg: *cfg, span, opts };
    let results = compress_fields_chunked_with(pool, Arc::new(vec![field]), &[spec], None, cancel)?;
    let r = results.into_iter().next().expect("one result per field");
    Ok((r.bytes, r.stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressor::{Config, EbMode};
    use crate::util::prng::Pcg32;
    use std::sync::{Condvar, Mutex};
    use std::time::Duration;

    fn field(name: &str, rows: usize, cols: usize, seed: u64) -> Field {
        let dims = Dims::d2(rows, cols);
        let mut rng = Pcg32::seeded(seed);
        let mut x = 0.0f32;
        let data: Vec<f32> = (0..dims.len())
            .map(|_| {
                x += (rng.next_f32() - 0.5) * 0.1;
                x
            })
            .collect();
        Field::new(name.to_string(), dims, data)
    }

    fn abs_cfg(eb: f64) -> Config {
        Config { eb: EbMode::Abs(eb), ..Config::default() }
    }

    #[test]
    fn scheduler_output_is_byte_identical_to_serial_writer() {
        let fields = vec![field("a", 96, 64, 1), field("b", 32, 64, 2), field("c", 64, 48, 3)];
        let cfg = abs_cfg(1e-3);
        let specs: Vec<FieldSpec> = fields
            .iter()
            .map(|_| FieldSpec { cfg, span: 16, opts: StreamOptions::default() })
            .collect();
        // reference: the serial streaming writer, field by field
        let reference: Vec<Vec<u8>> = fields
            .iter()
            .map(|f| stream::compress_chunked(f, &cfg, 16).unwrap().0)
            .collect();
        for threads in [1usize, 2, 7] {
            let pool = ThreadPool::new(threads);
            let out =
                compress_fields_chunked(&pool, Arc::new(fields.clone()), &specs, None).unwrap();
            for (i, r) in out.iter().enumerate() {
                assert_eq!(
                    r.bytes, reference[i],
                    "field {i} bytes differ at {threads} threads"
                );
                assert!(r.stats.n_chunks >= 2);
                assert_eq!(r.stats.compressed_bytes, r.bytes.len());
            }
        }
    }

    #[test]
    fn mixed_size_batch_interleaves_chunks_from_distinct_fields() {
        // one large and one small field, two workers. Round-robin
        // submission + FIFO dispatch puts f0c0 and f1c0 on the two workers
        // first; the rendezvous below *blocks* both jobs until two are in
        // flight simultaneously, proving chunks of ≥2 distinct fields run
        // concurrently (the starvation regression).
        let fields = vec![field("big", 128, 64, 4), field("small", 32, 64, 5)];
        let cfg = abs_cfg(1e-3);
        let specs: Vec<FieldSpec> = fields
            .iter()
            .map(|_| FieldSpec { cfg, span: 16, opts: StreamOptions::default() })
            .collect();
        let seen = Arc::new((Mutex::new(Vec::<(usize, usize)>::new()), Condvar::new()));
        let hook: TraceHook = {
            let seen = Arc::clone(&seen);
            Arc::new(move |f, c| {
                let (m, cv) = &*seen;
                let mut v = m.lock().unwrap();
                v.push((f, c));
                if v.len() >= 2 {
                    cv.notify_all();
                } else {
                    // first job blocks until a second one starts
                    let deadline = std::time::Instant::now() + Duration::from_secs(20);
                    while v.len() < 2 {
                        let left = deadline.saturating_duration_since(std::time::Instant::now());
                        assert!(!left.is_zero(), "no second job started: workers starved");
                        let (vv, _) = cv.wait_timeout(v, left).unwrap();
                        v = vv;
                    }
                }
            })
        };
        let pool = ThreadPool::new(2);
        let out = compress_fields_chunked(
            &pool,
            Arc::new(fields.clone()),
            &specs,
            Some(hook),
        )
        .unwrap();
        let order = seen.0.lock().unwrap().clone();
        assert_ne!(order[0].0, order[1].0, "first two jobs must come from distinct fields");
        // interleaving must not cost correctness: still byte-identical
        for (i, r) in out.iter().enumerate() {
            let (reference, _) = stream::compress_chunked(&fields[i], &cfg, 16).unwrap();
            assert_eq!(r.bytes, reference, "field {i}");
        }
    }

    #[test]
    fn default_span_and_stats_match_serial_writer() {
        let f = field("d", 64, 64, 6);
        let cfg = abs_cfg(5e-4);
        let pool = ThreadPool::new(3);
        let (bytes, stats) =
            compress_field_chunked(&pool, f.clone(), &cfg, 0, StreamOptions::default()).unwrap();
        let (reference, ref_stats) = stream::compress_chunked(&f, &cfg, 0).unwrap();
        assert_eq!(bytes, reference);
        assert_eq!(stats.n_chunks, ref_stats.n_chunks);
        assert_eq!(stats.n_elements, ref_stats.n_elements);
        assert_eq!(stats.n_outliers, ref_stats.n_outliers);
        assert_eq!(stats.compressed_bytes, ref_stats.compressed_bytes);
        assert_eq!(stats.raw_bytes, ref_stats.raw_bytes);
    }

    #[test]
    fn cancelled_token_aborts_batch_with_cancelled_error() {
        // the first chunk job to start flips the shared token; every job
        // (including that one, via the cooperative check) must then report
        // cancellation and the batch must surface it as a single error
        let f = field("x", 96, 64, 8);
        let cfg = abs_cfg(1e-3);
        let pool = ThreadPool::new(2);
        let token = CancelToken::new();
        let hook: TraceHook = {
            let t = token.clone();
            Arc::new(move |_, _| t.cancel())
        };
        let spec = FieldSpec { cfg, span: 16, opts: StreamOptions::default() };
        let err = compress_fields_chunked_with(
            &pool,
            Arc::new(vec![f]),
            &[spec],
            Some(hook),
            Some(token),
        )
        .unwrap_err();
        assert!(err.to_string().contains("cancelled"), "unexpected error: {err}");
    }

    #[test]
    fn invalid_spec_is_rejected_before_any_work() {
        let f = field("e", 32, 32, 7);
        let pool = ThreadPool::new(2);
        let cfg = Config { eb: EbMode::Rel(1e-3), ..Config::default() };
        let err = compress_field_chunked(&pool, f, &cfg, 16, StreamOptions::default());
        assert!(err.is_err(), "relative eb must be rejected by the planner");
    }
}

//! Thread pool + scoped parallel-for (substrate — the paper's OpenMP
//! analog, §III-F).
//!
//! Two tools:
//! * [`parallel_chunks_mut`] — scoped fork/join over disjoint mutable
//!   chunks (the `#pragma omp parallel for` of the block loops). Thread
//!   affinity: like the paper's `OMP_PLACES=cores / OMP_PROC_BIND=close`,
//!   work is dealt in contiguous ranges so neighbouring blocks stay on the
//!   same worker.
//! * [`ThreadPool`] — a persistent pool with a shared injector queue for
//!   the streaming coordinator (decode side, pipeline stages).

use std::sync::{Arc, Condvar, Mutex};

/// Scoped parallel iteration over `data` in `nthreads` contiguous spans.
/// `f(span_index, start_item, items)` runs on its own thread (or inline for
/// nthreads <= 1). Items are `chunk`-sized groups: `data.len()` must be a
/// multiple of `chunk` except possibly the tail.
pub fn parallel_chunks_mut<T: Send, R: Send>(
    data: &mut [T],
    chunk: usize,
    nthreads: usize,
    f: impl Fn(usize, usize, &mut [T]) -> R + Sync,
) -> Vec<R> {
    assert!(chunk > 0);
    let n_items = data.len().div_ceil(chunk);
    let nthreads = nthreads.max(1).min(n_items.max(1));
    if nthreads <= 1 || n_items <= 1 {
        return vec![f(0, 0, data)];
    }
    // contiguous item ranges per thread ("close" affinity analog)
    let per = n_items.div_ceil(nthreads);
    let mut results: Vec<Option<R>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        let mut rest = data;
        let mut item0 = 0usize;
        let mut t = 0usize;
        while !rest.is_empty() {
            let take = (per * chunk).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let fref = &f;
            let my_t = t;
            let my_item0 = item0;
            handles.push(s.spawn(move || fref(my_t, my_item0, head)));
            item0 += take / chunk;
            t += 1;
        }
        for h in handles {
            results.push(Some(h.join().expect("worker panicked")));
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

pub(crate) type Job = Box<dyn FnOnce() + Send + 'static>;

pub(crate) struct PoolShared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    available: Condvar,
    shutdown: Mutex<bool>,
}

impl PoolShared {
    /// Enqueue a job on the injector — the hook the `exec` layer uses to
    /// push dispatch ticks without borrowing the [`ThreadPool`] itself.
    pub(crate) fn push(&self, job: Job) {
        self.queue.lock().unwrap().push_back(job);
        self.available.notify_one();
    }
}

/// Persistent worker pool with FIFO dispatch. Used by the streaming
/// coordinator; block-parallel hot loops prefer [`parallel_chunks_mut`]
/// (no queue overhead, contiguous ranges).
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(nthreads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let workers = (0..nthreads.max(1))
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || loop {
                    let job = {
                        let mut q = sh.queue.lock().unwrap();
                        loop {
                            if let Some(j) = q.pop_front() {
                                break j;
                            }
                            if *sh.shutdown.lock().unwrap() {
                                return;
                            }
                            q = sh.available.wait(q).unwrap();
                        }
                    };
                    // keep the worker alive across a panicking job: the
                    // job's result never arrives, which scatter_gather
                    // surfaces as a "missing result" panic on the caller —
                    // instead of a dead worker silently stranding the
                    // still-queued jobs (a permanent hang).
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                })
            })
            .collect();
        Self { shared, workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// The shared injector state (for the `exec` layer, which outlives any
    /// one borrow of the pool).
    pub(crate) fn shared(&self) -> &Arc<PoolShared> {
        &self.shared
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.push(Box::new(job));
    }

    /// Submit `n` indexed jobs and wait for all of them.
    pub fn scatter_gather<R: Send + 'static>(
        &self,
        n: usize,
        f: impl Fn(usize) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        self.scoped_scatter_gather(n, f)
    }

    /// [`scatter_gather`](Self::scatter_gather) for closures that borrow
    /// from the caller's stack (the chunked Huffman encode/decode paths hand
    /// out sub-slices of one borrowed symbol/payload buffer). Blocks until
    /// every job closure has been destroyed — run to completion or dropped —
    /// so no borrow escapes the call.
    ///
    /// Implemented as a thin wrapper over [`exec::Executor`]: `n` jobs with
    /// equal priority and no dependencies, results reordered from the
    /// completion-ordered channel back to submission order.
    pub fn scoped_scatter_gather<'env, R: Send + 'env>(
        &self,
        n: usize,
        f: impl Fn(usize) -> R + Send + Sync + 'env,
    ) -> Vec<R> {
        use crate::coordinator::exec::{Executor, JobSpec, JobStatus};
        let f = Arc::new(f);
        // SAFETY: the drain loop below receives exactly `n` statuses
        // before returning. The executor sends a job's status strictly
        // after the job closure (the Arc clone of `f` and its captures)
        // has been consumed or dropped, so `n` received statuses prove
        // every clone of `f` is dead and this frame's Arc is the sole
        // owner: no 'env borrow survives the call. A panicking job still
        // sends a status (Failed); the drain records it and only re-panics
        // after all n statuses have arrived, so the unwind cannot start
        // while a still-live closure borrows this frame.
        let mut exec = unsafe { Executor::<R>::new_unchecked(self, n.max(1)) };
        for i in 0..n {
            let g = Arc::clone(&f);
            unsafe { exec.submit_unchecked(JobSpec::default(), move || g(i)) }
                .expect("dependency-free submission cannot fail");
        }
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        // Drain ALL n statuses before reacting to a failure: a status is
        // only sent once that job's closure is dead, so the caller frame
        // (and the 'env borrows anchored to it) must not unwind while any
        // status — hence any live closure — is still outstanding.
        let mut failure: Option<String> = None;
        for _ in 0..n {
            let (id, status) = exec.recv().expect("missing result");
            match status {
                JobStatus::Done(r) => out[id as usize] = Some(r),
                JobStatus::Cancelled => {
                    failure.get_or_insert_with(|| "job cancelled".to_string());
                }
                JobStatus::Failed(m) => {
                    failure.get_or_insert(m);
                }
            }
        }
        if let Some(m) = failure {
            panic!("worker job failed: {m}");
        }
        out.into_iter().map(|r| r.expect("missing result")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_chunks_cover_everything_once() {
        let mut data = vec![0u32; 1000];
        parallel_chunks_mut(&mut data, 7, 4, |_, item0, span| {
            for (k, v) in span.iter_mut().enumerate() {
                *v += (item0 * 7 + k) as u32 + 1;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1, "at {i}");
        }
    }

    #[test]
    fn parallel_chunks_single_thread_inline() {
        let mut data = vec![1u8; 10];
        let r = parallel_chunks_mut(&mut data, 3, 1, |t, _, span| (t, span.len()));
        assert_eq!(r, vec![(0, 10)]);
    }

    #[test]
    fn parallel_chunks_more_threads_than_items() {
        let mut data = vec![0u8; 6];
        let r = parallel_chunks_mut(&mut data, 3, 64, |t, _, span| (t, span.len()));
        // 2 items, so at most 2 spans
        assert!(r.len() <= 2);
        assert_eq!(r.iter().map(|x| x.1).sum::<usize>(), 6);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        let results = pool.scatter_gather(100, move |i| {
            c.fetch_add(1, Ordering::SeqCst);
            i * 2
        });
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(results[17], 34);
        assert_eq!(results.len(), 100);
    }

    #[test]
    fn pool_shutdown_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn scoped_failure_drains_all_jobs_before_panicking() {
        let pool = ThreadPool::new(2);
        let finished = AtomicUsize::new(0);
        let data: Vec<u64> = (0..64).collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scoped_scatter_gather(8, |i| {
                if i == 0 {
                    panic!("early failure");
                }
                // slow borrowers: still reading the caller's stack long
                // after job 0 has already failed
                std::thread::sleep(std::time::Duration::from_millis(10));
                let s = data[i * 8..(i + 1) * 8].iter().sum::<u64>();
                finished.fetch_add(1, Ordering::SeqCst);
                s
            })
        }));
        let payload = result.expect_err("a failed job must panic the caller");
        let msg = payload.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("early failure"), "{msg}");
        // the caller only unwound after draining every status, i.e. after
        // all 7 borrowing jobs ran to completion — none was left alive
        // referencing the (now dead) stack frame
        assert_eq!(finished.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn scoped_scatter_gather_borrows_stack_data() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..100).collect();
        let chunk_sum = |i: usize| data[i * 10..(i + 1) * 10].iter().sum::<u64>();
        let sums = pool.scoped_scatter_gather(10, chunk_sum);
        assert_eq!(sums.len(), 10);
        assert_eq!(sums.iter().sum::<u64>(), (0..100).sum::<u64>());
        assert_eq!(sums[0], (0..10).sum::<u64>());
        // empty fan-out is a no-op
        let none: Vec<u64> = pool.scoped_scatter_gather(0, |_| 0u64);
        assert!(none.is_empty());
    }
}

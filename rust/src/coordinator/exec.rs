//! Job-graph executor — the dispatch layer between the raw [`ThreadPool`]
//! and the higher-level schedulers.
//!
//! The pool (`pool.rs`) is a plain FIFO injector: it knows nothing about
//! job identity, ordering constraints or flow control. Everything above it
//! — the chunk scheduler (`sched`), the batch driver, the `vsz serve`
//! service — needs the same four facilities, so they live here once:
//!
//! * **Dependencies** — a job may name previously submitted jobs that must
//!   reach a terminal state first. Submission order gives a natural DAG
//!   (forward references are rejected), so cycles are impossible.
//! * **Priorities** — among ready jobs, higher [`JobSpec::priority`] runs
//!   first; ties run in submission order (FIFO), which keeps the plain
//!   `scatter_gather` path byte-for-byte deterministic.
//! * **Cancellation** — a [`CancelToken`] flips jobs to
//!   [`JobStatus::Cancelled`] before they start; running jobs may poll the
//!   token cooperatively. A cancelled dependency cancels its dependents; a
//!   failed (panicked) dependency fails them.
//! * **Bounded submission** — at most `capacity` jobs may be outstanding
//!   (submitted but not yet terminal); [`Executor::submit`] blocks until a
//!   slot frees, so producers cannot grow the queue unboundedly.
//!
//! Results come back on a **completion-ordered channel** ([`Executor::recv`]):
//! whichever job finishes first is received first, tagged with its
//! [`JobId`]. Callers that need submission order (scatter/gather) reorder by
//! id; callers that stream (the ordered container sink) forward completions
//! as they arrive.
//!
//! Exactly one status is delivered per submitted job — run, cancelled,
//! poisoned or panicked — and the status is sent strictly *after* the job
//! closure has been consumed or dropped. That ordering is the soundness
//! anchor for the scoped (borrowing) entry points in `pool.rs`: receiving
//! `n` statuses proves all `n` job closures are dead, so no borrow of the
//! caller's frame can escape.

use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::pool::ThreadPool;
use crate::error::{Result, VszError};

/// Monotonic per-executor job handle (assigned from 0 in submission order).
pub type JobId = u64;

/// Cooperative cancellation flag, cloneable across threads.
///
/// Cancelling before a job starts turns it into [`JobStatus::Cancelled`]
/// without running it; a job that is already running can poll
/// [`is_cancelled`](Self::is_cancelled) and bail early (its return value is
/// still delivered as [`JobStatus::Done`] then — cancellation observed
/// mid-run is the job's own business).
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Per-job submission parameters.
#[derive(Clone, Debug, Default)]
pub struct JobSpec {
    /// Higher runs first among ready jobs; ties in submission order.
    pub priority: i32,
    /// Ids of previously submitted jobs that must finish first.
    pub deps: Vec<JobId>,
    /// Checked immediately before the job runs.
    pub cancel: Option<CancelToken>,
}

impl JobSpec {
    pub fn with_priority(priority: i32) -> Self {
        Self { priority, ..Self::default() }
    }

    pub fn after(deps: Vec<JobId>) -> Self {
        Self { deps, ..Self::default() }
    }
}

/// Terminal state of one job.
#[derive(Debug)]
pub enum JobStatus<R> {
    /// Ran to completion.
    Done(R),
    /// Skipped: its token was cancelled before it started, or a dependency
    /// was cancelled.
    Cancelled,
    /// The job panicked (message captured), or a dependency failed.
    Failed(String),
}

impl<R> JobStatus<R> {
    /// Unwrap `Done`, panicking with the failure message otherwise — the
    /// scatter/gather convention (a panicking job panics the caller).
    pub fn expect_done(self) -> R {
        match self {
            JobStatus::Done(r) => r,
            JobStatus::Cancelled => panic!("job cancelled"),
            JobStatus::Failed(m) => panic!("worker job failed: {m}"),
        }
    }
}

/// How a popped job is to be disposed of.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Disposition {
    Run,
    Cancelled,
    DepFailed,
}

/// Outcome kind reported back to the graph (the `R`-typed payload travels
/// on the executor's channel instead).
#[derive(Clone, Copy, Debug, PartialEq)]
enum Outcome {
    Ok,
    Cancelled,
    Failed,
}

/// Type-erased job body: told its disposition, it sends exactly one
/// `(JobId, JobStatus<R>)` on the executor's channel — after dropping or
/// consuming the user closure — and returns the outcome kind for
/// dependency propagation.
type ErasedJob = Box<dyn FnOnce(Disposition) -> Outcome + Send + 'static>;

struct PendingJob {
    body: ErasedJob,
    deps_left: usize,
    priority: i32,
    seq: u64,
    cancel: Option<CancelToken>,
    /// Set when a dependency terminated abnormally; overrides `Run`.
    poison: Option<Disposition>,
}

/// Ready-heap key: higher priority first, then FIFO by submission sequence.
#[derive(PartialEq, Eq)]
struct ReadyKey {
    priority: i32,
    seq: u64,
    id: JobId,
}

impl Ord for ReadyKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.seq.cmp(&self.seq)) // max-heap: smaller seq wins
    }
}

impl PartialOrd for ReadyKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Default)]
struct ExecState {
    jobs: HashMap<JobId, PendingJob>,
    ready: BinaryHeap<ReadyKey>,
    dependents: HashMap<JobId, Vec<JobId>>,
    /// Terminal outcome of every finished job (late-submitted dependents
    /// resolve against this).
    done: HashMap<JobId, Outcome>,
    /// Submitted jobs whose status has not been sent yet.
    outstanding: usize,
    next_seq: u64,
}

struct ExecShared {
    state: Mutex<ExecState>,
    /// Signalled when `outstanding` drops below capacity.
    room: Condvar,
    pool: Arc<crate::coordinator::pool::PoolShared>,
}

impl ExecShared {
    /// Run (or dispose of) the highest-priority ready job. Called from a
    /// pool worker; exactly one tick is enqueued per job that becomes
    /// ready, so the pop below always finds an entry.
    fn run_one(self: &Arc<Self>) {
        let (id, body, disp) = {
            let mut st = self.state.lock().unwrap();
            let key = st.ready.pop().expect("tick without ready job");
            let job = st.jobs.remove(&key.id).expect("ready job missing");
            let disp = job.poison.unwrap_or_else(|| match &job.cancel {
                Some(t) if t.is_cancelled() => Disposition::Cancelled,
                _ => Disposition::Run,
            });
            (key.id, job.body, disp)
        };
        // The body consumes/drops the user closure, then sends the status.
        let outcome = (body)(disp);
        let newly_ready = {
            let mut st = self.state.lock().unwrap();
            st.done.insert(id, outcome);
            st.outstanding -= 1;
            let mut ready = Vec::new();
            if let Some(deps) = st.dependents.remove(&id) {
                for d in deps {
                    let job = st.jobs.get_mut(&d).expect("dependent vanished");
                    job.deps_left -= 1;
                    match outcome {
                        Outcome::Ok => {}
                        Outcome::Cancelled => {
                            job.poison.get_or_insert(Disposition::Cancelled);
                        }
                        Outcome::Failed => job.poison = Some(Disposition::DepFailed),
                    }
                    if job.deps_left == 0 {
                        st.ready.push(ReadyKey { priority: job.priority, seq: job.seq, id: d });
                        ready.push(());
                    }
                }
            }
            self.room.notify_all();
            ready
        };
        for _ in newly_ready {
            self.enqueue_tick();
        }
    }

    fn enqueue_tick(self: &Arc<Self>) {
        let sh = Arc::clone(self);
        self.pool.push(Box::new(move || sh.run_one()));
    }
}

/// Job-graph executor over a borrowed [`ThreadPool`].
///
/// Lightweight: holds scheduling state and a result channel; the worker
/// threads belong to the pool, so many executors (one per batch call, one
/// per server request) can share one pool concurrently.
pub struct Executor<R: Send> {
    shared: Arc<ExecShared>,
    /// Master sender — keeps `rx` connected while jobs are in flight.
    tx: Sender<(JobId, JobStatus<R>)>,
    rx: Receiver<(JobId, JobStatus<R>)>,
    capacity: usize,
    next_id: JobId,
}

impl<R: Send + 'static> Executor<R> {
    /// Executor with at most `capacity` outstanding jobs (≥ 1); `submit`
    /// blocks when full.
    pub fn new(pool: &ThreadPool, capacity: usize) -> Self {
        // SAFETY: R: 'static and `submit` requires 'static closures, so no
        // borrow can outlive the pool queue.
        unsafe { Self::new_unchecked(pool, capacity) }
    }

    /// Submit a job; blocks while the executor is at capacity. Returns the
    /// job's id (also carried by its status on the result channel).
    pub fn submit(
        &mut self,
        spec: JobSpec,
        f: impl FnOnce() -> R + Send + 'static,
    ) -> Result<JobId> {
        // SAFETY: f is 'static — nothing to outlive.
        unsafe { self.submit_unchecked(spec, f) }
    }
}

impl<R: Send> Executor<R> {
    /// [`Executor::new`] without the `'static` bound on `R`.
    ///
    /// # Safety
    /// Every closure later passed to [`submit_unchecked`](Self::submit_unchecked)
    /// may borrow non-`'static` data; the caller must receive a status for
    /// every submitted job (via [`recv`](Self::recv)) before any borrowed
    /// data goes out of scope. A status is sent only after the job closure
    /// has been consumed or dropped, so `n` received statuses prove all `n`
    /// closures are dead.
    pub(crate) unsafe fn new_unchecked(pool: &ThreadPool, capacity: usize) -> Self {
        let (tx, rx) = channel();
        Self {
            shared: Arc::new(ExecShared {
                state: Mutex::new(ExecState::default()),
                room: Condvar::new(),
                pool: Arc::clone(pool.shared()),
            }),
            tx,
            rx,
            capacity: capacity.max(1),
            next_id: 0,
        }
    }

    /// [`Executor::submit`] without the `'static` bound on the closure.
    ///
    /// # Safety
    /// See [`new_unchecked`](Self::new_unchecked): the caller must drain
    /// this job's status before any data `f` borrows goes out of scope.
    pub(crate) unsafe fn submit_unchecked(
        &mut self,
        spec: JobSpec,
        f: impl FnOnce() -> R + Send,
    ) -> Result<JobId> {
        let id = self.next_id;
        for &d in &spec.deps {
            if d >= id {
                return Err(VszError::config(format!(
                    "job {id}: dependency {d} not yet submitted (forward references \
                     would allow cycles)"
                )));
            }
        }
        self.next_id += 1;
        let tx = self.tx.clone();
        let cancel = spec.cancel.clone();
        let body: Box<dyn FnOnce(Disposition) -> Outcome + Send + '_> =
            Box::new(move |disp: Disposition| {
                let (status, outcome) = match disp {
                    Disposition::Run => {
                        // catch_unwind consumes `f`: by the time the status
                        // is built the user closure (and everything it
                        // borrows) is gone, normally or by unwind.
                        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
                            Ok(r) => (JobStatus::Done(r), Outcome::Ok),
                            Err(p) => (JobStatus::Failed(panic_msg(&p)), Outcome::Failed),
                        }
                    }
                    Disposition::Cancelled => {
                        drop(f); // closure dies before the status is sent
                        (JobStatus::Cancelled, Outcome::Cancelled)
                    }
                    Disposition::DepFailed => {
                        drop(f);
                        (JobStatus::Failed("dependency failed".into()), Outcome::Failed)
                    }
                };
                let _ = tx.send((id, status));
                outcome
            });
        // SAFETY: per the caller contract the job's status is drained
        // before any 'env borrow in `f` dies, and the status is sent
        // strictly after `f` is consumed/dropped — so the erased body never
        // touches dead borrows even though the pool queue is 'static.
        let body: ErasedJob = std::mem::transmute(body);

        let mut st = self.shared.state.lock().unwrap();
        while st.outstanding >= self.capacity {
            st = self.shared.room.wait(st).unwrap();
        }
        st.outstanding += 1;
        let seq = st.next_seq;
        st.next_seq += 1;
        let mut deps_left = 0usize;
        let mut poison = None;
        for &d in &spec.deps {
            if let Some(out) = st.done.get(&d) {
                match out {
                    Outcome::Ok => {}
                    Outcome::Cancelled => {
                        poison.get_or_insert(Disposition::Cancelled);
                    }
                    Outcome::Failed => poison = Some(Disposition::DepFailed),
                }
            } else {
                st.dependents.entry(d).or_default().push(id);
                deps_left += 1;
            }
        }
        st.jobs.insert(
            id,
            PendingJob { body, deps_left, priority: spec.priority, seq, cancel, poison },
        );
        let ready_now = deps_left == 0;
        if ready_now {
            st.ready.push(ReadyKey { priority: spec.priority, seq, id });
        }
        drop(st);
        if ready_now {
            self.shared.enqueue_tick();
        }
        Ok(id)
    }

    /// Next status in completion order; blocks. `None` only if the channel
    /// somehow closed (cannot happen while the executor holds its master
    /// sender).
    pub fn recv(&self) -> Option<(JobId, JobStatus<R>)> {
        self.rx.recv().ok()
    }

    /// Non-blocking [`recv`](Self::recv).
    pub fn try_recv(&self) -> Option<(JobId, JobStatus<R>)> {
        match self.rx.try_recv() {
            Ok(m) => Some(m),
            Err(TryRecvError::Empty | TryRecvError::Disconnected) => None,
        }
    }

    /// Jobs submitted so far (also the next id to be assigned).
    pub fn submitted(&self) -> u64 {
        self.next_id
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    fn drain<R: Send>(exec: &Executor<R>, n: usize) -> Vec<(JobId, JobStatus<R>)> {
        (0..n).map(|_| exec.recv().expect("status")).collect()
    }

    #[test]
    fn dependency_ordering_is_respected() {
        let pool = ThreadPool::new(4);
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut exec: Executor<()> = Executor::new(&pool, 64);
        // diamond: a -> {b, c} -> d
        let mk = |tag: &'static str| {
            let order = Arc::clone(&order);
            move || {
                order.lock().unwrap().push(tag);
            }
        };
        let a = exec.submit(JobSpec::default(), mk("a")).unwrap();
        let b = exec.submit(JobSpec::after(vec![a]), mk("b")).unwrap();
        let c = exec.submit(JobSpec::after(vec![a]), mk("c")).unwrap();
        let _d = exec.submit(JobSpec::after(vec![b, c]), mk("d")).unwrap();
        for (_, st) in drain(&exec, 4) {
            st.expect_done();
        }
        let seen = order.lock().unwrap().clone();
        assert_eq!(seen[0], "a");
        assert_eq!(seen[3], "d");
        assert_eq!(seen.len(), 4);
    }

    #[test]
    fn dep_on_already_finished_job_runs() {
        let pool = ThreadPool::new(2);
        let mut exec: Executor<u32> = Executor::new(&pool, 8);
        let a = exec.submit(JobSpec::default(), || 1).unwrap();
        let (_, st) = exec.recv().unwrap();
        assert!(matches!(st, JobStatus::Done(1)));
        // a is terminal before b is submitted
        let _b = exec.submit(JobSpec::after(vec![a]), || 2).unwrap();
        let (_, st) = exec.recv().unwrap();
        assert!(matches!(st, JobStatus::Done(2)));
    }

    #[test]
    fn forward_dependency_is_rejected() {
        let pool = ThreadPool::new(1);
        let mut exec: Executor<()> = Executor::new(&pool, 4);
        assert!(exec.submit(JobSpec::after(vec![0]), || ()).is_err());
    }

    #[test]
    fn cancellation_mid_graph_skips_job_and_dependents() {
        let pool = ThreadPool::new(2);
        let mut exec: Executor<u32> = Executor::new(&pool, 16);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = Arc::clone(&gate);
        let a = exec
            .submit(JobSpec::default(), move || {
                let (m, cv) = &*g;
                let mut open = m.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                1
            })
            .unwrap();
        let token = CancelToken::new();
        let spec = JobSpec { deps: vec![a], cancel: Some(token.clone()), ..JobSpec::default() };
        let b = exec.submit(spec, || 2).unwrap();
        let c = exec.submit(JobSpec::after(vec![b]), || 3).unwrap();
        // cancel b while a is still running, then release a
        token.cancel();
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        let mut statuses: HashMap<JobId, JobStatus<u32>> =
            drain(&exec, 3).into_iter().collect();
        assert!(matches!(statuses.remove(&a), Some(JobStatus::Done(1))));
        assert!(matches!(statuses.remove(&b), Some(JobStatus::Cancelled)));
        assert!(matches!(statuses.remove(&c), Some(JobStatus::Cancelled)));
    }

    #[test]
    fn panic_is_contained_and_fails_dependents() {
        let pool = ThreadPool::new(2);
        let mut exec: Executor<u32> = Executor::new(&pool, 16);
        let a = exec.submit(JobSpec::default(), || panic!("boom-{}", 7)).unwrap();
        let b = exec.submit(JobSpec::after(vec![a]), || 2).unwrap();
        let c = exec.submit(JobSpec::default(), || 3).unwrap();
        let statuses: HashMap<JobId, JobStatus<u32>> = drain(&exec, 3).into_iter().collect();
        match statuses.get(&a) {
            Some(JobStatus::Failed(m)) => assert!(m.contains("boom-7"), "{m}"),
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(statuses.get(&b), Some(JobStatus::Failed(_))));
        // unrelated work is unaffected
        assert!(matches!(statuses.get(&c), Some(JobStatus::Done(3))));
    }

    #[test]
    fn bounded_queue_blocks_submit() {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let submitted = Arc::new(AtomicUsize::new(0));
        let g = Arc::clone(&gate);
        let s = Arc::clone(&submitted);
        let h = std::thread::spawn(move || {
            let pool = ThreadPool::new(1);
            let mut exec: Executor<()> = Executor::new(&pool, 1);
            let gg = Arc::clone(&g);
            exec.submit(JobSpec::default(), move || {
                let (m, cv) = &*gg;
                let mut open = m.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            })
            .unwrap();
            s.store(1, Ordering::SeqCst);
            // capacity 1 and one job outstanding: this must block until
            // the gate opens
            exec.submit(JobSpec::default(), || ()).unwrap();
            s.store(2, Ordering::SeqCst);
            drain(&exec, 2);
        });
        while submitted.load(Ordering::SeqCst) < 1 {
            std::thread::sleep(Duration::from_millis(1));
        }
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(submitted.load(Ordering::SeqCst), 1, "second submit should be blocked");
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        h.join().unwrap();
        assert_eq!(submitted.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn priorities_order_ready_work() {
        let pool = ThreadPool::new(1);
        let mut exec: Executor<&'static str> = Executor::new(&pool, 16);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = Arc::clone(&gate);
        // occupy the single worker so later submissions pile up as ready
        exec.submit(JobSpec::default(), move || {
            let (m, cv) = &*g;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            "gate"
        })
        .unwrap();
        exec.submit(JobSpec::with_priority(0), || "low").unwrap();
        exec.submit(JobSpec::with_priority(5), || "high").unwrap();
        {
            let (m, cv) = &*gate;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        let order: Vec<&str> =
            drain(&exec, 3).into_iter().map(|(_, st)| st.expect_done()).collect();
        assert_eq!(order, vec!["gate", "high", "low"]);
    }

    #[test]
    fn determinism_across_thread_counts() {
        let run = |threads: usize| -> Vec<u64> {
            let pool = ThreadPool::new(threads);
            let mut exec: Executor<u64> = Executor::new(&pool, 32);
            for i in 0..40u64 {
                exec.submit(JobSpec::default(), move || i * i + 1).unwrap();
            }
            let mut out = vec![0u64; 40];
            for (id, st) in drain(&exec, 40) {
                out[id as usize] = st.expect_done();
            }
            out
        };
        let r1 = run(1);
        assert_eq!(r1, run(2));
        assert_eq!(r1, run(7));
    }
}

//! Deterministic fault injection for crash/corruption testing.
//!
//! A *failpoint* is a named site in the code (e.g. `chunk_encode`,
//! `chunk_decode`, `huffman_decode` — hit once per HUF3 gap-array
//! segment — `frame_write`, `frame_read`, `parity_write`,
//! `serve_frame_write`, `serve_frame_read`) that consults this module
//! on every pass. With no configuration the check is a single relaxed atomic
//! load of a `false` flag — zero allocation, no locks, no syscalls —
//! so shipping the hooks in release builds costs nothing.
//!
//! Configuration comes from the `VECSZ_FAILPOINTS` environment
//! variable, parsed once per process. The grammar is a semicolon-
//! separated list of rules:
//!
//! ```text
//! VECSZ_FAILPOINTS = rule (';' rule)*
//! rule             = site ':' hit '=' action
//! action           = 'panic' | 'err' | 'torn' | 'delay(' millis ')'
//! ```
//!
//! `site` names the failpoint, `hit` is the 1-based pass count at
//! which the rule fires (hit counters are per-site and process-wide),
//! and `action` is what happens:
//!
//! * `panic` — the site panics (simulates a crashed worker / killed
//!   process when the caller aborts on panic).
//! * `err`   — the site reports an injected [`VszError::Runtime`].
//! * `torn`  — for write sites: only a prefix of the buffer is
//!   written before the injected error (simulates a torn write /
//!   power cut mid-`write`). Non-write sites treat it like `err`.
//! * `delay(ms)` — the site sleeps `ms` milliseconds, then proceeds
//!   normally. Used to simulate a stuck chunk job so deadline /
//!   cancellation paths can be exercised deterministically.
//!
//! Example: `VECSZ_FAILPOINTS='chunk_encode:3=panic;frame_write:2=torn'`
//! panics the third chunk encode and tears the second frame write.
//!
//! Tests that cannot set the environment before process start can use
//! [`set_config_for_tests`] to (re)install a configuration
//! programmatically; it is test-oriented but safe — it swaps the
//! active rule table under a lock.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// What a matched rule does at the site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Panic at the site (simulated crash).
    Panic,
    /// Return an injected error from the site.
    Err,
    /// Write only a prefix, then error (torn write). `usize` is the
    /// number of bytes to let through; `usize::MAX` means "half".
    Torn,
    /// Sleep this many milliseconds, then continue normally.
    Delay(u64),
}

struct Rule {
    /// 1-based hit number at which the rule fires.
    hit: u64,
    action: Action,
}

struct Registry {
    /// site name -> rules for that site (usually one).
    rules: HashMap<String, Vec<Rule>>,
    /// site name -> passes so far.
    counters: HashMap<String, AtomicU64>,
}

/// Fast-path gate: false until a non-empty config is installed.
static ARMED: AtomicBool = AtomicBool::new(false);
static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();

fn registry() -> &'static Mutex<Registry> {
    REGISTRY.get_or_init(|| {
        let cfg = std::env::var("VECSZ_FAILPOINTS").unwrap_or_default();
        let reg = parse_config(&cfg);
        if !reg.rules.is_empty() {
            ARMED.store(true, Ordering::Release);
        }
        Mutex::new(reg)
    })
}

fn parse_config(cfg: &str) -> Registry {
    let mut rules: HashMap<String, Vec<Rule>> = HashMap::new();
    for part in cfg.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let Some((site_hit, action)) = part.split_once('=') else {
            continue;
        };
        let Some((site, hit)) = site_hit.split_once(':') else {
            continue;
        };
        let Ok(hit) = hit.trim().parse::<u64>() else {
            continue;
        };
        let action = match action.trim() {
            "panic" => Action::Panic,
            "err" => Action::Err,
            "torn" => Action::Torn,
            a if a.starts_with("delay(") && a.ends_with(')') => {
                match a["delay(".len()..a.len() - 1].trim().parse::<u64>() {
                    Ok(ms) => Action::Delay(ms),
                    Err(_) => continue,
                }
            }
            _ => continue,
        };
        rules.entry(site.trim().to_string()).or_default().push(Rule { hit: hit.max(1), action });
    }
    Registry { rules, counters: HashMap::new() }
}

/// Install a configuration programmatically (tests that cannot set
/// `VECSZ_FAILPOINTS` before the process starts). Replaces any prior
/// rules and resets all hit counters. Pass `""` to disarm.
pub fn set_config_for_tests(cfg: &str) {
    let reg = registry();
    let mut g = match reg.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    *g = parse_config(cfg);
    ARMED.store(!g.rules.is_empty(), Ordering::Release);
}

/// True when any rule is installed. A `false` here is the entire cost
/// of an unconfigured failpoint.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Acquire)
}

/// Record one pass through `site` and return the action to take, if
/// any rule matches this pass. The common path (nothing configured)
/// is a single atomic load.
#[inline]
pub fn check(site: &str) -> Option<Action> {
    if !armed() {
        return None;
    }
    check_slow(site)
}

#[cold]
fn check_slow(site: &str) -> Option<Action> {
    let mut g = match registry().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    if !g.rules.contains_key(site) {
        return None;
    }
    if !g.counters.contains_key(site) {
        g.counters.insert(site.to_string(), AtomicU64::new(0));
    }
    let n = {
        let c = g.counters.get(site).expect("counter just inserted");
        c.fetch_add(1, Ordering::Relaxed) + 1
    };
    let rules = g.rules.get(site)?;
    rules.iter().find(|r| r.hit == n).map(|r| r.action)
}

/// Evaluate `site` and turn `Panic`/`Err`/`Torn` into their effect;
/// returns `Ok(())` on no-match or after a completed `Delay`. For
/// sites that have no buffer to tear, `Torn` behaves like `Err`.
pub fn hit(site: &str) -> crate::Result<()> {
    match check(site) {
        None => Ok(()),
        Some(Action::Delay(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        Some(Action::Panic) => panic!("failpoint '{site}' panic injected"),
        Some(Action::Err) | Some(Action::Torn) => {
            Err(crate::VszError::runtime(format!("failpoint '{site}' error injected")))
        }
    }
}

/// Write-site helper: runs `buf` through `site`'s rule before handing
/// it to `write`. `Torn` writes the first half of `buf` (at least one
/// byte when non-empty) and then reports the injected error, so the
/// output stream is left with a realistic partial frame.
pub fn write_through<W: std::io::Write>(
    site: &str,
    w: &mut W,
    buf: &[u8],
) -> crate::Result<()> {
    match check(site) {
        None => {
            w.write_all(buf)?;
            Ok(())
        }
        Some(Action::Delay(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            w.write_all(buf)?;
            Ok(())
        }
        Some(Action::Panic) => panic!("failpoint '{site}' panic injected"),
        Some(Action::Err) => {
            Err(crate::VszError::runtime(format!("failpoint '{site}' error injected")))
        }
        Some(Action::Torn) => {
            let cut = (buf.len() / 2).max(usize::from(!buf.is_empty()));
            w.write_all(&buf[..cut])?;
            let _ = w.flush();
            Err(crate::VszError::runtime(format!("failpoint '{site}' torn write injected")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global; serialize tests that reconfigure it.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn parse_grammar_and_fire_order() {
        let _g = lock();
        set_config_for_tests("alpha:2=err;beta:1=delay(0);gamma:1=torn");
        assert!(armed());
        // first pass through alpha: no action; second: err
        assert_eq!(check("alpha"), None);
        assert_eq!(check("alpha"), Some(Action::Err));
        assert_eq!(check("alpha"), None);
        // unknown site never matches and never allocates a counter entry
        assert_eq!(check("nope"), None);
        // delay(0) completes and hit() maps it to Ok
        assert!(hit("beta").is_ok());
        // torn on a non-write site degrades to an error
        assert!(hit("gamma").is_err());
        set_config_for_tests("");
        assert!(!armed());
        assert_eq!(check("alpha"), None);
    }

    #[test]
    fn torn_write_leaves_prefix() {
        let _g = lock();
        set_config_for_tests("tw:1=torn");
        let mut out = Vec::new();
        let err = write_through("tw", &mut out, &[1u8, 2, 3, 4]).unwrap_err();
        assert!(err.to_string().contains("torn"), "{err}");
        assert_eq!(out, vec![1u8, 2]);
        // the rule fired once; the next write goes through whole
        write_through("tw", &mut out, &[9u8]).unwrap();
        assert_eq!(out, vec![1u8, 2, 9]);
        set_config_for_tests("");
    }

    #[test]
    fn malformed_rules_are_ignored() {
        let _g = lock();
        set_config_for_tests("bad;also:bad;x:0=panic;y:1=delay(nope);z:1=err");
        // x:0 is clamped to hit 1; z parses; the rest are dropped
        assert_eq!(check("z"), Some(Action::Err));
        assert_eq!(check("x"), Some(Action::Panic));
        assert_eq!(check("bad"), None);
        assert_eq!(check("also"), None);
        assert_eq!(check("y"), None);
        set_config_for_tests("");
    }
}

//! Error type shared across the vecSZ crate.
//!
//! A single lightweight enum instead of an external error-handling crate:
//! every layer (container parsing, PJRT runtime, CLI) maps into it so public
//! APIs expose one `vecsz::Result<T>`.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, VszError>;

/// Unified error for all vecSZ operations.
#[derive(Debug)]
pub enum VszError {
    /// Malformed or truncated `.vsz` container / artifact manifest.
    Format(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// Invalid user configuration (CLI flags, config file, API misuse).
    Config(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Data integrity check failed (checksum, error-bound verification).
    Integrity(String),
}

impl fmt::Display for VszError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VszError::Format(m) => write!(f, "format error: {m}"),
            VszError::Io(e) => write!(f, "io error: {e}"),
            VszError::Config(m) => write!(f, "config error: {m}"),
            VszError::Runtime(m) => write!(f, "runtime error: {m}"),
            VszError::Integrity(m) => write!(f, "integrity error: {m}"),
        }
    }
}

impl std::error::Error for VszError {}

impl From<std::io::Error> for VszError {
    fn from(e: std::io::Error) -> Self {
        VszError::Io(e)
    }
}

impl VszError {
    /// Shorthand constructor for format errors.
    pub fn format(msg: impl Into<String>) -> Self {
        VszError::Format(msg.into())
    }
    /// Shorthand constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        VszError::Config(msg.into())
    }
    /// Shorthand constructor for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        VszError::Runtime(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert!(VszError::format("bad magic").to_string().contains("bad magic"));
        assert!(VszError::config("x").to_string().starts_with("config"));
        let io: VszError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("gone"));
    }
}

//! Quality and size metrics: PSNR, NRMSE, max error, compression ratio,
//! bit-rate — the quantities of the paper's rate-distortion study (Fig 10)
//! and the padding study (§V-I).

/// Distortion statistics of a reconstruction against the original.
#[derive(Clone, Copy, Debug)]
pub struct Distortion {
    pub max_abs_err: f64,
    pub mse: f64,
    pub nrmse: f64,
    /// Peak signal-to-noise ratio in dB, using the value range as peak
    /// (the SZ convention).
    pub psnr_db: f64,
    pub value_range: f64,
}

/// Compare reconstruction vs original.
pub fn distortion(orig: &[f32], rec: &[f32]) -> Distortion {
    assert_eq!(orig.len(), rec.len(), "length mismatch");
    assert!(!orig.is_empty(), "empty field");
    let mut max_err = 0.0f64;
    let mut sq = 0.0f64;
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for (&o, &r) in orig.iter().zip(rec) {
        let o = o as f64;
        let e = (o - r as f64).abs();
        max_err = max_err.max(e);
        sq += e * e;
        lo = lo.min(o);
        hi = hi.max(o);
    }
    let mse = sq / orig.len() as f64;
    let range = (hi - lo).max(f64::MIN_POSITIVE);
    let rmse = mse.sqrt();
    let psnr = 20.0 * (range / rmse.max(f64::MIN_POSITIVE)).log10();
    Distortion { max_abs_err: max_err, mse, nrmse: rmse / range, psnr_db: psnr, value_range: hi - lo }
}

/// Size statistics of a compression run.
#[derive(Clone, Copy, Debug)]
pub struct SizeStats {
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
}

impl SizeStats {
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes.max(1) as f64
    }

    /// Bits per value (raw values are f32 = 32 bits).
    pub fn bit_rate(&self) -> f64 {
        32.0 / self.ratio()
    }
}

/// One point of a rate-distortion curve (Fig 10 axes).
#[derive(Clone, Copy, Debug)]
pub struct RdPoint {
    pub eb: f64,
    pub bit_rate: f64,
    pub psnr_db: f64,
}

/// Honest f32 round-trip tolerance: the algorithmic guarantee is `eb`, but
/// pre-quantization (`x * (0.5/eb)` in f32) and the final `2*eb*d°` multiply
/// each add O(ulp(value-scale)); callers verifying the bound must allow it.
pub fn roundtrip_tolerance(eb: f64, range: f64) -> f64 {
    eb * 1.0001 + 4.0 * f32::EPSILON as f64 * range.abs()
}

/// Value range of a field (used by relative error bounds).
pub fn value_range(xs: &[f32]) -> f64 {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        lo = lo.min(x as f64);
        hi = hi.max(x as f64);
    }
    (hi - lo).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_arrays_have_infinite_psnr_like_values() {
        let a = vec![1.0f32, 2.0, 3.0];
        let d = distortion(&a, &a);
        assert_eq!(d.max_abs_err, 0.0);
        assert_eq!(d.mse, 0.0);
        assert!(d.psnr_db > 300.0); // effectively infinite
    }

    #[test]
    fn known_psnr_case() {
        // orig range 1.0, constant error 0.1 -> rmse 0.1 -> psnr = 20 dB
        let orig = vec![0.0f32, 1.0];
        let rec = vec![0.1f32, 1.1];
        let d = distortion(&orig, &rec);
        assert!((d.psnr_db - 20.0).abs() < 1e-4, "psnr {}", d.psnr_db);
        assert!((d.max_abs_err - 0.1).abs() < 1e-7);
        assert!((d.nrmse - 0.1).abs() < 1e-7);
    }

    #[test]
    fn size_stats_math() {
        let s = SizeStats { raw_bytes: 4000, compressed_bytes: 500 };
        assert_eq!(s.ratio(), 8.0);
        assert_eq!(s.bit_rate(), 4.0);
    }

    #[test]
    fn value_range_basics() {
        assert_eq!(value_range(&[3.0, -1.0, 2.0]), 4.0);
        assert_eq!(value_range(&[5.0]), 0.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        distortion(&[1.0], &[1.0, 2.0]);
    }
}

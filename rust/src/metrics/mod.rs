//! Quality and size metrics: PSNR, NRMSE, max error, compression ratio,
//! bit-rate — the quantities of the paper's rate-distortion study (Fig 10)
//! and the padding study (§V-I).

/// Distortion statistics of a reconstruction against the original.
#[derive(Clone, Copy, Debug)]
pub struct Distortion {
    pub max_abs_err: f64,
    pub mse: f64,
    pub nrmse: f64,
    /// Peak signal-to-noise ratio in dB, using the value range as peak
    /// (the SZ convention).
    pub psnr_db: f64,
    pub value_range: f64,
}

/// Compare reconstruction vs original.
pub fn distortion(orig: &[f32], rec: &[f32]) -> Distortion {
    assert_eq!(orig.len(), rec.len(), "length mismatch");
    assert!(!orig.is_empty(), "empty field");
    let mut max_err = 0.0f64;
    let mut sq = 0.0f64;
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for (&o, &r) in orig.iter().zip(rec) {
        let o = o as f64;
        let e = (o - r as f64).abs();
        max_err = max_err.max(e);
        sq += e * e;
        lo = lo.min(o);
        hi = hi.max(o);
    }
    let mse = sq / orig.len() as f64;
    let range = (hi - lo).max(f64::MIN_POSITIVE);
    let rmse = mse.sqrt();
    let psnr = 20.0 * (range / rmse.max(f64::MIN_POSITIVE)).log10();
    Distortion { max_abs_err: max_err, mse, nrmse: rmse / range, psnr_db: psnr, value_range: hi - lo }
}

/// Size statistics of a compression run.
#[derive(Clone, Copy, Debug)]
pub struct SizeStats {
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
}

impl SizeStats {
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes.max(1) as f64
    }

    /// Bits per value (raw values are f32 = 32 bits).
    pub fn bit_rate(&self) -> f64 {
        32.0 / self.ratio()
    }
}

/// One point of a rate-distortion curve (Fig 10 axes).
#[derive(Clone, Copy, Debug)]
pub struct RdPoint {
    pub eb: f64,
    pub bit_rate: f64,
    pub psnr_db: f64,
}

/// Honest f32 round-trip tolerance: the algorithmic guarantee is `eb`, but
/// pre-quantization (`x * (0.5/eb)` in f32) and the final `2*eb*d°` multiply
/// each add O(ulp(value-scale)); callers verifying the bound must allow it.
pub fn roundtrip_tolerance(eb: f64, range: f64) -> f64 {
    eb * 1.0001 + 4.0 * f32::EPSILON as f64 * range.abs()
}

/// Aggregated operation statistics of a compression service or driver —
/// the numbers a long-running `vsz serve` process reports, also filled by
/// the batch and pipeline drivers.
///
/// Designed around `merge`: per-request / per-field / per-step stats are
/// recorded independently (often on different threads) and folded into a
/// lifetime aggregate. `Default` is the merge identity (note the min/max
/// ratio sentinels), and `merge` is commutative, so the fold order never
/// matters.
#[derive(Clone, Debug)]
pub struct CompressionStats {
    pub compress_ops: u64,
    pub decompress_ops: u64,
    pub extract_ops: u64,
    pub errors: u64,
    /// Raw bytes entering compression (or compressed bytes entering
    /// decompression).
    pub bytes_in: u64,
    /// Bytes produced.
    pub bytes_out: u64,
    /// Smallest per-operation compression ratio seen (`f64::INFINITY`
    /// until the first compression — the merge identity).
    pub min_ratio: f64,
    /// Largest per-operation compression ratio seen
    /// (`f64::NEG_INFINITY` until the first compression).
    pub max_ratio: f64,
    ratio_sum: f64,
    ratio_count: u64,
    /// Seconds requests spent queued before work started.
    pub queue_wait_s: f64,
    /// Seconds spent compressing (worker time).
    pub compress_s: f64,
    /// Seconds spent decompressing.
    pub decompress_s: f64,
}

impl Default for CompressionStats {
    fn default() -> Self {
        Self {
            compress_ops: 0,
            decompress_ops: 0,
            extract_ops: 0,
            errors: 0,
            bytes_in: 0,
            bytes_out: 0,
            min_ratio: f64::INFINITY,
            max_ratio: f64::NEG_INFINITY,
            ratio_sum: 0.0,
            ratio_count: 0,
            queue_wait_s: 0.0,
            compress_s: 0.0,
            decompress_s: 0.0,
        }
    }
}

impl CompressionStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one compression: `raw` bytes in, `compressed` bytes out.
    pub fn record_compress(&mut self, raw: usize, compressed: usize, seconds: f64) {
        self.compress_ops += 1;
        self.bytes_in += raw as u64;
        self.bytes_out += compressed as u64;
        self.compress_s += seconds;
        let ratio = raw as f64 / compressed.max(1) as f64;
        self.min_ratio = self.min_ratio.min(ratio);
        self.max_ratio = self.max_ratio.max(ratio);
        self.ratio_sum += ratio;
        self.ratio_count += 1;
    }

    /// Record one decompression: `compressed` bytes in, `raw` bytes out.
    pub fn record_decompress(&mut self, compressed: usize, raw: usize, seconds: f64) {
        self.decompress_ops += 1;
        self.bytes_in += compressed as u64;
        self.bytes_out += raw as u64;
        self.decompress_s += seconds;
    }

    /// Record one partial decode (random-access extract).
    pub fn record_extract(&mut self, compressed: usize, raw: usize, seconds: f64) {
        self.extract_ops += 1;
        self.bytes_in += compressed as u64;
        self.bytes_out += raw as u64;
        self.decompress_s += seconds;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn record_queue_wait(&mut self, seconds: f64) {
        self.queue_wait_s += seconds;
    }

    pub fn total_ops(&self) -> u64 {
        self.compress_ops + self.decompress_ops + self.extract_ops
    }

    /// Mean per-operation compression ratio (0 before any compression).
    pub fn mean_ratio(&self) -> f64 {
        if self.ratio_count == 0 {
            0.0
        } else {
            self.ratio_sum / self.ratio_count as f64
        }
    }

    /// Fold `other` into `self`. Commutative and associative (sums,
    /// min/max); `Default` is the identity.
    pub fn merge(&mut self, other: &CompressionStats) {
        self.compress_ops += other.compress_ops;
        self.decompress_ops += other.decompress_ops;
        self.extract_ops += other.extract_ops;
        self.errors += other.errors;
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
        self.min_ratio = self.min_ratio.min(other.min_ratio);
        self.max_ratio = self.max_ratio.max(other.max_ratio);
        self.ratio_sum += other.ratio_sum;
        self.ratio_count += other.ratio_count;
        self.queue_wait_s += other.queue_wait_s;
        self.compress_s += other.compress_s;
        self.decompress_s += other.decompress_s;
    }

    /// Render as a JSON object (the `vsz serve` stats response payload;
    /// non-finite ratios serialize as null to stay valid JSON).
    pub fn to_json(&self) -> String {
        fn num(x: f64) -> String {
            if x.is_finite() {
                format!("{x:.6}")
            } else {
                "null".to_string()
            }
        }
        format!(
            "{{\"compress_ops\":{},\"decompress_ops\":{},\"extract_ops\":{},\
             \"errors\":{},\"bytes_in\":{},\"bytes_out\":{},\"min_ratio\":{},\
             \"max_ratio\":{},\"mean_ratio\":{},\"queue_wait_s\":{},\
             \"compress_s\":{},\"decompress_s\":{}}}",
            self.compress_ops,
            self.decompress_ops,
            self.extract_ops,
            self.errors,
            self.bytes_in,
            self.bytes_out,
            num(self.min_ratio),
            num(self.max_ratio),
            num(self.mean_ratio()),
            num(self.queue_wait_s),
            num(self.compress_s),
            num(self.decompress_s),
        )
    }
}

/// Lock-free gauges of a decoded-chunk cache (`stream::dataset`): how many
/// region reads were served from resident slabs, how many had to decode, how
/// much was evicted to stay under the byte budget, and what is resident now.
///
/// All counters are atomics so a serving thread can snapshot them without
/// taking the cache lock. A reader that joins an in-flight decode of the same
/// chunk (single-flight dedup) counts as a hit — it was served without a
/// decode of its own.
#[derive(Debug, Default)]
pub struct CacheStats {
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
    evictions: std::sync::atomic::AtomicU64,
    resident_bytes: std::sync::atomic::AtomicU64,
    repaired_reads: std::sync::atomic::AtomicU64,
}

impl CacheStats {
    pub fn record_hit(&self) {
        self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn record_miss(&self) {
        self.misses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn record_eviction(&self) {
        self.evictions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Record one chunk read that was transparently rebuilt from the
    /// container's parity layer after its on-disk frame failed its CRC.
    pub fn record_repair(&self) {
        self.repaired_reads.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn add_resident(&self, bytes: u64) {
        self.resident_bytes.fetch_add(bytes, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn sub_resident(&self, bytes: u64) {
        self.resident_bytes.fetch_sub(bytes, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        use std::sync::atomic::Ordering::Relaxed;
        CacheSnapshot {
            hits: self.hits.load(Relaxed),
            misses: self.misses.load(Relaxed),
            evictions: self.evictions.load(Relaxed),
            resident_bytes: self.resident_bytes.load(Relaxed),
            repaired_reads: self.repaired_reads.load(Relaxed),
        }
    }
}

/// Point-in-time copy of [`CacheStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub resident_bytes: u64,
    /// Chunk reads that succeeded only because the frame was rebuilt
    /// from parity (bit rot healed in-flight).
    pub repaired_reads: u64,
}

impl CacheSnapshot {
    /// Fraction of lookups served without a decode (0 before any lookup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Render as a JSON object (nested into the `vsz serve` status payload).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"hits\":{},\"misses\":{},\"evictions\":{},\"resident_bytes\":{},\"repaired_reads\":{}}}",
            self.hits, self.misses, self.evictions, self.resident_bytes, self.repaired_reads
        )
    }
}

/// Value range of a field (used by relative error bounds).
pub fn value_range(xs: &[f32]) -> f64 {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        lo = lo.min(x as f64);
        hi = hi.max(x as f64);
    }
    (hi - lo).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_arrays_have_infinite_psnr_like_values() {
        let a = vec![1.0f32, 2.0, 3.0];
        let d = distortion(&a, &a);
        assert_eq!(d.max_abs_err, 0.0);
        assert_eq!(d.mse, 0.0);
        assert!(d.psnr_db > 300.0); // effectively infinite
    }

    #[test]
    fn known_psnr_case() {
        // orig range 1.0, constant error 0.1 -> rmse 0.1 -> psnr = 20 dB
        let orig = vec![0.0f32, 1.0];
        let rec = vec![0.1f32, 1.1];
        let d = distortion(&orig, &rec);
        assert!((d.psnr_db - 20.0).abs() < 1e-4, "psnr {}", d.psnr_db);
        assert!((d.max_abs_err - 0.1).abs() < 1e-7);
        assert!((d.nrmse - 0.1).abs() < 1e-7);
    }

    #[test]
    fn size_stats_math() {
        let s = SizeStats { raw_bytes: 4000, compressed_bytes: 500 };
        assert_eq!(s.ratio(), 8.0);
        assert_eq!(s.bit_rate(), 4.0);
    }

    #[test]
    fn value_range_basics() {
        assert_eq!(value_range(&[3.0, -1.0, 2.0]), 4.0);
        assert_eq!(value_range(&[5.0]), 0.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        distortion(&[1.0], &[1.0, 2.0]);
    }

    fn sample_stats(seed: u64) -> CompressionStats {
        let mut s = CompressionStats::new();
        s.record_compress(4000 + seed as usize, 500, 0.25);
        s.record_compress(8000, 1000 + seed as usize, 0.5);
        s.record_decompress(500, 4000, 0.125);
        s.record_extract(100, 800, 0.01);
        s.record_queue_wait(0.002 * (seed + 1) as f64);
        if seed % 2 == 0 {
            s.record_error();
        }
        s
    }

    fn assert_stats_eq(a: &CompressionStats, b: &CompressionStats) {
        assert_eq!(a.compress_ops, b.compress_ops);
        assert_eq!(a.decompress_ops, b.decompress_ops);
        assert_eq!(a.extract_ops, b.extract_ops);
        assert_eq!(a.errors, b.errors);
        assert_eq!(a.bytes_in, b.bytes_in);
        assert_eq!(a.bytes_out, b.bytes_out);
        assert_eq!(a.min_ratio, b.min_ratio);
        assert_eq!(a.max_ratio, b.max_ratio);
        assert!((a.mean_ratio() - b.mean_ratio()).abs() < 1e-12);
        assert!((a.queue_wait_s - b.queue_wait_s).abs() < 1e-12);
        assert!((a.compress_s - b.compress_s).abs() < 1e-12);
        assert!((a.decompress_s - b.decompress_s).abs() < 1e-12);
    }

    #[test]
    fn compression_stats_record_and_ratios() {
        let mut s = CompressionStats::new();
        assert_eq!(s.mean_ratio(), 0.0);
        s.record_compress(4000, 500, 0.1);
        s.record_compress(4000, 2000, 0.1);
        assert_eq!(s.compress_ops, 2);
        assert_eq!(s.min_ratio, 2.0);
        assert_eq!(s.max_ratio, 8.0);
        assert_eq!(s.mean_ratio(), 5.0);
        assert_eq!(s.bytes_in, 8000);
        assert_eq!(s.bytes_out, 2500);
        assert_eq!(s.total_ops(), 2);
    }

    #[test]
    fn compression_stats_merge_identity() {
        // default is the identity on both sides
        let s = sample_stats(1);
        let mut left = CompressionStats::default();
        left.merge(&s);
        assert_stats_eq(&left, &s);
        let mut right = s.clone();
        right.merge(&CompressionStats::default());
        assert_stats_eq(&right, &s);
        // min/max sentinels survive an identity-only merge
        let mut empty = CompressionStats::default();
        empty.merge(&CompressionStats::default());
        assert_eq!(empty.min_ratio, f64::INFINITY);
        assert_eq!(empty.max_ratio, f64::NEG_INFINITY);
        assert_eq!(empty.total_ops(), 0);
    }

    #[test]
    fn compression_stats_merge_commutes() {
        let (a, b) = (sample_stats(3), sample_stats(10));
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_stats_eq(&ab, &ba);
        assert_eq!(ab.total_ops(), a.total_ops() + b.total_ops());
    }

    #[test]
    fn cache_stats_snapshot_and_json() {
        let s = CacheStats::default();
        assert_eq!(s.snapshot(), CacheSnapshot::default());
        assert_eq!(s.snapshot().hit_rate(), 0.0);
        s.record_hit();
        s.record_hit();
        s.record_hit();
        s.record_miss();
        s.record_eviction();
        s.add_resident(4096);
        s.sub_resident(1024);
        s.record_repair();
        s.record_repair();
        let snap = s.snapshot();
        assert_eq!(snap.hits, 3);
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.evictions, 1);
        assert_eq!(snap.resident_bytes, 3072);
        assert_eq!(snap.repaired_reads, 2);
        assert!((snap.hit_rate() - 0.75).abs() < 1e-12);
        let j = crate::util::json::parse(&snap.to_json()).unwrap();
        assert_eq!(j.get("hits").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("resident_bytes").unwrap().as_usize(), Some(3072));
        assert_eq!(j.get("repaired_reads").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn compression_stats_json_is_parseable() {
        let j = crate::util::json::parse(&sample_stats(2).to_json()).unwrap();
        assert_eq!(j.get("compress_ops").unwrap().as_usize(), Some(2));
        assert!(j.get("min_ratio").unwrap().as_f64().unwrap() > 1.0);
        // empty stats: non-finite ratios must serialize as null
        let e = crate::util::json::parse(&CompressionStats::default().to_json()).unwrap();
        assert_eq!(e.get("min_ratio"), Some(&crate::util::json::Json::Null));
    }
}

//! Public compression API: configuration, `compress`, `decompress`, and
//! the per-run statistics the benchmarks report.
//!
//! `compress` runs the full SZ pipeline:
//! gather blocks → P&Q backend (dual-quant or SZ-1.4) → chunked HUF2
//! Huffman codes → outlier streams (delta-varint positions + lossless
//! values) → container. With `threads > 1` the entropy tail is parallel
//! too: Huffman chunks fan out across the pool while the three lossless
//! streams compress on scoped helper threads (see [`encode_body`]).
//!
//! `decompress` reverses it through the decode backend engine
//! ([`crate::quant::decode`]): the SIMD reverse-Lorenzo wavefront kernel on
//! the active ISA (bit-identical to the scalar reference), batch-decoded
//! and parallel *across* blocks.
//!
//! The section encode/decode cores ([`encode_body`]/[`decode_body`]) are
//! shared with the chunked streaming engine in [`crate::stream`]: a v2
//! chunk is exactly one encoded body over a slab sub-field. `decompress`
//! transparently handles both container versions.

use crate::bitio::{get_uvarint, put_uvarint};
use crate::blocks::{gather_block, scatter_block, BlockShape};
use crate::coordinator::pool::{parallel_chunks_mut, ThreadPool};
use crate::data::Field;
use crate::error::{Result, VszError};
use crate::format::{self, tag, Header, Section};
use crate::huffman;
use crate::lossless;
use crate::metrics::{value_range, SizeStats};
use crate::padding::{compute_scalars, PadScalars, PaddingPolicy};
use crate::quant::decode::default_decode_backend;
use crate::quant::psz::PszBackend;
use crate::quant::simd::SimdBackend;
use crate::quant::sz14::Sz14Backend;
use crate::quant::vectorized::VecBackend;
use crate::quant::{DqConfig, PqBackend, OUTLIER_CODE};
use crate::util::timer::{mb_per_s, StageProfile, Timer};
use crate::util::{bytes_to_f32, f32_as_bytes, SendPtr};

/// How the error bound is specified.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EbMode {
    /// Absolute bound.
    Abs(f64),
    /// Value-range-relative bound: eb = rel * (max - min).
    Rel(f64),
}

impl EbMode {
    pub fn resolve(&self, data: &[f32]) -> f64 {
        match *self {
            EbMode::Abs(e) => e,
            EbMode::Rel(r) => r * value_range(data).max(f64::MIN_POSITIVE),
        }
    }
}

/// Which P&Q backend compresses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// SZ-1.4 baseline (Algorithm 1).
    Sz14,
    /// Serial dual-quant (Algorithm 2, scalar).
    Psz,
    /// Lane-chunked autovectorized dual-quant — the original vecSZ kernel.
    Vec { width: usize },
    /// Explicit-intrinsics fused dual-quant with runtime ISA dispatch
    /// (see [`crate::simd`]); bit-identical to `Psz`/`Vec` on every ISA.
    Simd { width: usize },
}

impl BackendChoice {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sz14" => Some(BackendChoice::Sz14),
            "psz" => Some(BackendChoice::Psz),
            "vec4" => Some(BackendChoice::Vec { width: 4 }),
            "vec8" | "vec" => Some(BackendChoice::Vec { width: 8 }),
            "vec16" => Some(BackendChoice::Vec { width: 16 }),
            "simd4" => Some(BackendChoice::Simd { width: 4 }),
            "simd8" => Some(BackendChoice::Simd { width: 8 }),
            "simd16" | "simd" => Some(BackendChoice::Simd { width: 16 }),
            _ => None,
        }
    }

    pub fn instantiate(&self) -> Box<dyn PqBackend> {
        match *self {
            BackendChoice::Sz14 => Box::new(Sz14Backend),
            BackendChoice::Psz => Box::new(PszBackend),
            BackendChoice::Vec { width } => Box::new(VecBackend::new(width)),
            BackendChoice::Simd { width } => Box::new(SimdBackend::new(width)),
        }
    }
}

/// Full compression configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub eb: EbMode,
    pub radius: u16,
    /// Block size; 0 = per-dimension default (256 / 16 / 8, §III-D).
    pub block_size: usize,
    pub padding: PaddingPolicy,
    pub backend: BackendChoice,
    pub threads: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            eb: EbMode::Abs(1e-4),
            radius: 512,
            block_size: 0,
            padding: PaddingPolicy::ZERO,
            backend: BackendChoice::Vec { width: 8 },
            threads: 1,
        }
    }
}

/// Traditional SZ block sizes per dimensionality (§III-D).
pub fn default_block_size(ndim: usize) -> usize {
    match ndim {
        1 => 256,
        2 => 16,
        _ => 8,
    }
}

/// Statistics of one compression run.
#[derive(Clone, Debug)]
pub struct CompressStats {
    pub n_elements: usize,
    pub n_blocks: usize,
    pub n_outliers: usize,
    pub eb: f64,
    pub block_size: usize,
    pub backend: String,
    /// Wall time of the prediction+quantization stage only — the paper's
    /// "P&Q bandwidth" numerator (input bytes / this).
    pub pq_seconds: f64,
    pub profile: StageProfile,
    pub size: SizeStats,
}

impl CompressStats {
    pub fn outlier_pct(&self) -> f64 {
        100.0 * self.n_outliers as f64 / self.n_elements.max(1) as f64
    }

    pub fn pq_bandwidth_mbs(&self) -> f64 {
        mb_per_s(self.n_elements * 4, self.pq_seconds)
    }

    pub fn total_bandwidth_mbs(&self) -> f64 {
        mb_per_s(self.n_elements * 4, self.profile.total())
    }
}

/// Run the P&Q stage only (no encoding) — the unit the paper benchmarks in
/// Figs 3/5/8. Returns (codes, outv, pads, pq_seconds).
pub fn pq_stage(
    field: &Field,
    cfg: &Config,
    backend: &dyn PqBackend,
) -> (Vec<u16>, Vec<f32>, PadScalars, f64) {
    let bs = if cfg.block_size == 0 { default_block_size(field.dims.ndim) } else { cfg.block_size };
    let shape = BlockShape::new(field.dims.ndim, bs);
    let eb = cfg.eb.resolve(&field.data);
    let dq = DqConfig::new(eb, cfg.radius, shape);
    let nb = field.dims.num_blocks(bs);
    let elems = shape.elems();
    let pads = compute_scalars(&field.data, &field.dims, bs, cfg.padding);

    let mut codes = vec![0u16; nb * elems];
    let mut outv = vec![0.0f32; nb * elems];

    let t = Timer::start();
    // Parallel over contiguous block ranges; each worker gathers its own
    // blocks and runs the backend on a batch (64 blocks per gather batch
    // bounds the scratch buffer). Workers write disjoint outv regions
    // derived from the shared base pointer (see `util::SendPtr`).
    let outv_ptr = SendPtr::new(outv.as_mut_ptr());
    let field_ref = &field.data;
    let pads_ref = &pads;
    parallel_chunks_mut(&mut codes, elems, cfg.threads, |_, item0, span| {
        let n_my_blocks = span.len() / elems;
        let mut batch = vec![0.0f32; 64 * elems];
        let mut done = 0usize;
        while done < n_my_blocks {
            let take = (n_my_blocks - done).min(64);
            let b0 = item0 + done;
            for k in 0..take {
                gather_block(
                    field_ref,
                    &field.dims,
                    bs,
                    b0 + k,
                    pads_ref.block_scalar(b0 + k),
                    &mut batch[k * elems..(k + 1) * elems],
                );
            }
            // SAFETY: span covers blocks [item0, item0 + n_my_blocks); the
            // matching outv region is disjoint between workers by the same
            // split. Raw pointer used because parallel_chunks_mut owns the
            // codes split only.
            let my_outv = unsafe {
                std::slice::from_raw_parts_mut(outv_ptr.get().add(b0 * elems), take * elems)
            };
            backend.run(
                &dq,
                &batch[..take * elems],
                b0,
                pads_ref,
                &mut span[done * elems..(done + take) * elems],
                my_outv,
            );
            done += take;
        }
    });
    let pq_seconds = t.elapsed_s();
    (codes, outv, pads, pq_seconds)
}

/// One encoded field body: the four standard sections plus the numbers the
/// caller needs for stats/framing. Produced by [`encode_body`]; consumed by
/// the v1 container writer and the v2 chunk framer alike.
pub(crate) struct EncodedBody {
    pub sections: Vec<Section>,
    pub n_outliers: usize,
    pub eb: f64,
    pub block_size: usize,
    pub n_blocks: usize,
    pub pq_seconds: f64,
    pub profile: StageProfile,
}

/// Auxiliary-stream byte floor below which the entropy stage runs serial:
/// spawning the lossless helper threads costs more than the work itself.
const ENTROPY_OVERLAP_MIN: usize = 1 << 12;

/// Encode one field (or chunk sub-field) into CODES / OUTLIER_POS /
/// OUTLIER_VAL / PAD_SCALARS sections.
///
/// The entropy tail is parallel two ways, both opt-in so a single-threaded
/// configuration spawns no threads at all: with `entropy_threads > 1` the
/// quant codes fan out across a pool through the framed HUF3 encoder
/// (the pool is only built when the stream is long enough to split), and
/// with `overlap_aux` the three independent `lossless` streams (outlier
/// positions, outlier values, pad scalars) compress on scoped helper
/// threads concurrently with the Huffman pass — skipped when they are
/// tiny and the spawn overhead would dominate. The streaming engine sets
/// `entropy_threads = 1` but `overlap_aux = true` for its pipelined chunk
/// jobs (its parallelism axis is across chunks). Neither axis changes the
/// output bytes: every payload is a pure function of its input, and HUF3
/// chunk geometry plus its local-table/gap gates are worker-count
/// independent.
pub(crate) fn encode_body(
    field: &Field,
    cfg: &Config,
    backend: &dyn PqBackend,
    entropy_threads: usize,
    overlap_aux: bool,
) -> Result<EncodedBody> {
    if field.data.is_empty() {
        return Err(VszError::config("empty field"));
    }
    if cfg.block_size != 0 && format::check_block_size(cfg.block_size as u64).is_err() {
        // same bounds the decoder enforces, so every container we write is
        // one we can read back (and a bad --block errors instead of
        // tripping the BlockShape assert)
        return Err(VszError::config(format!("block size {} out of range", cfg.block_size)));
    }
    let bs = if cfg.block_size == 0 { default_block_size(field.dims.ndim) } else { cfg.block_size };
    let mut profile = StageProfile::new();

    // resolve a Rel bound once; pq_stage would otherwise rescan the field
    let eb = cfg.eb.resolve(&field.data);
    let mut cfg = *cfg;
    cfg.eb = EbMode::Abs(eb);
    let cfg = &cfg;

    let (codes, outv, pads, pq_seconds) = pq_stage(field, cfg, backend);
    profile.add("pq", pq_seconds);

    // --- outlier streams: delta-varint positions + f32 values ---
    let mut t = Timer::start();
    let mut pos_bytes = Vec::new();
    let mut out_values: Vec<f32> = Vec::new();
    let mut prev = 0u64;
    let mut n_outliers = 0usize;
    for (i, &c) in codes.iter().enumerate() {
        if c == OUTLIER_CODE {
            put_uvarint(&mut pos_bytes, i as u64 - prev);
            prev = i as u64;
            out_values.push(outv[i]);
            n_outliers += 1;
        }
    }
    profile.add("outlier-scan", t.lap_s());

    // --- entropy coding: chunked Huffman overlapped with the three
    // independent lossless streams ---
    let alphabet = 2 * cfg.radius as usize;
    let val_bytes = f32_as_bytes(&out_values);
    let pad_bytes = f32_as_bytes(&pads.scalars);
    // only build a pool when the code stream actually splits into >1 chunk
    let pool = if entropy_threads > 1 && codes.len() > huffman::CHUNK_SYMS {
        Some(ThreadPool::new(entropy_threads))
    } else {
        None
    };
    let pool = pool.as_ref();
    let overlap =
        overlap_aux && pos_bytes.len() + val_bytes.len() + pad_bytes.len() >= ENTROPY_OVERLAP_MIN;
    let entropy_opts = huffman::EntropyOptions::default();
    let (codes_payload, pos_payload, val_payload, pad_payload) = if overlap {
        std::thread::scope(|s| {
            let h_pos = s.spawn(|| lossless::compress(&pos_bytes));
            let h_val = s.spawn(|| lossless::compress(val_bytes));
            let h_pad = s.spawn(|| lossless::compress(pad_bytes));
            let codes_payload = huffman::compress_u16_framed(&codes, alphabet, pool, &entropy_opts);
            (
                codes_payload,
                h_pos.join().expect("lossless worker panicked"),
                h_val.join().expect("lossless worker panicked"),
                h_pad.join().expect("lossless worker panicked"),
            )
        })
    } else {
        (
            huffman::compress_u16_framed(&codes, alphabet, pool, &entropy_opts),
            lossless::compress(&pos_bytes),
            lossless::compress(val_bytes),
            lossless::compress(pad_bytes),
        )
    };
    profile.add("entropy", t.lap_s());

    let sections = vec![
        Section { tag: tag::CODES, raw_len: (codes.len() * 2) as u64, payload: codes_payload },
        Section { tag: tag::OUTLIER_POS, raw_len: pos_bytes.len() as u64, payload: pos_payload },
        Section {
            tag: tag::OUTLIER_VAL,
            raw_len: (out_values.len() * 4) as u64,
            payload: val_payload,
        },
        Section {
            tag: tag::PAD_SCALARS,
            raw_len: (pads.scalars.len() * 4) as u64,
            payload: pad_payload,
        },
    ];
    Ok(EncodedBody {
        sections,
        n_outliers,
        eb,
        block_size: bs,
        n_blocks: field.dims.num_blocks(bs),
        pq_seconds,
        profile,
    })
}

/// Compress one field to a `.vsz` (v1) container.
pub fn compress(field: &Field, cfg: &Config) -> Result<(Vec<u8>, CompressStats)> {
    let backend = cfg.backend.instantiate();
    let mut body = encode_body(field, cfg, backend.as_ref(), cfg.threads, cfg.threads > 1)?;

    let mut t = Timer::start();
    let header = Header {
        dims: field.dims,
        codes_kind: backend.kind(),
        eb: body.eb,
        radius: cfg.radius,
        block_size: body.block_size as u32,
        padding: cfg.padding.normalized(),
    };
    let bytes = format::write_container(&header, &body.sections);
    body.profile.add("container", t.lap_s());

    let stats = CompressStats {
        n_elements: field.data.len(),
        n_blocks: body.n_blocks,
        n_outliers: body.n_outliers,
        eb: body.eb,
        block_size: body.block_size,
        backend: backend.name(),
        pq_seconds: body.pq_seconds,
        profile: body.profile,
        size: SizeStats { raw_bytes: field.data.len() * 4, compressed_bytes: bytes.len() },
    };
    Ok((bytes, stats))
}

/// Blocks per reconstruction batch handed to the decode backend at once —
/// bounds the per-worker scratch while amortizing the backend's per-call
/// setup, mirroring `pq_stage`'s gather batch.
const DECODE_BATCH: usize = 64;

/// Would this CODES payload actually fan out on a decode pool? HUF2 splits
/// at chunk granularity; HUF3 gap arrays split down to the gap interval,
/// so even a single-chunk container scales on threads.
fn payload_splits(payload: &[u8], need: usize) -> bool {
    if payload.starts_with(&huffman::HUF3_MAGIC) {
        return need > huffman::GAP_INTERVAL_SYMS;
    }
    payload.starts_with(&huffman::HUF2_MAGIC) && need > huffman::CHUNK_SYMS
}

/// Reconstruct a field payload from its parsed header + sections.
///
/// Shared by the v1 decompressor and the per-chunk streaming decoder
/// (where `header.dims` describes the chunk slab, not the whole field).
/// Block reconstruction goes through the [`crate::quant::decode`] backend
/// engine — the SIMD reverse-Lorenzo wavefront on the active ISA
/// (`VECSZ_FORCE_ISA`/`--isa` govern decode exactly like compress), the
/// scalar reference under forced-scalar dispatch; every backend is
/// bit-identical. Blocks are batch-decoded and parallel across workers.
pub(crate) fn decode_body(header: &Header, sections: &[Section], threads: usize) -> Result<Vec<f32>> {
    let dims = header.dims;
    if dims.is_empty() {
        return Err(VszError::format("empty dims"));
    }
    let bs = header.block_size as usize;
    format::check_block_size(bs as u64)?;
    if header.radius < 2 {
        return Err(VszError::format(format!("bad radius {}", header.radius)));
    }
    let shape = BlockShape::new(dims.ndim, bs);
    let elems = shape.elems();
    let nb = dims.num_blocks(bs);
    let need = nb
        .checked_mul(elems)
        .ok_or_else(|| VszError::format("block geometry overflow"))?;
    let dq = DqConfig::new(header.eb, header.radius, shape);

    // sections; a framed CODES payload decodes chunk-parallel (HUF2) or
    // segment-parallel (HUF3 gap arrays — splitting pays below one whole
    // chunk, down to the gap interval) on the pool, and framed lossless
    // side-streams reuse the same pool. Legacy unframed payloads decode
    // serially on this thread, and no pool is spawned unless something
    // actually fans out.
    let codes_payload = &format::find_section(sections, tag::CODES)?.payload;
    let splits = payload_splits(codes_payload, need)
        || [tag::OUTLIER_POS, tag::OUTLIER_VAL, tag::PAD_SCALARS].iter().any(|&t| {
            format::find_section(sections, t)
                .map(|s| lossless::is_framed(&s.payload))
                .unwrap_or(false)
        });
    let pool = if threads > 1 && splits { Some(ThreadPool::new(threads)) } else { None };
    let pool = pool.as_ref();
    let codes = huffman::decompress_u16_pooled(codes_payload, pool)?;
    if codes.len() != need {
        return Err(VszError::format("codes length mismatch"));
    }
    let pos_sec = format::find_section(sections, tag::OUTLIER_POS)?;
    let pos_bytes = lossless::decompress_pooled(&pos_sec.payload, pool)?;
    let val_sec = format::find_section(sections, tag::OUTLIER_VAL)?;
    let val_bytes = lossless::decompress_pooled(&val_sec.payload, pool)?;
    if val_bytes.len() % 4 != 0 {
        return Err(VszError::format("outlier values not a whole number of f32s"));
    }
    let out_values = bytes_to_f32(&val_bytes);
    let pad_sec = format::find_section(sections, tag::PAD_SCALARS)?;
    let pad_bytes = lossless::decompress_pooled(&pad_sec.payload, pool)?;
    if pad_bytes.len() % 4 != 0 {
        return Err(VszError::format("padding scalars not a whole number of f32s"));
    }
    let pad_scalars = bytes_to_f32(&pad_bytes);
    // the stored policy drives scalar indexing during decode; a corrupt
    // (CRC-unprotected) header byte must not turn into an out-of-bounds
    // panic, so the scalar count has to match the policy exactly
    let expected_scalars = match header.padding.granularity {
        crate::padding::PadGranularity::Global => 1,
        crate::padding::PadGranularity::Block => nb,
        crate::padding::PadGranularity::Edge => nb * dims.ndim,
    };
    if pad_scalars.len() != expected_scalars {
        return Err(VszError::format(format!(
            "padding scalars length {} does not match policy (need {expected_scalars})",
            pad_scalars.len()
        )));
    }
    let pads = PadScalars { policy: header.padding, scalars: pad_scalars, ndim: dims.ndim };

    // outlier expansion
    let mut outv = vec![0.0f32; nb * elems];
    {
        let mut pos = 0usize;
        let mut idx = 0u64;
        for (k, v) in out_values.iter().enumerate() {
            let (delta, n) = get_uvarint(&pos_bytes[pos..])
                .ok_or_else(|| VszError::format("outlier positions truncated"))?;
            pos += n;
            idx = if k == 0 { delta } else { idx + delta };
            *outv
                .get_mut(idx as usize)
                .ok_or_else(|| VszError::format("outlier position out of range"))? = *v;
        }
    }

    // block-parallel reconstruction; workers write disjoint field regions
    // because blocks partition the field. A shared &mut would alias at the
    // slice level though, so each worker re-derives its region through the
    // raw pointer (see `util::SendPtr`). Each worker's contiguous block
    // range decodes in DECODE_BATCH-block batches through the backend,
    // then scatters each block back into place.
    let backend = default_decode_backend();
    let backend = backend.as_ref();
    let mut out_field = vec![0.0f32; dims.len()];
    let fp = SendPtr::new(out_field.as_mut_ptr());
    let codes_ref = &codes;
    let outv_ref = &outv;
    let pads_ref = &pads;
    let mut block_ids: Vec<usize> = (0..nb).collect();
    parallel_chunks_mut(&mut block_ids, 1, threads, |_, b0, my_blocks| {
        let n_my = my_blocks.len();
        let mut rec = vec![0.0f32; DECODE_BATCH.min(n_my) * elems];
        // SAFETY: scatter_block writes only the elements of block b, and
        // blocks are disjoint by construction.
        let field_mut = unsafe { std::slice::from_raw_parts_mut(fp.get(), dims.len()) };
        let mut done = 0usize;
        while done < n_my {
            let take = (n_my - done).min(DECODE_BATCH);
            let base = b0 + done;
            backend.decode(
                header.codes_kind,
                &dq,
                &codes_ref[base * elems..(base + take) * elems],
                &outv_ref[base * elems..(base + take) * elems],
                base,
                pads_ref,
                &mut rec[..take * elems],
            );
            for k in 0..take {
                scatter_block(&rec[k * elems..(k + 1) * elems], &dims, bs, base + k, field_mut);
            }
            done += take;
        }
    });

    Ok(out_field)
}

/// Decompress a `.vsz` container (any version: v1 monolithic, v2 chunked
/// and v3 indexed-chunked containers all decode through this entry point,
/// dispatched on the leading magic).
pub fn decompress(bytes: &[u8], threads: usize) -> Result<Field> {
    if format::is_chunked_container(bytes) {
        return crate::stream::decompress_chunked(bytes, threads);
    }
    let (header, sections) = format::read_container(bytes)?;
    let data = decode_body(&header, &sections, threads)?;
    Ok(Field::new("decompressed", header.dims, data))
}

/// Compress + decompress + verify the bound in one call (CLI `verify`).
pub fn verify_roundtrip(field: &Field, cfg: &Config) -> Result<(CompressStats, f64)> {
    let (bytes, stats) = compress(field, cfg)?;
    let rec = decompress(&bytes, cfg.threads)?;
    let mut max_err = 0.0f64;
    for (o, r) in field.data.iter().zip(&rec.data) {
        max_err = max_err.max((*o as f64 - *r as f64).abs());
    }
    let tol = crate::metrics::roundtrip_tolerance(stats.eb, value_range(&field.data));
    if max_err > tol {
        return Err(VszError::Integrity(format!(
            "error bound violated: max err {max_err:.3e} > eb {:.3e}",
            stats.eb
        )));
    }
    Ok((stats, max_err))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::Dims;
    use crate::data::{suite, Scale};
    use crate::padding::{PadGranularity, PadValue};
    use crate::util::prng::Pcg32;

    fn smooth_field(dims: Dims, seed: u64) -> Field {
        let mut rng = Pcg32::seeded(seed);
        let mut x = 1.0f32;
        let data: Vec<f32> = (0..dims.len())
            .map(|_| {
                x += (rng.next_f32() - 0.5) * 0.1;
                x
            })
            .collect();
        Field::new("t", dims, data)
    }

    fn roundtrip_max_err(field: &Field, cfg: &Config) -> (CompressStats, f64) {
        let (bytes, stats) = compress(field, cfg).unwrap();
        let rec = decompress(&bytes, cfg.threads).unwrap();
        assert_eq!(rec.dims, field.dims);
        let mut max_err = 0.0f64;
        for (o, r) in field.data.iter().zip(&rec.data) {
            max_err = max_err.max((*o as f64 - *r as f64).abs());
        }
        (stats, max_err)
    }

    #[test]
    fn roundtrip_all_backends_all_dims() {
        for dims in [Dims::d1(1000), Dims::d2(37, 41), Dims::d3(11, 13, 17)] {
            let field = smooth_field(dims, 7);
            for backend in [
                BackendChoice::Psz,
                BackendChoice::Vec { width: 8 },
                BackendChoice::Vec { width: 16 },
                BackendChoice::Simd { width: 8 },
                BackendChoice::Simd { width: 16 },
                BackendChoice::Sz14,
            ] {
                let cfg = Config { backend, eb: EbMode::Abs(1e-3), ..Config::default() };
                let (stats, err) = roundtrip_max_err(&field, &cfg);
                assert!(err <= 1e-3 + 1e-6, "{:?} {dims:?}: err {err}", backend);
                assert!(stats.size.ratio() > 1.0, "no compression for {backend:?}");
            }
        }
    }

    #[test]
    fn simd_and_vec_backends_emit_identical_containers() {
        // the container stores only codes_kind, never the backend, and the
        // dual-quant backends are bit-exact — so the bytes must match too
        let field = smooth_field(Dims::d2(60, 44), 41);
        for width in [8usize, 16] {
            let c_vec = Config { backend: BackendChoice::Vec { width }, ..Config::default() };
            let c_simd = Config { backend: BackendChoice::Simd { width }, ..Config::default() };
            let (bv, _) = compress(&field, &c_vec).unwrap();
            let (bsd, stats) = compress(&field, &c_simd).unwrap();
            assert_eq!(bv, bsd, "simd{width} container diverged from vec{width}");
            assert_eq!(stats.backend, format!("simd{width}"));
        }
    }

    #[test]
    fn roundtrip_with_threads_matches_serial() {
        let field = smooth_field(Dims::d2(100, 100), 9);
        let cfg1 = Config { threads: 1, ..Config::default() };
        let cfg4 = Config { threads: 4, ..Config::default() };
        let (b1, _) = compress(&field, &cfg1).unwrap();
        let (b4, _) = compress(&field, &cfg4).unwrap();
        assert_eq!(b1, b4, "threading must not change the bitstream");
        let r4 = decompress(&b4, 4).unwrap();
        let r1 = decompress(&b1, 1).unwrap();
        assert_eq!(r1.data, r4.data);
    }

    #[test]
    fn padding_policies_roundtrip() {
        let field = smooth_field(Dims::d2(50, 60), 11);
        for value in [PadValue::Zero, PadValue::Min, PadValue::Max, PadValue::Avg] {
            for gran in [PadGranularity::Global, PadGranularity::Block, PadGranularity::Edge] {
                let cfg = Config {
                    padding: PaddingPolicy::new(value, gran),
                    eb: EbMode::Abs(1e-3),
                    ..Config::default()
                };
                let (_, err) = roundtrip_max_err(&field, &cfg);
                assert!(err <= 1e-3 + 1e-6, "{value:?}/{gran:?}: err {err}");
            }
        }
    }

    #[test]
    fn relative_error_bound_resolves_to_range() {
        let field = smooth_field(Dims::d1(5000), 13);
        let range = value_range(&field.data);
        let cfg = Config { eb: EbMode::Rel(1e-3), ..Config::default() };
        let (stats, err) = roundtrip_max_err(&field, &cfg);
        assert!((stats.eb - 1e-3 * range).abs() < 1e-12);
        assert!(err as f64 <= stats.eb * 1.0001 + 1e-9);
    }

    #[test]
    fn verify_roundtrip_api() {
        let field = smooth_field(Dims::d3(8, 9, 10), 17);
        let cfg = Config::default();
        let (stats, err) = verify_roundtrip(&field, &cfg).unwrap();
        assert!(err <= stats.eb * 1.0001);
    }

    #[test]
    fn real_suite_field_compresses_well() {
        let ds = suite("cesm", Scale::Small, 3).unwrap();
        // shrink to keep the test fast: take the first field rows
        let f = &ds.fields[0];
        let sub_dims = Dims::d2(128, 256);
        let mut sub = Vec::with_capacity(sub_dims.len());
        for i in 0..128 {
            sub.extend_from_slice(&f.data[i * f.dims.shape[1]..i * f.dims.shape[1] + 256]);
        }
        let field = Field::new("CLDHGH-sub", sub_dims, sub);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (stats, err) = roundtrip_max_err(&field, &cfg);
        assert!(err <= 1e-3 + 1e-6);
        assert!(stats.size.ratio() > 4.0, "smooth climate field should compress >4x, got {:.2}", stats.size.ratio());
    }

    #[test]
    fn nonuniform_dims_with_partial_blocks() {
        // dims deliberately not multiples of bs
        let field = smooth_field(Dims::d2(33, 45), 19);
        let cfg = Config { block_size: 16, ..Config::default() };
        let (_, err) = roundtrip_max_err(&field, &cfg);
        assert!(err <= 1e-4 + 1e-6);
    }

    #[test]
    fn stats_are_coherent() {
        let field = smooth_field(Dims::d1(4096), 23);
        let (_, stats) = compress(&field, &Config::default()).unwrap();
        assert_eq!(stats.n_elements, 4096);
        assert_eq!(stats.n_blocks, 16);
        assert!(stats.pq_seconds >= 0.0);
        assert!(stats.profile.total() >= stats.pq_seconds);
        assert!(stats.outlier_pct() >= 0.0 && stats.outlier_pct() <= 100.0);
        assert!(stats.size.ratio() > 0.0);
    }

    #[test]
    fn empty_field_rejected() {
        let field = Field::new("empty", Dims::d1(0), Vec::new());
        assert!(compress(&field, &Config::default()).is_err());
    }

    /// Locate every section boundary of a v1 container: byte offsets of the
    /// section tag, the crc field and the first/last payload bytes.
    fn section_landmarks(bytes: &[u8]) -> Vec<usize> {
        // reparse manually: header is fixed 48 bytes, then n_sections frames
        let mut marks = Vec::new();
        let mut pos = 48usize; // magic..pad_granularity
        let n_sections = bytes[pos] as usize;
        pos += 1;
        for _ in 0..n_sections {
            marks.push(pos); // tag byte
            pos += 1;
            let (_, n1) = get_uvarint(&bytes[pos..]).unwrap();
            pos += n1;
            let (enc_len, n2) = get_uvarint(&bytes[pos..]).unwrap();
            pos += n2;
            marks.push(pos); // crc field
            pos += 4;
            marks.push(pos); // first payload byte
            pos += enc_len as usize;
            marks.push(pos - 1); // last payload byte
        }
        assert_eq!(pos, bytes.len(), "landmark walk must consume the container");
        marks
    }

    #[test]
    fn legacy_unframed_codes_payload_still_decodes() {
        // Pre-HUF2 containers carried the CODES section as one unframed
        // Huffman stream (`huffman::compress_u16`), and the first parallel
        // entropy stage wrote HUF2; the v1 container framing itself is
        // unchanged, so rebuilding a container with either older payload
        // reproduces the corresponding historical on-disk format exactly.
        let field = smooth_field(Dims::d2(40, 30), 101);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, _) = compress(&field, &cfg).unwrap();
        let (header, sections) = format::read_container(&bytes).unwrap();
        let idx = sections.iter().position(|s| s.tag == tag::CODES).unwrap();
        assert!(
            sections[idx].payload.starts_with(&huffman::HUF3_MAGIC),
            "new containers should carry HUF3-framed codes"
        );
        let syms = huffman::decompress_u16(&sections[idx].payload).unwrap();
        let modern = decompress(&bytes, 2).unwrap();
        let alphabet = 2 * header.radius as usize;
        let older_payloads = [
            huffman::compress_u16(&syms, alphabet),
            huffman::compress_u16_chunked(&syms, alphabet, None),
        ];
        for (kind, payload) in ["legacy", "huf2"].iter().zip(older_payloads) {
            let mut sections = sections.clone();
            sections[idx].payload = payload;
            let legacy = format::write_container(&header, &sections);
            let old = decompress(&legacy, 2).unwrap();
            assert_eq!(modern.data, old.data, "{kind} CODES payload must decode bit-exactly");
        }
    }

    #[test]
    fn corrupt_container_is_rejected() {
        let field = smooth_field(Dims::d1(100), 29);
        let (mut bytes, _) = compress(&field, &Config::default()).unwrap();
        let n = bytes.len();
        bytes[n - 2] ^= 0x55;
        assert!(decompress(&bytes, 1).is_err());
    }

    #[test]
    fn corruption_sweep_every_section_boundary() {
        // flip a byte at every section landmark (tag, crc, payload first and
        // last byte): decompress must return Err — never panic, never
        // silently return wrong data.
        let field = smooth_field(Dims::d2(40, 30), 31);
        let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
        let (bytes, _) = compress(&field, &cfg).unwrap();
        assert!(decompress(&bytes, 1).is_ok(), "pristine container must decode");
        for &at in &section_landmarks(&bytes) {
            let mut bad = bytes.clone();
            bad[at] ^= 0xA5;
            match decompress(&bad, 1) {
                Err(_) => {}
                Ok(rec) => {
                    // a flip inside a varint length can, in principle,
                    // reframe to a still-valid container only if everything
                    // re-checks; require the data to be untouched then.
                    assert_eq!(
                        rec.data.len(),
                        field.data.len(),
                        "byte flip at {at} produced a silently different field"
                    );
                }
            }
        }
    }

    #[test]
    fn truncation_sweep_returns_err_never_panics() {
        let field = smooth_field(Dims::d2(24, 24), 37);
        let (bytes, _) = compress(&field, &Config::default()).unwrap();
        let cuts: Vec<usize> = vec![
            0,
            1,
            3,                 // inside magic
            5,                 // inside version
            20,                // inside dims
            47,                // last header byte
            49,                // inside first section frame
            bytes.len() / 2,   // inside a payload
            bytes.len() - 1,   // one byte short
        ];
        for cut in cuts {
            assert!(decompress(&bytes[..cut], 1).is_err(), "cut at {cut} accepted");
        }
    }
}

//! Explicit-intrinsics SIMD layer: runtime ISA dispatch + the fused
//! prequant/predict/quantize kernel (§III-C, done with `core::arch`).
//!
//! The crate's `vec{4,8,16}` backends rely on LLVM autovectorizing
//! fixed-width lane chunks, which silently degrades to scalar code on the
//! default `target-cpu` and cannot use the ISA's rounding/convert/select
//! instructions directly. This module is the hand-written counterpart the
//! paper actually benchmarks:
//!
//! * `lanes` — a thin `f32 × W` lane abstraction (load/store, add/sub/
//!   mul, round-ties-even, abs, compare, select, truncating convert with a
//!   u16 narrowing store) implemented with x86-64 AVX2 intrinsics (AVX-512F
//!   behind the `avx512` cargo feature), aarch64 NEON, and a safe scalar
//!   fallback. All `unsafe` in the crate's SIMD path lives here and in
//!   [`kernel`]; every intrinsic impl carries its safety argument.
//! * [`kernel`] — the **fused** dual-quant batch kernel: the per-block
//!   prequantization pass is folded into the predict/quantize lane loop, so
//!   each element is pre-quantized exactly once, in-register, as it streams
//!   through (the separate prequant pass's full re-read of every block is
//!   gone; the `dq` scratch block remains only because neighbour rows need
//!   it). Operation order is exactly `(w+n+u)-(nw+nu+wu)+nwu`, so output is
//!   bit-identical to `PszBackend`/`VecBackend` on every ISA.
//! * [`decode`] — the reverse-Lorenzo **wavefront** kernel: decompression
//!   reconstructs from already-reconstructed neighbours, so the independent
//!   axis is the anti-diagonal (`i + j = d`) wavefront, swept west to east
//!   over a skewed per-diagonal layout that turns every neighbour read into
//!   a contiguous vector load; 3D sweeps plane by plane against the fully
//!   reconstructed up-plane, 1D stays scalar (true west prefix dependency).
//!   Bit-identical to the scalar reference decode on every ISA.
//! * [`Isa`] — runtime CPU dispatch. The best ISA is detected once via
//!   `is_x86_feature_detected!` (NEON is architecturally guaranteed on
//!   aarch64) and can be overridden for benchmarking/testing with the
//!   `VECSZ_FORCE_ISA` environment variable or the `--isa` CLI flag
//!   (programmatically: [`force_isa`]). Forcing an ISA the host cannot run
//!   falls back to the detected one — the dispatcher never executes an
//!   instruction the CPU lacks.
//!
//! The public entry points are [`run_fused`] and [`run_reverse`];
//! `quant::simd::SimdBackend` wraps the former behind the common
//! `PqBackend` trait, `quant::decode::SimdDecodeBackend` the latter behind
//! `DecodeBackend`.

pub mod decode;
pub mod kernel;
pub(crate) mod lanes;

use std::sync::atomic::{AtomicU8, Ordering};

pub use decode::run_reverse;
pub use kernel::run_fused;

/// Instruction-set architectures the fused kernel can dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar fallback — bit-identical, always available.
    Scalar,
    /// aarch64 NEON (128-bit, 4 × f32).
    Neon,
    /// x86-64 AVX2 (256-bit, 8 × f32).
    Avx2,
    /// x86-64 AVX-512F (512-bit, 16 × f32). Compiled only with the
    /// `avx512` cargo feature (the intrinsics need rustc >= 1.89).
    Avx512,
}

impl Isa {
    /// Stable lowercase name (used by `VECSZ_FORCE_ISA`, `--isa` and the
    /// `BENCH_*.json` metadata).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Neon => "neon",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
        }
    }

    /// Parse a [`name`](Self::name).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "neon" => Some(Isa::Neon),
            "avx2" => Some(Isa::Avx2),
            "avx512" | "avx512f" => Some(Isa::Avx512),
            _ => None,
        }
    }

    /// Native f32 lanes per vector register.
    pub fn native_lanes(self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Neon => 4,
            Isa::Avx2 => 8,
            Isa::Avx512 => 16,
        }
    }

    /// Can the host execute this ISA's instructions?
    pub fn is_available(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            Isa::Avx512 => std::arch::is_x86_feature_detected!("avx512f"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Every ISA the fused kernel can run on this host, best first
    /// (the test matrix iterates this).
    pub fn available() -> Vec<Isa> {
        [Isa::Avx512, Isa::Avx2, Isa::Neon, Isa::Scalar]
            .into_iter()
            .filter(|i| i.is_available())
            .collect()
    }

    /// Best ISA the host supports (ignoring any override).
    pub fn detect_best() -> Isa {
        Self::available().first().copied().unwrap_or(Isa::Scalar)
    }

    /// The ISA the dispatcher will actually use: a programmatic
    /// [`force_isa`] override wins, then `VECSZ_FORCE_ISA`, then
    /// [`detect_best`](Self::detect_best). Unavailable overrides are
    /// ignored (with a warning for the env var).
    pub fn active() -> Isa {
        match state() {
            STATE_AUTO => Isa::detect_best(),
            s => from_idx(s - STATE_FORCED_BASE),
        }
    }

    fn idx(self) -> u8 {
        match self {
            Isa::Scalar => 0,
            Isa::Neon => 1,
            Isa::Avx2 => 2,
            Isa::Avx512 => 3,
        }
    }
}

fn from_idx(i: u8) -> Isa {
    match i {
        0 => Isa::Scalar,
        1 => Isa::Neon,
        2 => Isa::Avx2,
        _ => Isa::Avx512,
    }
}

/// Dispatch-override state: 0 = uninitialized (env not read yet),
/// 1 = automatic detection, `STATE_FORCED_BASE + idx` = forced ISA.
static STATE: AtomicU8 = AtomicU8::new(0);
const STATE_AUTO: u8 = 1;
const STATE_FORCED_BASE: u8 = 2;

fn state() -> u8 {
    let s = STATE.load(Ordering::Relaxed);
    if s != 0 {
        return s;
    }
    // first touch: honour VECSZ_FORCE_ISA once (empty counts as unset so
    // CI matrices can pass it through unconditionally)
    let s = match std::env::var("VECSZ_FORCE_ISA") {
        Ok(v) if v.trim().is_empty() => STATE_AUTO,
        Ok(v) => match Isa::parse(&v) {
            Some(isa) if isa.is_available() => STATE_FORCED_BASE + isa.idx(),
            Some(isa) => {
                eprintln!(
                    "vecsz: VECSZ_FORCE_ISA={} not available on this host; using {}",
                    isa.name(),
                    Isa::detect_best().name()
                );
                STATE_AUTO
            }
            None => {
                eprintln!("vecsz: VECSZ_FORCE_ISA='{v}' not recognized; using auto detection");
                STATE_AUTO
            }
        },
        Err(_) => STATE_AUTO,
    };
    // racing first-touchers compute the same value; plain store is fine
    STATE.store(s, Ordering::Relaxed);
    s
}

/// Force the dispatcher to `isa` (benchmarking/test hook; the CLI `--isa`
/// flag lands here). `None` — and an unavailable ISA, which is ignored —
/// restores the default precedence (`VECSZ_FORCE_ISA`, then detection) by
/// clearing the state so the env var is re-read on the next touch; a
/// programmatic force must not permanently erase the env override.
/// Returns the now-active ISA.
pub fn force_isa(isa: Option<Isa>) -> Isa {
    match isa {
        Some(i) if i.is_available() => STATE.store(STATE_FORCED_BASE + i.idx(), Ordering::Relaxed),
        _ => STATE.store(0, Ordering::Relaxed),
    }
    Isa::active()
}

/// Target features this binary was *compiled* with (the `-C target-cpu`
/// axis, as opposed to the runtime-detected ISA) — recorded in the
/// `BENCH_*.json` metadata so perf baselines are never diffed across
/// incompatible builds.
pub fn compiled_target_features() -> String {
    let mut feats: Vec<&str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if cfg!(target_feature = "sse4.1") {
            feats.push("sse4.1");
        }
        if cfg!(target_feature = "avx") {
            feats.push("avx");
        }
        if cfg!(target_feature = "avx2") {
            feats.push("avx2");
        }
        if cfg!(target_feature = "avx512f") {
            feats.push("avx512f");
        }
    }
    #[cfg(target_arch = "aarch64")]
    feats.push("neon");
    if feats.is_empty() {
        feats.push("baseline");
    }
    format!("{}:{}", std::env::consts::ARCH, feats.join("+"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for isa in [Isa::Scalar, Isa::Neon, Isa::Avx2, Isa::Avx512] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("AVX2"), Some(Isa::Avx2));
        assert_eq!(Isa::parse("avx512f"), Some(Isa::Avx512));
        assert_eq!(Isa::parse("mmx"), None);
    }

    #[test]
    fn scalar_always_available_and_listed_last() {
        assert!(Isa::Scalar.is_available());
        let avail = Isa::available();
        assert_eq!(*avail.last().unwrap(), Isa::Scalar);
        assert!(avail.contains(&Isa::detect_best()));
        // best-first ordering: native lane counts are non-increasing
        for w in avail.windows(2) {
            assert!(w[0].native_lanes() >= w[1].native_lanes());
        }
    }

    #[test]
    fn force_isa_roundtrip() {
        // baseline respects a VECSZ_FORCE_ISA the test run may carry (the
        // scalar-forced CI job does), so compare against it, not detection
        let baseline = Isa::active();
        assert_eq!(force_isa(Some(Isa::Scalar)), Isa::Scalar);
        assert_eq!(Isa::active(), Isa::Scalar);
        // unavailable forces are ignored and restore env-then-detect
        #[cfg(not(target_arch = "aarch64"))]
        assert_eq!(force_isa(Some(Isa::Neon)), baseline);
        assert_eq!(force_isa(None), baseline, "None must re-honour the env override");
    }

    #[test]
    fn compiled_features_nonempty() {
        let f = compiled_target_features();
        assert!(f.contains(':'), "{f}");
    }
}

//! The fused prequant + predict + quantize batch kernel.
//!
//! `VecBackend` runs two passes per block: (1) pre-quantize every element
//! into a scratch block, (2) re-read the scratch and predict/quantize.
//! This kernel fuses them: each element is loaded once from the raw block,
//! pre-quantized **in-register**, stored to the scratch (later rows read
//! it back as their north/up neighbours) and immediately predicted and
//! quantized — pass 2's full re-read of the current element stream is
//! gone, and every element is pre-quantized exactly once.
//!
//! Bit-exactness with `PszBackend`/`VecBackend` holds because
//!
//! * the west neighbour is **read back from the scratch row** just after
//!   the store (not recomputed), so it is the same f32 the two-pass code
//!   reads;
//! * border neighbours come from *broadcast rows* pre-filled with the
//!   pre-quantized padding scalars, reproducing the halo-fill precedence
//!   (highest axis wins shared cells), and every prediction keeps
//!   `predict_halo`'s operation order `(w+n+u)-(nw+nu+wu)+nwu`;
//! * the lane ops are single IEEE f32 instructions with scalar-identical
//!   semantics (see `lanes`), so lane partitioning cannot change results.
//!
//! The backend `width` (4/8/16, the paper's vector-length knob) is the
//! chunk the row loop advances by; a chunk is processed as
//! `width / LANES` native vectors (e.g. width 16 on AVX2 = 2 × ymm — an
//! unrolled form), and rows shorter than a chunk fall to the scalar tail,
//! exactly like `VecBackend`'s remainder handling.

#[cfg(target_arch = "x86_64")]
use super::lanes::Avx2Lane;
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
use super::lanes::Avx512Lane;
#[cfg(target_arch = "aarch64")]
use super::lanes::NeonLane;
use super::lanes::{LaneF32, ScalarLane, MAX_VECTOR_RADIUS};
use super::Isa;
use crate::padding::PadScalars;
use crate::quant::{check_batch, prequant, DqConfig, OUTLIER_CODE};

/// Run the fused dual-quant kernel over a gathered-block batch (the
/// `PqBackend::run` contract) on `isa`, with lane-chunk width `width`
/// (4, 8 or 16).
///
/// Safe for any arguments: an unavailable `isa` falls back to the best
/// detected one, and a radius beyond `MAX_VECTOR_RADIUS` (32767) routes to
/// the scalar path (whose Rust casts match `VecBackend` for every radius).
#[allow(clippy::too_many_arguments)]
pub fn run_fused(
    isa: Isa,
    width: usize,
    cfg: &DqConfig,
    blocks: &[f32],
    block_base: usize,
    pads: &PadScalars,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    assert!(matches!(width, 4 | 8 | 16), "supported lane-chunk widths: 4, 8, 16");
    let isa = if isa.is_available() { isa } else { Isa::detect_best() };
    // Vector narrowing is only exact while codes stay < 65534; larger
    // radii (degenerate — the alphabet no longer fits u16 headroom) take
    // the scalar path, which wraps exactly like VecBackend.
    let isa = if cfg.radius > MAX_VECTOR_RADIUS { Isa::Scalar } else { isa };
    // A chunk narrower than the native register cannot fill one vector;
    // drop to the widest ISA whose register fits the chunk.
    let isa = match isa {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        Isa::Avx512 if width < 16 => Isa::Avx2,
        Isa::Avx2 if width < 8 => Isa::Scalar,
        Isa::Neon if width < 4 => Isa::Scalar,
        other => other,
    };
    match (isa, width) {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        // SAFETY: AVX-512F availability was checked by `is_available`
        (Isa::Avx512, 16) => unsafe {
            batch_avx512_w16(cfg, blocks, block_base, pads, codes, outv)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: AVX2 availability was checked by `is_available`
        (Isa::Avx2, 8) => unsafe { batch_avx2_w8(cfg, blocks, block_base, pads, codes, outv) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above
        (Isa::Avx2, 16) => unsafe { batch_avx2_w16(cfg, blocks, block_base, pads, codes, outv) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally guaranteed on aarch64
        (Isa::Neon, w) => unsafe {
            match w {
                4 => batch::<NeonLane, 4>(cfg, blocks, block_base, pads, codes, outv),
                8 => batch::<NeonLane, 8>(cfg, blocks, block_base, pads, codes, outv),
                _ => batch::<NeonLane, 16>(cfg, blocks, block_base, pads, codes, outv),
            }
        },
        // SAFETY: the scalar lane type has no CPU or alignment
        // requirements; all pointer arithmetic is bounds-derived
        (_, w) => unsafe {
            match w {
                4 => batch::<ScalarLane, 4>(cfg, blocks, block_base, pads, codes, outv),
                8 => batch::<ScalarLane, 8>(cfg, blocks, block_base, pads, codes, outv),
                _ => batch::<ScalarLane, 16>(cfg, blocks, block_base, pads, codes, outv),
            }
        },
    }
}

// Monomorphized `#[target_feature]` entries: marking the whole batch lets
// LLVM inline the (feature-gated) intrinsic wrappers into the loops instead
// of leaving per-intrinsic calls behind.

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn batch_avx2_w8(
    cfg: &DqConfig,
    blocks: &[f32],
    block_base: usize,
    pads: &PadScalars,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    batch::<Avx2Lane, 8>(cfg, blocks, block_base, pads, codes, outv)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn batch_avx2_w16(
    cfg: &DqConfig,
    blocks: &[f32],
    block_base: usize,
    pads: &PadScalars,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    batch::<Avx2Lane, 16>(cfg, blocks, block_base, pads, codes, outv)
}

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
#[target_feature(enable = "avx512f")]
unsafe fn batch_avx512_w16(
    cfg: &DqConfig,
    blocks: &[f32],
    block_base: usize,
    pads: &PadScalars,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    batch::<Avx512Lane, 16>(cfg, blocks, block_base, pads, codes, outv)
}

/// Branch form of the outlier split for row heads and scalar tails —
/// verbatim `VecBackend::emit1` semantics.
#[inline(always)]
fn emit_scalar(dq: f32, pred: f32, radius_f: f32, code: &mut u16, ov: &mut f32) {
    let delta = dq - pred;
    if delta.abs() < radius_f {
        *code = (delta + radius_f) as i32 as u16;
        *ov = 0.0;
    } else {
        *code = OUTLIER_CODE;
        *ov = dq;
    }
}

/// One fused row: pre-quantize `raw` into `dqrow` while predicting with
/// `pred(j)` built from the west lane and the supplied neighbour rows.
///
/// `$north`/`$up`/`$nu` are either real scratch rows of the previous
/// row/plane or broadcast pad rows — the caller encodes the border cases
/// by substitution, the expression itself never changes.
macro_rules! fused_row {
    ($V:ty, $CW:expr, $raw:expr, $dqrow:expr, $pred0:expr, $hie:expr, $radius_f:expr,
     $codes:expr, $outv:expr, |$w:ident, $j:ident| $vpred:expr, |$ws:ident, $js:ident| $spred:expr
    ) => {{
        let raw: &[f32] = $raw;
        let dqrow: &mut [f32] = $dqrow;
        let codes: &mut [u16] = $codes;
        let outv: &mut [f32] = $outv;
        let n = raw.len();
        // j = 0: the row head predicts purely from halo values
        let d0 = prequant(raw[0], $hie);
        dqrow[0] = d0;
        emit_scalar(d0, $pred0, $radius_f, &mut codes[0], &mut outv[0]);
        let rv = <$V>::splat($radius_f);
        let hv = <$V>::splat($hie);
        let zv = <$V>::splat(0.0);
        let mut j = 1usize;
        while j + $CW <= n {
            let mut t = 0usize;
            while t < $CW {
                let $j = j + t;
                // fused prequant: raw -> dq in-register, then to scratch
                let d = <$V>::load(raw.as_ptr().add($j)).mul(hv).round_ne();
                d.store(dqrow.as_mut_ptr().add($j));
                // west reads the scratch *after* the store, so lane t>0
                // sees the freshly pre-quantized values — same f32s the
                // two-pass kernel reads
                let $w = <$V>::load(dqrow.as_ptr().add($j - 1));
                let pred = $vpred;
                let delta = d.sub(pred);
                let m = delta.abs().lt(rv);
                <$V>::select(m, delta.add(rv), zv).store_codes(codes.as_mut_ptr().add($j));
                <$V>::select(m, zv, d).store(outv.as_mut_ptr().add($j));
                t += <$V>::LANES;
            }
            j += $CW;
        }
        while j < n {
            let $js = j;
            let d = prequant(raw[$js], $hie);
            dqrow[$js] = d;
            let $ws = dqrow[$js - 1];
            let pred = $spred;
            emit_scalar(d, pred, $radius_f, &mut codes[$js], &mut outv[$js]);
            j += 1;
        }
    }};
}

/// The generic fused batch: the row/plane structure of `VecBackend`'s
/// `run_w`, with the pre-quantization pass folded into each row visit.
///
/// # Safety
/// `V`'s ISA must be executable on the current CPU; `CW` must be a
/// multiple of `V::LANES` and >= `V::LANES`.
///
/// `inline(always)` is load-bearing: collapsing the batch into its
/// `#[target_feature]` entry point lets the always-inline lane wrappers
/// (and the intrinsics inside them) fold into a context where the feature
/// is enabled, instead of degrading to per-intrinsic function calls.
/// (`rustfmt::skip`: the prediction-expression macro calls read as layed
/// out here; rustfmt would scramble the operand-order comments.)
#[rustfmt::skip]
#[inline(always)]
unsafe fn batch<V: LaneF32, const CW: usize>(
    cfg: &DqConfig,
    blocks: &[f32],
    block_base: usize,
    pads: &PadScalars,
    codes: &mut [u16],
    outv: &mut [f32],
) {
    let shape = cfg.shape;
    let elems = shape.elems();
    let bs = shape.bs;
    let nb = check_batch(shape, blocks, codes, outv);
    let radius_f = cfg.radius as f32;
    let hie = cfg.half_inv_eb();
    // scratch: pre-quantized block (neighbour rows) + broadcast pad rows
    let mut dq = vec![0.0f32; elems];
    let mut prow0 = vec![0.0f32; bs];
    let mut prow1 = vec![0.0f32; bs];

    for b in 0..nb {
        let block = &blocks[b * elems..(b + 1) * elems];
        let gb = block_base + b;
        let ccodes = &mut codes[b * elems..(b + 1) * elems];
        let coutv = &mut outv[b * elems..(b + 1) * elems];

        match shape.ndim {
            1 => {
                let p0 = prequant(pads.edge_scalar(gb, 0), hie);
                fused_row!(V, CW, block, &mut dq[..], p0, hie, radius_f, ccodes, coutv,
                    |w, _j| w, |w, _j| w);
            }
            2 => {
                // halo precedence: axis-1 planes overwrite shared cells,
                // so row-0 body cells hold p0, the column (incl. corner) p1
                let p0 = prequant(pads.edge_scalar(gb, 0), hie);
                let p1 = prequant(pads.edge_scalar(gb, 1), hie);
                prow0.as_mut_slice().fill(p0);
                for i in 0..bs {
                    let row = i * bs;
                    let (before, cur_on) = dq.split_at_mut(row);
                    let cur = &mut cur_on[..bs];
                    let c = &mut ccodes[row..row + bs];
                    let v = &mut coutv[row..row + bs];
                    // (i,0): w = nw = p1; row 0 substitutes the p0 row for
                    // north, reproducing `cur[j-1] + p0 - p0` exactly
                    let (north, pred0): (&[f32], f32) = if i == 0 {
                        (&prow0[..], p1 + p0 - p1)
                    } else {
                        let nr = &before[row - bs..];
                        (nr, p1 + nr[0] - p1)
                    };
                    let nrp = north.as_ptr();
                    fused_row!(V, CW, &block[row..row + bs], cur, pred0, hie, radius_f,
                        c, v,
                        |w, j| w.add(V::load(nrp.add(j))).sub(V::load(nrp.add(j - 1))),
                        |w, j| w + north[j] - north[j - 1]);
                }
            }
            3 => {
                // halo precedence (fill order axis0 -> axis1 -> axis2):
                //   j-coord 0 -> p2, else i-coord 0 -> p1, else k-coord 0 -> p0
                let p0 = prequant(pads.edge_scalar(gb, 0), hie);
                let p1 = prequant(pads.edge_scalar(gb, 1), hie);
                let p2 = prequant(pads.edge_scalar(gb, 2), hie);
                prow0.as_mut_slice().fill(p0);
                prow1.as_mut_slice().fill(p1);
                let plane = bs * bs;
                for k in 0..bs {
                    for i in 0..bs {
                        let row = k * plane + i * bs;
                        let (before, cur_on) = dq.split_at_mut(row);
                        let cur = &mut cur_on[..bs];
                        let c = &mut ccodes[row..row + bs];
                        let v = &mut coutv[row..row + bs];
                        // substitute broadcast pad rows on the borders; the
                        // unified expression then reproduces every case of
                        // the two-pass kernel with identical operand order
                        let (north, up, nu, pred0): (&[f32], &[f32], &[f32], f32) =
                            match (k > 0, i > 0) {
                                (true, true) => {
                                    let nr = &before[row - bs..row];
                                    let ur = &before[row - plane..row - plane + bs];
                                    let nr2 = &before[row - plane - bs..row - plane];
                                    // j=0: w = nw = wu = nwu = p2
                                    let pr = (p2 + nr[0] + ur[0]) - (p2 + nr2[0] + p2) + p2;
                                    (nr, ur, nr2, pr)
                                }
                                (true, false) => {
                                    // i == 0: n, nw, nu, nwu live in the
                                    // i=0 halo -> p1 row
                                    let ur = &before[row - plane..row - plane + bs];
                                    let pr = (p2 + p1 + ur[0]) - (p2 + p1 + p2) + p2;
                                    (&prow1[..], ur, &prow1[..], pr)
                                }
                                (false, true) => {
                                    // k == 0: u, wu, nu, nwu live in the
                                    // k=0 halo -> p0 row
                                    let nr = &before[row - bs..row];
                                    let pr = (p2 + nr[0] + p0) - (p2 + p0 + p2) + p2;
                                    (nr, &prow0[..], &prow0[..], pr)
                                }
                                (false, false) => {
                                    // k == i == 0: n/nw/nu/nwu -> p1,
                                    // u/wu -> p0 (see run_w's derivation)
                                    let pr = (p2 + p1 + p0) - (p2 + p1 + p2) + p2;
                                    (&prow1[..], &prow0[..], &prow1[..], pr)
                                }
                            };
                        let (np, up_p, nup) = (north.as_ptr(), up.as_ptr(), nu.as_ptr());
                        // predict_halo order: (w+n+u) - (nw+nu+wu) + nwu
                        fused_row!(V, CW, &block[row..row + bs], cur, pred0, hie,
                            radius_f, c, v,
                            |w, j| w
                                .add(V::load(np.add(j)))
                                .add(V::load(up_p.add(j)))
                                .sub(
                                    V::load(np.add(j - 1))
                                        .add(V::load(nup.add(j)))
                                        .add(V::load(up_p.add(j - 1))),
                                )
                                .add(V::load(nup.add(j - 1))),
                            |w, j| (w + north[j] + up[j]) - (north[j - 1] + nu[j] + up[j - 1])
                                + nu[j - 1]);
                    }
                }
            }
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};

    fn zero_pads(ndim: usize) -> PadScalars {
        PadScalars {
            policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
            scalars: vec![0.0],
            ndim,
        }
    }

    // The cross-backend / cross-ISA equivalence matrix lives in
    // quant::simd; here: direct kernel sanity on hand-computed cases.
    #[test]
    fn known_1d_case_matches_algorithm2() {
        // eb = 0.5 -> prequant = round(x); pad 0
        // data [1,2,4,4]: dq = [1,2,4,4]; preds [0,1,2,4]; deltas [1,1,2,0]
        let shape = BlockShape::new(1, 4);
        let cfg = DqConfig::new(0.5, 512, shape);
        let blocks = vec![1.0f32, 2.0, 4.0, 4.0];
        for isa in Isa::available() {
            let mut codes = vec![0u16; 4];
            let mut outv = vec![0.0f32; 4];
            run_fused(isa, 8, &cfg, &blocks, 0, &zero_pads(1), &mut codes, &mut outv);
            assert_eq!(codes, vec![513, 513, 514, 512], "isa {}", isa.name());
            assert_eq!(outv, vec![0.0; 4]);
        }
    }

    #[test]
    fn unavailable_isa_and_giant_radius_fall_back() {
        let shape = BlockShape::new(1, 4);
        let blocks = vec![1.0f32, 2.0, 4.0, 4.0];
        // forcing an ISA the host may lack must still produce the answer
        let mut codes = vec![0u16; 4];
        let mut outv = vec![0.0f32; 4];
        let cfg = DqConfig::new(0.5, 512, shape);
        run_fused(Isa::Avx512, 16, &cfg, &blocks, 0, &zero_pads(1), &mut codes, &mut outv);
        assert_eq!(codes, vec![513, 513, 514, 512]);
        // radius beyond the vector-exact range routes to the scalar path
        let cfg = DqConfig::new(0.5, 40_000, shape);
        let mut c2 = vec![0u16; 4];
        run_fused(Isa::detect_best(), 8, &cfg, &blocks, 0, &zero_pads(1), &mut c2, &mut outv);
        assert_eq!(c2, vec![40_001, 40_001, 40_002, 40_000]);
    }
}

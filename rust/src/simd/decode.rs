//! The SIMD reverse-Lorenzo (decode) wavefront kernel.
//!
//! Decompression reconstructs each element from its *already reconstructed*
//! neighbours, so the forward kernel's trick — rows are independent because
//! prediction reads pre-quantized values — does not apply: in 2D the
//! recurrence `dq[i][j] = (w + n) - nw + delta` depends on the same row
//! (west) **and** the previous row (north). What is dependency-free is the
//! **anti-diagonal wavefront**: every cell on `i + j = d` depends only on
//! cells of diagonals `d-1` and `d-2`, so all of them can be reconstructed
//! in parallel lanes. In 3D, planes are processed in order (the up-plane is
//! then fully reconstructed) and the same 2D wavefront sweeps each plane,
//! with four extra neighbour loads from the previous plane. 1D has a true
//! west prefix dependency and stays scalar on every ISA, as the paper notes
//! for the reverse scan (§III-A).
//!
//! # Skewed storage
//!
//! Cells of one diagonal are `bs - 1` apart in row-major order — a
//! gather/scatter pattern AVX2/NEON cannot store efficiently. The kernel
//! therefore runs on a **skewed layout**: one buffer of `bs + 2` slots per
//! diagonal (`slot(i) = i + 1`), so every neighbour read becomes a
//! contiguous unaligned vector load:
//!
//! * `w  = (i, j-1)`  → diagonal `d-1`, slot `i+1`
//! * `n  = (i-1, j)`  → diagonal `d-1`, slot `i`
//! * `nw = (i-1, j-1)` → diagonal `d-2`, slot `i`
//! * `u/wu/nu/nwu` → the up-plane's diagonals `d / d-1 / d-1 / d-2` at the
//!   same slots.
//!
//! Slot 0 of every diagonal holds the row-halo padding scalar, slot `d+2`
//! the column-halo scalar, and two *virtual* diagonals (`d = -1, -2`) in
//! front carry the halo values the first cells read — the same
//! broadcast-halo substitution `kernel::run_fused` uses forward, so the
//! unified per-cell expression never branches on borders. A scalar prologue
//! skews the code/outlier streams into `(addend, substitute, flag)` arrays
//! (performing the only int→f32 conversions, so the vector path needs no
//! radius cap), and a scalar epilogue de-skews and applies the final
//! `dq * twice_eb` scale.
//!
//! # Bit-exactness
//!
//! Every cell computes exactly the scalar reference's f32 sequence: halo
//! values from the same `fill_halo` precedence (highest axis wins shared
//! cells), `predict_halo`'s operation order `(w+n+u)-(nw+nu+wu)+nwu`, the
//! same `(code as i32 - radius) as f32` delta, and the same final scale.
//! Outlier substitution is mask+select on the pre-computed flag, matching
//! the reference's branch. Lane partitioning cannot change per-cell order,
//! so output is bit-identical to `decode_block_dualquant` /
//! `decode_block_sz14` on every ISA — enforced by the matrix in
//! `quant::decode`.

#[cfg(target_arch = "x86_64")]
use super::lanes::Avx2Lane;
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
use super::lanes::Avx512Lane;
#[cfg(target_arch = "aarch64")]
use super::lanes::NeonLane;
use super::lanes::{LaneF32, ScalarLane};
use super::Isa;
use crate::padding::PadScalars;
use crate::quant::{prequant, CodesKind, DqConfig, OUTLIER_CODE};

/// Run the reverse-Lorenzo wavefront kernel over a gathered-block batch on
/// `isa`. `codes`/`outv` hold `nb = codes.len() / shape.elems()` blocks
/// back-to-back (the `PqBackend::run` output layout); `out` receives the
/// reconstructed data-unit values in the same layout; `block_base` is the
/// global index of the first block (padding scalars are indexed globally).
///
/// Safe for any arguments: an unavailable `isa` falls back to the best
/// detected one. Unlike the forward kernel there is no radius cap — the
/// vector path performs no int↔f32 conversions (the scalar prologue does
/// them with the reference's exact casts).
#[allow(clippy::too_many_arguments)]
pub fn run_reverse(
    isa: Isa,
    width: usize,
    kind: CodesKind,
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    block_base: usize,
    pads: &PadScalars,
    out: &mut [f32],
) {
    assert!(matches!(width, 4 | 8 | 16), "supported lane widths: 4, 8, 16");
    let isa = if isa.is_available() { isa } else { Isa::detect_best() };
    // a width narrower than the native register cannot fill one vector;
    // drop to the widest ISA whose register fits (same rule as run_fused)
    let isa = match isa {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        Isa::Avx512 if width < 16 => Isa::Avx2,
        Isa::Avx2 if width < 8 => Isa::Scalar,
        Isa::Neon if width < 4 => Isa::Scalar,
        other => other,
    };
    match isa {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        // SAFETY: AVX-512F availability was checked by `is_available`
        Isa::Avx512 => unsafe { batch_avx512(kind, cfg, codes, outv, block_base, pads, out) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: AVX2 availability was checked by `is_available`
        Isa::Avx2 => unsafe { batch_avx2(kind, cfg, codes, outv, block_base, pads, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally guaranteed on aarch64
        Isa::Neon => unsafe {
            batch_rev::<NeonLane>(kind, cfg, codes, outv, block_base, pads, out)
        },
        // SAFETY: the scalar lane type has no CPU or alignment
        // requirements; all pointer arithmetic is bounds-derived
        _ => unsafe { batch_rev::<ScalarLane>(kind, cfg, codes, outv, block_base, pads, out) },
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn batch_avx2(
    kind: CodesKind,
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    block_base: usize,
    pads: &PadScalars,
    out: &mut [f32],
) {
    batch_rev::<Avx2Lane>(kind, cfg, codes, outv, block_base, pads, out)
}

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
#[target_feature(enable = "avx512f")]
unsafe fn batch_avx512(
    kind: CodesKind,
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    block_base: usize,
    pads: &PadScalars,
    out: &mut [f32],
) {
    batch_rev::<Avx512Lane>(kind, cfg, codes, outv, block_base, pads, out)
}

/// Scratch geometry of one skewed plane: `ndiag + 2` diagonal buffers
/// (two leading virtual ones) of `stride = bs + 2` slots each.
#[derive(Clone, Copy)]
struct Skew {
    bs: usize,
    stride: usize,
    ndiag: usize,
}

impl Skew {
    fn new(bs: usize) -> Self {
        Self { bs, stride: bs + 2, ndiag: 2 * bs - 1 }
    }

    fn plane_len(&self) -> usize {
        (self.ndiag + 2) * self.stride
    }

    /// Skewed position of cell `(i, j)`: diagonal `i + j`, slot `i + 1`
    /// (diagonal buffers are shifted by the two virtual ones).
    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> usize {
        (i + j + 2) * self.stride + i + 1
    }
}

/// Pre-fill one plane's halo slots: slot 0 of every diagonal carries the
/// row-halo scalar, slot `d + 2` of diagonal `d` (for `d <= bs - 2`, plus
/// the virtual `d = -1`) the column-halo scalar, and the virtual `d = -2`
/// buffer's slot 0 the shared corner — which `fill_halo`'s ascending-axis
/// write order resolves to the column scalar in 2D and 3D alike.
fn fill_plane_halos(plane: &mut [f32], sk: Skew, rowh: f32, colh: f32) {
    for db in 0..sk.ndiag + 2 {
        plane[db * sk.stride] = rowh;
    }
    plane[0] = colh; // virtual diagonal -2, slot 0: the corner
    for db in 1..=sk.bs {
        // diagonals d = -1 ..= bs-2 (shifted: db = d + 2), slot d + 2 = db
        plane[db * sk.stride + db] = colh;
    }
}

/// Skew one plane's code/outlier streams into `(addend, substitute, flag)`
/// — the only int→f32 conversions of the decode, done with the scalar
/// reference's exact casts: `(code as i32 - radius) as f32`, times
/// `twice_eb` for SZ-1.4 (which cascades in data units).
#[allow(clippy::too_many_arguments)]
fn skew_inputs(
    kind: CodesKind,
    codes: &[u16],
    outv: &[f32],
    radius: i32,
    twice_eb: f32,
    sk: Skew,
    askew: &mut [f32],
    sskew: &mut [f32],
    fskew: &mut [f32],
) {
    let bs = sk.bs;
    for i in 0..bs {
        for j in 0..bs {
            let l = i * bs + j;
            let p = sk.at(i, j);
            if codes[l] == OUTLIER_CODE {
                askew[p] = 0.0;
                sskew[p] = outv[l];
                fskew[p] = 1.0;
            } else {
                let a = (codes[l] as i32 - radius) as f32;
                askew[p] = match kind {
                    CodesKind::DualQuant => a,
                    CodesKind::Sz14 => a * twice_eb,
                };
                sskew[p] = 0.0;
                fskew[p] = 0.0;
            }
        }
    }
}

/// One wavefront sweep over a skewed plane. `up` is the previous plane's
/// skewed buffer (3D) or `None` (2D — the up/nu/wu/nwu terms vanish).
///
/// # Safety
/// `V`'s ISA must be executable on the current CPU; all buffers must have
/// `sk.plane_len()` elements.
#[inline(always)]
unsafe fn wave_plane<V: LaneF32>(
    cur: &mut [f32],
    up: Option<&[f32]>,
    askew: &[f32],
    sskew: &[f32],
    fskew: &[f32],
    sk: Skew,
) {
    let bs = sk.bs;
    let stride = sk.stride;
    let half = V::splat(0.5);
    let cp = cur.as_mut_ptr();
    let ap = askew.as_ptr();
    let sp = sskew.as_ptr();
    let fp = fskew.as_ptr();
    for d in 0..sk.ndiag {
        let lo = d.saturating_sub(bs - 1);
        let hi = d.min(bs - 1);
        let cb = (d + 2) * stride;
        let p1 = cb - stride;
        let p2 = cb - 2 * stride;
        let mut i = lo;
        // vector body: all lanes of a diagonal are independent (their
        // neighbours live on diagonals d-1/d-2, already reconstructed)
        while i + V::LANES <= hi + 1 {
            let w = V::load(cp.add(p1 + i + 1));
            let n = V::load(cp.add(p1 + i));
            let nw = V::load(cp.add(p2 + i));
            // predict_halo order: 2D (w + n) - nw;
            // 3D (w + n + u) - (nw + nu + wu) + nwu
            let pred = match up {
                None => w.add(n).sub(nw),
                Some(u) => {
                    let upb = u.as_ptr();
                    w.add(n)
                        .add(V::load(upb.add(cb + i + 1)))
                        .sub(nw.add(V::load(upb.add(p1 + i))).add(V::load(upb.add(p1 + i + 1))))
                        .add(V::load(upb.add(p2 + i)))
                }
            };
            let t = pred.add(V::load(ap.add(cb + i + 1)));
            let m = V::load(fp.add(cb + i + 1)).lt(half);
            V::select(m, t, V::load(sp.add(cb + i + 1))).store(cp.add(cb + i + 1));
            i += V::LANES;
        }
        // scalar tail — same per-cell expression, plain Rust f32 ops
        while i <= hi {
            let w = *cp.add(p1 + i + 1);
            let n = *cp.add(p1 + i);
            let nw = *cp.add(p2 + i);
            let pred = match up {
                None => (w + n) - nw,
                Some(u) => {
                    ((w + n) + u[cb + i + 1]) - ((nw + u[p1 + i]) + u[p1 + i + 1]) + u[p2 + i]
                }
            };
            let t = pred + askew[cb + i + 1];
            let dq = if fskew[cb + i + 1] < 0.5 { t } else { sskew[cb + i + 1] };
            *cp.add(cb + i + 1) = dq;
            i += 1;
        }
    }
}

/// The generic reverse batch: scalar prologue (skew), wavefront sweep(s),
/// scalar epilogue (de-skew + final scale). 1D takes the sequential
/// cascade — the west recurrence is a true prefix dependency.
///
/// # Safety
/// `V`'s ISA must be executable on the current CPU.
///
/// `inline(always)` collapses the batch into its `#[target_feature]` entry
/// point so the lane wrappers fold into a feature-enabled context (same
/// rationale as the forward kernel).
#[inline(always)]
unsafe fn batch_rev<V: LaneF32>(
    kind: CodesKind,
    cfg: &DqConfig,
    codes: &[u16],
    outv: &[f32],
    block_base: usize,
    pads: &PadScalars,
    out: &mut [f32],
) {
    let shape = cfg.shape;
    let elems = shape.elems();
    let bs = shape.bs;
    assert_eq!(codes.len() % elems, 0, "codes not a whole number of blocks");
    let nb = codes.len() / elems;
    assert_eq!(outv.len(), nb * elems);
    assert_eq!(out.len(), nb * elems);
    let radius = cfg.radius as i32;
    let twice_eb = cfg.twice_eb();
    let hie = cfg.half_inv_eb();
    // halo scalars enter the cascade pre-quantized for dual-quant (the
    // cascade runs in the prequant domain) and verbatim for SZ-1.4
    let pad = |gb: usize, axis: usize| match kind {
        CodesKind::DualQuant => prequant(pads.edge_scalar(gb, axis), hie),
        CodesKind::Sz14 => pads.edge_scalar(gb, axis),
    };
    // final per-element transform back to data units
    let finish = |dq: f32| match kind {
        CodesKind::DualQuant => dq * twice_eb,
        CodesKind::Sz14 => dq,
    };

    if shape.ndim == 1 {
        for b in 0..nb {
            let bc = &codes[b * elems..(b + 1) * elems];
            let bv = &outv[b * elems..(b + 1) * elems];
            let bo = &mut out[b * elems..(b + 1) * elems];
            let mut prev = pad(block_base + b, 0);
            for l in 0..bs {
                let v = if bc[l] == OUTLIER_CODE {
                    bv[l]
                } else {
                    let a = (bc[l] as i32 - radius) as f32;
                    match kind {
                        CodesKind::DualQuant => prev + a,
                        CodesKind::Sz14 => prev + a * twice_eb,
                    }
                };
                prev = v;
                bo[l] = finish(v);
            }
        }
        return;
    }

    let sk = Skew::new(bs);
    let psz = sk.plane_len();
    let mut askew = vec![0.0f32; psz];
    let mut sskew = vec![0.0f32; psz];
    let mut fskew = vec![0.0f32; psz];
    let mut cur = vec![0.0f32; psz];
    let mut up = if shape.ndim == 3 { vec![0.0f32; psz] } else { Vec::new() };
    let plane_elems = bs * bs;

    for b in 0..nb {
        let gb = block_base + b;
        let bc = &codes[b * elems..(b + 1) * elems];
        let bv = &outv[b * elems..(b + 1) * elems];
        let bo = &mut out[b * elems..(b + 1) * elems];
        if shape.ndim == 2 {
            // halo precedence: row halo = axis 0, column halo (and the
            // corner, written last by fill_halo) = axis 1
            fill_plane_halos(&mut cur, sk, pad(gb, 0), pad(gb, 1));
            skew_inputs(kind, bc, bv, radius, twice_eb, sk, &mut askew, &mut sskew, &mut fskew);
            wave_plane::<V>(&mut cur, None, &askew, &sskew, &fskew, sk);
            for i in 0..bs {
                for j in 0..bs {
                    bo[i * bs + j] = finish(cur[sk.at(i, j)]);
                }
            }
        } else {
            // 3D halo precedence (fill order axis0 -> axis1 -> axis2):
            // in-plane row halo = axis 1, column halo + corner = axis 2;
            // the k = 0 up-plane is the axis-0 halo plane, whose own row/
            // column borders resolve to axis 1/2 by the same write order
            let (p1, p2) = (pad(gb, 1), pad(gb, 2));
            up.fill(pad(gb, 0));
            fill_plane_halos(&mut up, sk, p1, p2);
            fill_plane_halos(&mut cur, sk, p1, p2);
            for k in 0..bs {
                let pc = &bc[k * plane_elems..(k + 1) * plane_elems];
                let pv = &bv[k * plane_elems..(k + 1) * plane_elems];
                skew_inputs(
                    kind, pc, pv, radius, twice_eb, sk, &mut askew, &mut sskew, &mut fskew,
                );
                wave_plane::<V>(&mut cur, Some(up.as_slice()), &askew, &sskew, &fskew, sk);
                let po = &mut bo[k * plane_elems..(k + 1) * plane_elems];
                for i in 0..bs {
                    for j in 0..bs {
                        po[i * bs + j] = finish(cur[sk.at(i, j)]);
                    }
                }
                // the finished plane becomes the up-plane; halo slots of
                // both buffers are constants, filled once above
                std::mem::swap(&mut up, &mut cur);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::padding::{PadGranularity, PadValue, PaddingPolicy};

    fn zero_pads(ndim: usize) -> PadScalars {
        PadScalars {
            policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
            scalars: vec![0.0],
            ndim,
        }
    }

    // The cross-backend / cross-ISA equivalence matrix lives in
    // quant::decode; here: direct kernel sanity on hand-computed cases.
    #[test]
    fn known_1d_case_reverses_algorithm2() {
        // the forward known case: data [1,2,4,4] @ eb 0.5, pad 0 encodes to
        // codes [513, 513, 514, 512] (radius 512); reverse must return the
        // rounded originals
        let shape = BlockShape::new(1, 4);
        let cfg = DqConfig::new(0.5, 512, shape);
        let codes = vec![513u16, 513, 514, 512];
        let outv = vec![0.0f32; 4];
        for isa in Isa::available() {
            let mut out = vec![0.0f32; 4];
            run_reverse(
                isa,
                8,
                CodesKind::DualQuant,
                &cfg,
                &codes,
                &outv,
                0,
                &zero_pads(1),
                &mut out,
            );
            assert_eq!(out, vec![1.0, 2.0, 4.0, 4.0], "isa {}", isa.name());
        }
    }

    #[test]
    fn known_2d_case_with_outlier() {
        // 2x2 block, eb 0.5 (twice_eb = 1, prequant = round), zero pads,
        // radius 4. codes [5, 4, OUT, 6], outlier value 9:
        //   (0,0): pred = 0        -> dq = 1
        //   (0,1): pred = w=1      -> dq = 1
        //   (1,0): outlier         -> dq = 9
        //   (1,1): pred = 9+1-1=9  -> dq = 11
        let shape = BlockShape::new(2, 2);
        let cfg = DqConfig::new(0.5, 4, shape);
        let codes = vec![5u16, 4, OUTLIER_CODE, 6];
        let outv = vec![0.0f32, 0.0, 9.0, 0.0];
        for isa in Isa::available() {
            let mut out = vec![0.0f32; 4];
            run_reverse(
                isa,
                16,
                CodesKind::DualQuant,
                &cfg,
                &codes,
                &outv,
                0,
                &zero_pads(2),
                &mut out,
            );
            assert_eq!(out, vec![1.0, 1.0, 9.0, 11.0], "isa {}", isa.name());
        }
    }

    #[test]
    fn unavailable_isa_falls_back() {
        let shape = BlockShape::new(1, 4);
        let cfg = DqConfig::new(0.5, 512, shape);
        let codes = vec![513u16, 513, 514, 512];
        let outv = vec![0.0f32; 4];
        let mut out = vec![0.0f32; 4];
        // forcing an ISA the host may lack must still produce the answer
        run_reverse(
            Isa::Avx512,
            16,
            CodesKind::DualQuant,
            &cfg,
            &codes,
            &outv,
            0,
            &zero_pads(1),
            &mut out,
        );
        assert_eq!(out, vec![1.0, 2.0, 4.0, 4.0]);
    }

    #[test]
    fn giant_radius_is_fine_without_a_cap() {
        // decode performs no vector int conversions, so radii beyond the
        // forward kernel's MAX_VECTOR_RADIUS need no scalar rerouting
        let shape = BlockShape::new(2, 4);
        let cfg = DqConfig::new(0.5, 40_000, shape);
        let codes = vec![40_001u16; 16];
        let outv = vec![0.0f32; 16];
        let mut expect = vec![0.0f32; 16];
        run_reverse(
            Isa::Scalar,
            8,
            CodesKind::DualQuant,
            &cfg,
            &codes,
            &outv,
            0,
            &zero_pads(2),
            &mut expect,
        );
        for isa in Isa::available() {
            let mut out = vec![0.0f32; 16];
            run_reverse(
                isa,
                8,
                CodesKind::DualQuant,
                &cfg,
                &codes,
                &outv,
                0,
                &zero_pads(2),
                &mut out,
            );
            assert_eq!(out, expect, "isa {}", isa.name());
        }
    }
}

//! The `f32 × W` lane abstraction behind the fused kernel.
//!
//! One trait, four implementations: scalar (always), AVX2 and AVX-512F
//! (x86-64, the latter behind the `avx512` cargo feature), NEON (aarch64).
//! Every operation is a single IEEE-754 f32 instruction applied lane-wise,
//! so any lane partitioning of the same element stream produces bit-equal
//! results — the property the cross-ISA equivalence tests in `quant`
//! enforce.
//!
//! Two semantic pins keep the vector paths equal to the scalar reference:
//!
//! * [`round_ne`](LaneF32::round_ne) is round-to-nearest-ties-even
//!   (`vroundps`/`vrndscaleps` imm 0x08, `frintn`), matching
//!   `f32::round_ties_even` in `quant::prequant`.
//! * [`store_codes`](LaneF32::store_codes) converts with **truncation
//!   toward zero** (`vcvttps2dq`, `fcvtzs`), matching Rust's `as i32`
//!   cast, then narrows to u16. The kernel only feeds it values in
//!   `[0, 2·radius)` with `radius <= MAX_VECTOR_RADIUS`, where truncating
//!   and saturating narrows agree — the dispatcher routes larger radii to
//!   the scalar path, whose Rust casts match `VecBackend` for any radius.

/// Largest quantization radius the vector paths handle: in-cap codes stay
/// `< 2·radius <= 65534`, inside exact-u16-narrowing range.
pub const MAX_VECTOR_RADIUS: u16 = 32767;

/// `W` f32 lanes plus the element-wise ops the fused dual-quant kernel
/// needs. All methods are `unsafe`: the caller must guarantee (a) the CPU
/// supports the implementing ISA and (b) pointers cover `LANES` elements.
pub trait LaneF32: Copy {
    /// Lanes per vector.
    const LANES: usize;
    /// Comparison-result type consumed by [`select`](Self::select).
    type Mask: Copy;

    unsafe fn splat(x: f32) -> Self;
    unsafe fn load(p: *const f32) -> Self;
    unsafe fn store(self, p: *mut f32);
    unsafe fn add(self, o: Self) -> Self;
    unsafe fn sub(self, o: Self) -> Self;
    unsafe fn mul(self, o: Self) -> Self;
    /// Round to nearest integer, ties to even (exactly
    /// `f32::round_ties_even` per lane).
    unsafe fn round_ne(self) -> Self;
    unsafe fn abs(self) -> Self;
    /// Lane-wise `self < o` (ordered: NaN compares false, like Rust `<`).
    unsafe fn lt(self, o: Self) -> Self::Mask;
    /// Lane-wise `if m { a } else { b }`.
    unsafe fn select(m: Self::Mask, a: Self, b: Self) -> Self;
    /// Truncate lanes toward zero to i32, narrow to u16 and store `LANES`
    /// codes at `p`. Exact for lane values in `[0, 65534)`.
    unsafe fn store_codes(self, p: *mut u16);
}

/// Scalar fallback: one lane, plain Rust float ops. Safe in substance (the
/// `unsafe` is only the trait contract); bit-identical to `VecBackend`'s
/// per-element math for **every** input including out-of-range radii,
/// which is why the dispatcher routes `radius > MAX_VECTOR_RADIUS` here.
#[derive(Clone, Copy)]
pub struct ScalarLane(pub f32);

impl LaneF32 for ScalarLane {
    const LANES: usize = 1;
    type Mask = bool;

    #[inline(always)]
    unsafe fn splat(x: f32) -> Self {
        ScalarLane(x)
    }
    #[inline(always)]
    unsafe fn load(p: *const f32) -> Self {
        // SAFETY: caller guarantees p is valid for 1 read
        ScalarLane(*p)
    }
    #[inline(always)]
    unsafe fn store(self, p: *mut f32) {
        // SAFETY: caller guarantees p is valid for 1 write
        *p = self.0;
    }
    #[inline(always)]
    unsafe fn add(self, o: Self) -> Self {
        ScalarLane(self.0 + o.0)
    }
    #[inline(always)]
    unsafe fn sub(self, o: Self) -> Self {
        ScalarLane(self.0 - o.0)
    }
    #[inline(always)]
    unsafe fn mul(self, o: Self) -> Self {
        ScalarLane(self.0 * o.0)
    }
    #[inline(always)]
    unsafe fn round_ne(self) -> Self {
        ScalarLane(self.0.round_ties_even())
    }
    #[inline(always)]
    unsafe fn abs(self) -> Self {
        ScalarLane(self.0.abs())
    }
    #[inline(always)]
    unsafe fn lt(self, o: Self) -> bool {
        self.0 < o.0
    }
    #[inline(always)]
    unsafe fn select(m: bool, a: Self, b: Self) -> Self {
        if m {
            a
        } else {
            b
        }
    }
    #[inline(always)]
    unsafe fn store_codes(self, p: *mut u16) {
        // Rust's saturating f32 -> i32 cast, then u16 truncation: the
        // scalar reference semantics the vector paths must agree with on
        // their (bounded) domain.
        // SAFETY: caller guarantees p is valid for 1 write
        *p = self.0 as i32 as u16;
    }
}

#[cfg(target_arch = "x86_64")]
pub use x86::Avx2Lane;
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
pub use x86::Avx512Lane;

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::LaneF32;
    use std::arch::x86_64::*;

    /// 8 × f32 in a ymm register.
    ///
    /// SAFETY contract for every method: the caller runs on a CPU with
    /// AVX2 (the dispatcher checks `is_x86_feature_detected!("avx2")`
    /// before selecting this type) and pointer args cover 8 elements.
    /// Loads/stores use the unaligned forms, so no alignment is required.
    #[derive(Clone, Copy)]
    pub struct Avx2Lane(__m256);

    impl LaneF32 for Avx2Lane {
        const LANES: usize = 8;
        type Mask = __m256;

        #[inline(always)]
        unsafe fn splat(x: f32) -> Self {
            Avx2Lane(_mm256_set1_ps(x))
        }
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            Avx2Lane(_mm256_loadu_ps(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            _mm256_storeu_ps(p, self.0)
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            Avx2Lane(_mm256_add_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn sub(self, o: Self) -> Self {
            Avx2Lane(_mm256_sub_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            Avx2Lane(_mm256_mul_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn round_ne(self) -> Self {
            // 0x08 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC:
            // ties-to-even, identical to f32::round_ties_even
            Avx2Lane(_mm256_round_ps::<0x08>(self.0))
        }
        #[inline(always)]
        unsafe fn abs(self) -> Self {
            // clear the sign bit; |NaN| stays NaN, matching f32::abs
            Avx2Lane(_mm256_andnot_ps(_mm256_set1_ps(-0.0), self.0))
        }
        #[inline(always)]
        unsafe fn lt(self, o: Self) -> __m256 {
            // ordered-quiet <: NaN lanes compare false, like Rust `<`
            _mm256_cmp_ps::<_CMP_LT_OQ>(self.0, o.0)
        }
        #[inline(always)]
        unsafe fn select(m: __m256, a: Self, b: Self) -> Self {
            // blendv picks `a` where the mask lane's sign bit is set
            Avx2Lane(_mm256_blendv_ps(b.0, a.0, m))
        }
        #[inline(always)]
        unsafe fn store_codes(self, p: *mut u16) {
            // vcvttps2dq truncates toward zero (Rust `as i32` semantics on
            // the kernel's bounded domain), then a 4+4 unsigned-saturating
            // pack narrows to 8 in-order u16 — exact for values < 65534.
            let i = _mm256_cvttps_epi32(self.0);
            let lo = _mm256_castsi256_si128(i);
            let hi = _mm256_extracti128_si256::<1>(i);
            _mm_storeu_si128(p as *mut __m128i, _mm_packus_epi32(lo, hi));
        }
    }

    /// 16 × f32 in a zmm register (`avx512` cargo feature; needs
    /// rustc >= 1.89 for stable AVX-512 intrinsics).
    ///
    /// SAFETY contract: CPU has AVX-512F (dispatcher-checked) and pointer
    /// args cover 16 elements; unaligned forms throughout.
    #[cfg(feature = "avx512")]
    #[derive(Clone, Copy)]
    pub struct Avx512Lane(__m512);

    #[cfg(feature = "avx512")]
    impl LaneF32 for Avx512Lane {
        const LANES: usize = 16;
        type Mask = __mmask16;

        #[inline(always)]
        unsafe fn splat(x: f32) -> Self {
            Avx512Lane(_mm512_set1_ps(x))
        }
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            Avx512Lane(_mm512_loadu_ps(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            _mm512_storeu_ps(p, self.0)
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            Avx512Lane(_mm512_add_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn sub(self, o: Self) -> Self {
            Avx512Lane(_mm512_sub_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            Avx512Lane(_mm512_mul_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn round_ne(self) -> Self {
            // vrndscaleps imm 0x08: scale 0, suppress exceptions,
            // round-to-nearest-even — identical to f32::round_ties_even
            Avx512Lane(_mm512_roundscale_ps::<0x08>(self.0))
        }
        #[inline(always)]
        unsafe fn abs(self) -> Self {
            Avx512Lane(_mm512_abs_ps(self.0))
        }
        #[inline(always)]
        unsafe fn lt(self, o: Self) -> __mmask16 {
            _mm512_cmp_ps_mask::<_CMP_LT_OQ>(self.0, o.0)
        }
        #[inline(always)]
        unsafe fn select(m: __mmask16, a: Self, b: Self) -> Self {
            Avx512Lane(_mm512_mask_blend_ps(m, b.0, a.0))
        }
        #[inline(always)]
        unsafe fn store_codes(self, p: *mut u16) {
            // vcvttps2dq truncation, then vpmovdw (plain low-16 narrowing,
            // exact on the kernel's [0, 65534) domain)
            let i = _mm512_cvttps_epi32(self.0);
            _mm256_storeu_si256(p as *mut __m256i, _mm512_cvtepi32_epi16(i));
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub use arm::NeonLane;

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::LaneF32;
    use std::arch::aarch64::*;

    /// 4 × f32 in a NEON q register.
    ///
    /// SAFETY contract: NEON is architecturally guaranteed on aarch64;
    /// pointer args cover 4 elements (NEON loads/stores are unaligned).
    #[derive(Clone, Copy)]
    pub struct NeonLane(float32x4_t);

    impl LaneF32 for NeonLane {
        const LANES: usize = 4;
        type Mask = uint32x4_t;

        #[inline(always)]
        unsafe fn splat(x: f32) -> Self {
            NeonLane(vdupq_n_f32(x))
        }
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            NeonLane(vld1q_f32(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            vst1q_f32(p, self.0)
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            NeonLane(vaddq_f32(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn sub(self, o: Self) -> Self {
            NeonLane(vsubq_f32(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            NeonLane(vmulq_f32(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn round_ne(self) -> Self {
            // frintn: round to nearest, ties to even
            NeonLane(vrndnq_f32(self.0))
        }
        #[inline(always)]
        unsafe fn abs(self) -> Self {
            NeonLane(vabsq_f32(self.0))
        }
        #[inline(always)]
        unsafe fn lt(self, o: Self) -> uint32x4_t {
            // fcmgt(o, self): NaN operands yield all-zero lanes (false)
            vcltq_f32(self.0, o.0)
        }
        #[inline(always)]
        unsafe fn select(m: uint32x4_t, a: Self, b: Self) -> Self {
            NeonLane(vbslq_f32(m, a.0, b.0))
        }
        #[inline(always)]
        unsafe fn store_codes(self, p: *mut u16) {
            // fcvtzs truncates toward zero; sqxtun (signed -> unsigned
            // saturating narrow) is exact on the kernel's [0, 65534) domain
            let i = vcvtq_s32_f32(self.0);
            vst1_u16(p, vqmovun_s32(i));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Scalar-lane semantics are the reference everything else is compared
    // against (the cross-ISA comparisons live in quant::simd's matrix).
    #[test]
    fn scalar_lane_matches_rust_ops() {
        unsafe {
            let a = ScalarLane::splat(2.5);
            assert_eq!(a.round_ne().0, 2.0); // ties to even
            assert_eq!(ScalarLane::splat(3.5).round_ne().0, 4.0);
            assert_eq!(ScalarLane::splat(-1.75).abs().0, 1.75);
            assert!(ScalarLane::splat(1.0).lt(ScalarLane::splat(2.0)));
            assert!(!ScalarLane::splat(f32::NAN).lt(ScalarLane::splat(2.0)));
            let mut c = 0u16;
            ScalarLane::splat(513.9).store_codes(&mut c);
            assert_eq!(c, 513); // truncation toward zero
            ScalarLane::splat(0.0).store_codes(&mut c);
            assert_eq!(c, 0);
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_lane_matches_scalar_reference() {
        if !crate::simd::Isa::Avx2.is_available() {
            return;
        }
        // SAFETY: AVX2 presence checked above; buffers sized for 8 lanes
        unsafe { avx2_case() }
    }

    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn avx2_case() {
        let xs: [f32; 8] = [0.5, 1.5, 2.5, -2.5, 1023.49, -0.49, 65533.4, 7.0];
        let v = Avx2Lane::load(xs.as_ptr());
        let mut rounded = [0.0f32; 8];
        v.round_ne().store(rounded.as_mut_ptr());
        for (x, r) in xs.iter().zip(rounded) {
            assert_eq!(r, x.round_ties_even(), "round_ne({x})");
        }
        let mut codes = [0u16; 8];
        // only non-negative in-range values reach store_codes in the kernel
        let pos: [f32; 8] = [0.0, 1.9, 2.0, 513.7, 1023.0, 65533.0, 12.3, 8.5];
        Avx2Lane::load(pos.as_ptr()).store_codes(codes.as_mut_ptr());
        for (x, c) in pos.iter().zip(codes) {
            assert_eq!(c, *x as i32 as u16, "store_codes({x})");
        }
        let m = Avx2Lane::load(xs.as_ptr()).abs().lt(Avx2Lane::splat(3.0));
        let sel = Avx2Lane::select(m, Avx2Lane::splat(1.0), Avx2Lane::splat(0.0));
        let mut out = [0.0f32; 8];
        sel.store(out.as_mut_ptr());
        for (x, o) in xs.iter().zip(out) {
            assert_eq!(o, if x.abs() < 3.0 { 1.0 } else { 0.0 }, "select({x})");
        }
    }
}

//! `BENCH_*.json` regression gate — the CI `compare-bench` step.
//!
//! The bench targets (`micro_substrates`, `stream_access`,
//! `serve_roundtrip`) emit machine-readable throughput rows; CI diffs a
//! fresh run against the baselines committed under `ci/bench-baselines/`
//! and fails the job when any matched row lost more than the tolerated
//! fraction of throughput. Rows are matched by `(op, format, threads)`;
//! rows present on only one side are reported but never fail the gate
//! (new benchmarks must be able to land before their baseline exists, and
//! baselines must survive a renamed row without blocking CI). The
//! complementary [`missing_required`] presence gate covers the hole that
//! leniency opens: CI names the row families that must exist in every
//! fresh run (the decode-kernel / decode-stage rows of `BENCH_pq.json`,
//! the serve rows of `BENCH_serve.json`), and the job fails if a bench
//! quietly stops emitting them.
//!
//! Both documents carry the runtime-dispatched SIMD `isa` (and the
//! compiled `target_features`) in their metadata. When the two sides
//! disagree — e.g. an AVX-512 baseline diffed on an SSE2 runner — the P&Q
//! numbers are incomparable, so the diff is still *reported* but the gate
//! is skipped with a warning instead of failing CI on a hardware change.

use crate::error::{Result, VszError};
use crate::util::json::{parse, Json};

/// One matched row of a baseline/fresh diff.
#[derive(Clone, Debug)]
pub struct RowDiff {
    pub key: String,
    pub base_mb_s: f64,
    pub fresh_mb_s: f64,
    /// Throughput change in percent (negative = slower than baseline).
    pub delta_pct: f64,
    pub regressed: bool,
}

/// Outcome of diffing one `BENCH_*.json` pair.
#[derive(Clone, Debug, Default)]
pub struct CompareReport {
    pub rows: Vec<RowDiff>,
    /// Row keys present in only one of the two documents.
    pub unmatched: Vec<String>,
    /// `Some((baseline, fresh))` when both documents record a SIMD ISA and
    /// they differ — the gate must warn-and-skip, not fail.
    pub isa_mismatch: Option<(String, String)>,
}

impl CompareReport {
    /// Rows past the tolerance. Empty whenever the two documents were
    /// measured on different ISAs (the numbers are incomparable).
    pub fn regressions(&self) -> impl Iterator<Item = &RowDiff> {
        let gated = self.isa_mismatch.is_none();
        self.rows.iter().filter(move |r| gated && r.regressed)
    }
}

/// Identity of a bench row: `op/format@threads` ("-" when a field is
/// absent — the stream bench has no `format` axis).
fn row_key(row: &Json) -> Option<String> {
    let op = row.get("op")?.as_str()?;
    let format = row.get("format").and_then(Json::as_str).unwrap_or("-");
    let threads = row.get("threads").and_then(Json::as_usize).unwrap_or(1);
    Some(format!("{op}/{format}@{threads}"))
}

fn rows_of(doc: &Json) -> Result<Vec<(String, f64)>> {
    let rows = doc
        .get("rows")
        .and_then(Json::as_array)
        .ok_or_else(|| VszError::format("bench json: missing 'rows' array"))?;
    let mut out = Vec::with_capacity(rows.len());
    for row in rows {
        let key =
            row_key(row).ok_or_else(|| VszError::format("bench json: row without an 'op'"))?;
        let mbs = row
            .get("mb_per_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| VszError::format(format!("bench json: row {key} has no mb_per_s")))?;
        out.push((key, mbs));
    }
    Ok(out)
}

/// Diff two bench documents. `tolerance_pct` is the throughput loss (in
/// percent of the baseline) beyond which a matched row counts as a
/// regression.
pub fn compare_docs(baseline: &Json, fresh: &Json, tolerance_pct: f64) -> Result<CompareReport> {
    let base_rows = rows_of(baseline)?;
    let fresh_rows = rows_of(fresh)?;
    let mut report = CompareReport::default();
    // both sides must have been measured on the same ISA for the gate to
    // mean anything; older documents without the field gate as before
    if let (Some(b), Some(f)) = (
        baseline.get("isa").and_then(Json::as_str),
        fresh.get("isa").and_then(Json::as_str),
    ) {
        if b != f {
            report.isa_mismatch = Some((b.to_string(), f.to_string()));
        }
    }
    for (key, fresh_mbs) in &fresh_rows {
        match base_rows.iter().find(|(k, _)| k == key) {
            Some((_, base_mbs)) if *base_mbs > 0.0 => {
                let delta_pct = (fresh_mbs - base_mbs) / base_mbs * 100.0;
                report.rows.push(RowDiff {
                    key: key.clone(),
                    base_mb_s: *base_mbs,
                    fresh_mb_s: *fresh_mbs,
                    delta_pct,
                    regressed: delta_pct < -tolerance_pct,
                });
            }
            _ => report.unmatched.push(key.clone()),
        }
    }
    for (key, _) in &base_rows {
        if !fresh_rows.iter().any(|(k, _)| k == key) {
            report.unmatched.push(format!("{key} (baseline only)"));
        }
    }
    Ok(report)
}

/// Diff two `BENCH_*.json` files on disk.
pub fn compare_files(baseline: &str, fresh: &str, tolerance_pct: f64) -> Result<CompareReport> {
    let b = parse(&std::fs::read_to_string(baseline)?)?;
    let f = parse(&std::fs::read_to_string(fresh)?)?;
    compare_docs(&b, &f, tolerance_pct)
}

/// Presence gate: every `required` prefix must match at least one row key
/// (`op/format@threads`) across the fresh documents. Returns the prefixes
/// with no match — unmatched-rows-never-fail makes the diff gate lenient
/// by design, so without this a bench that silently stops emitting its
/// rows (say the decode-kernel or serve rows) would pass CI forever;
/// `bench-compare --require` turns "these rows exist" into a hard check.
pub fn missing_required(fresh_docs: &[Json], required: &[String]) -> Result<Vec<String>> {
    let mut keys = Vec::new();
    for doc in fresh_docs {
        keys.extend(rows_of(doc)?.into_iter().map(|(k, _)| k));
    }
    Ok(required
        .iter()
        .filter(|req| !keys.iter().any(|k| k.starts_with(req.as_str())))
        .cloned()
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(rows: &str) -> Json {
        parse(&format!("{{\"workload\": \"t\", \"rows\": [{rows}]}}")).unwrap()
    }

    #[test]
    fn matched_rows_diff_and_gate() {
        let base = doc(
            r#"{"op":"decode","format":"huf2","threads":4,"mb_per_s":1000.0},
               {"op":"encode","format":"huf2","threads":4,"mb_per_s":500.0}"#,
        );
        let fresh = doc(
            r#"{"op":"decode","format":"huf2","threads":4,"mb_per_s":700.0},
               {"op":"encode","format":"huf2","threads":4,"mb_per_s":510.0}"#,
        );
        let r = compare_docs(&base, &fresh, 25.0).unwrap();
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.regressions().count(), 1);
        let reg = r.regressions().next().unwrap();
        assert_eq!(reg.key, "decode/huf2@4");
        assert!((reg.delta_pct - -30.0).abs() < 1e-9);
        // within tolerance: 30% loss passes a 35% gate
        let r = compare_docs(&base, &fresh, 35.0).unwrap();
        assert_eq!(r.regressions().count(), 0);
    }

    #[test]
    fn isa_mismatch_reports_but_never_gates() {
        let row = r#"{"op":"pq","format":"simd16","threads":1,"mb_per_s":1000.0}"#;
        let slow = r#"{"op":"pq","format":"simd16","threads":1,"mb_per_s":100.0}"#;
        let base = parse(&format!("{{\"isa\":\"avx512\",\"rows\":[{row}]}}")).unwrap();
        let fresh = parse(&format!("{{\"isa\":\"scalar\",\"rows\":[{slow}]}}")).unwrap();
        let r = compare_docs(&base, &fresh, 25.0).unwrap();
        assert_eq!(r.rows.len(), 1, "mismatched-ISA rows are still reported");
        assert!(r.rows[0].regressed, "the raw per-row flag is still computed");
        assert_eq!(r.regressions().count(), 0, "...but the gate skips them");
        assert_eq!(r.isa_mismatch, Some(("avx512".to_string(), "scalar".to_string())));
        // same ISA on both sides gates normally
        let fresh2 = parse(&format!("{{\"isa\":\"avx512\",\"rows\":[{slow}]}}")).unwrap();
        assert_eq!(compare_docs(&base, &fresh2, 25.0).unwrap().regressions().count(), 1);
        // docs predating the metadata (no "isa" field) gate normally too
        let old = parse(&format!("{{\"rows\":[{slow}]}}")).unwrap();
        assert_eq!(compare_docs(&base, &old, 25.0).unwrap().regressions().count(), 1);
    }

    #[test]
    fn unmatched_rows_never_fail() {
        let base = doc(r#"{"op":"old","threads":1,"mb_per_s":100.0}"#);
        let fresh = doc(r#"{"op":"new","threads":1,"mb_per_s":1.0}"#);
        let r = compare_docs(&base, &fresh, 25.0).unwrap();
        assert_eq!(r.rows.len(), 0);
        assert_eq!(r.regressions().count(), 0);
        assert_eq!(r.unmatched.len(), 2);
    }

    #[test]
    fn empty_baseline_is_all_unmatched() {
        // the committed first baseline has no rows (populated from CI
        // artifacts); the gate must pass until it is refreshed
        let base = doc("");
        let fresh = doc(r#"{"op":"decode","format":"huf2","threads":2,"mb_per_s":42.0}"#);
        let r = compare_docs(&base, &fresh, 25.0).unwrap();
        assert_eq!(r.regressions().count(), 0);
        assert_eq!(r.unmatched, vec!["decode/huf2@2".to_string()]);
    }

    #[test]
    fn missing_fields_are_format_errors() {
        assert!(compare_docs(&parse("{}").unwrap(), &doc(""), 25.0).is_err());
        let bad = doc(r#"{"format":"x","threads":1,"mb_per_s":1.0}"#);
        assert!(compare_docs(&bad, &doc(""), 25.0).is_err());
        let no_mbs = doc(r#"{"op":"x","threads":1}"#);
        assert!(compare_docs(&no_mbs, &doc(""), 25.0).is_err());
    }

    #[test]
    fn zero_baseline_rows_are_skipped_not_divided() {
        let base = doc(r#"{"op":"x","threads":1,"mb_per_s":0.0}"#);
        let fresh = doc(r#"{"op":"x","threads":1,"mb_per_s":5.0}"#);
        let r = compare_docs(&base, &fresh, 25.0).unwrap();
        assert_eq!(r.rows.len(), 0);
        assert_eq!(r.unmatched.len(), 1);
    }

    #[test]
    fn required_prefixes_match_across_documents() {
        let pq = doc(
            r#"{"op":"decode-kernel","format":"simd16","threads":1,"mb_per_s":9.0},
               {"op":"decode_stage","format":"v1","threads":4,"mb_per_s":9.0}"#,
        );
        let serve = doc(r#"{"op":"serve-compress","threads":1,"mb_per_s":9.0}"#);
        let req = |names: &[&str]| names.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        let missing = missing_required(
            &[pq.clone(), serve.clone()],
            &req(&["decode-kernel", "decode_stage", "serve-compress"]),
        )
        .unwrap();
        assert!(missing.is_empty(), "all present: {missing:?}");
        // a prefix covers every (format, threads) variant of the op
        assert!(missing_required(&[pq.clone()], &req(&["decode"])).unwrap().is_empty());
        // absent rows are reported by name, in order
        let missing =
            missing_required(&[pq], &req(&["serve-compress", "decode-kernel"])).unwrap();
        assert_eq!(missing, req(&["serve-compress"]));
        // malformed documents error rather than silently passing the gate
        assert!(missing_required(&[parse("{}").unwrap()], &req(&["x"])).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("vecsz_bench_compare_test");
        std::fs::create_dir_all(&dir).unwrap();
        let b = dir.join("base.json");
        let f = dir.join("fresh.json");
        std::fs::write(
            &b,
            r#"{"rows":[{"op":"a","threads":1,"mb_per_s":10.0}]}"#,
        )
        .unwrap();
        std::fs::write(
            &f,
            r#"{"rows":[{"op":"a","threads":1,"mb_per_s":2.0}]}"#,
        )
        .unwrap();
        let r =
            compare_files(b.to_str().unwrap(), f.to_str().unwrap(), 25.0).unwrap();
        assert_eq!(r.regressions().count(), 1);
    }
}

//! Criterion-like benchmark harness (substrate — criterion is not in the
//! vendored set).
//!
//! Measures a closure until a time budget or sample count is reached,
//! reports mean/σ/min and MB/s, and renders aligned table rows — the
//! format every `benches/*.rs` target and the figure harness use.

use crate::util::timer::Timer;

pub mod compare;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub bytes: usize,
}

impl BenchStats {
    pub fn mean_mb_s(&self) -> f64 {
        self.bytes as f64 / 1e6 / self.mean_s.max(f64::MIN_POSITIVE)
    }

    pub fn best_mb_s(&self) -> f64 {
        self.bytes as f64 / 1e6 / self.min_s.max(f64::MIN_POSITIVE)
    }

    /// σ of the MB/s estimate (first-order propagation).
    pub fn std_mb_s(&self) -> f64 {
        self.mean_mb_s() * (self.std_s / self.mean_s.max(f64::MIN_POSITIVE))
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>7} samples  {:>11.4} ms ±{:>8.4}  {:>10.1} MB/s",
            self.name,
            self.samples,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.mean_mb_s()
        )
    }
}

/// Harness settings.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: usize,
    pub min_samples: usize,
    pub max_samples: usize,
    pub budget_s: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { warmup: 1, min_samples: 5, max_samples: 50, budget_s: 2.0 }
    }
}

impl BenchOpts {
    /// Fast settings for CI / `cargo test`.
    pub fn quick() -> Self {
        Self { warmup: 1, min_samples: 2, max_samples: 5, budget_s: 0.2 }
    }

    /// Honour `VECSZ_BENCH_QUICK=1` (used by `cargo bench` in CI).
    pub fn from_env() -> Self {
        if std::env::var("VECSZ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Run `f` under the harness; `bytes` is the logical payload per call
/// (throughput denominator).
pub fn bench(name: &str, bytes: usize, opts: BenchOpts, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..opts.warmup {
        f();
    }
    let mut times = Vec::with_capacity(opts.max_samples);
    let budget = Timer::start();
    loop {
        let t = Timer::start();
        f();
        times.push(t.elapsed_s());
        if times.len() >= opts.max_samples {
            break;
        }
        if times.len() >= opts.min_samples && budget.elapsed_s() > opts.budget_s {
            break;
        }
    }
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    BenchStats {
        name: name.to_string(),
        samples: times.len(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: times.iter().copied().fold(f64::INFINITY, f64::min),
        bytes,
    }
}

/// Minimal CSV writer for results/ (figure harness output).
pub struct CsvWriter {
    path: std::path::PathBuf,
    rows: Vec<String>,
}

impl CsvWriter {
    pub fn new(path: impl Into<std::path::PathBuf>, header: &str) -> Self {
        Self { path: path.into(), rows: vec![header.to_string()] }
    }

    pub fn row(&mut self, cols: &[String]) {
        self.rows.push(cols.join(","));
    }

    pub fn finish(self) -> std::io::Result<std::path::PathBuf> {
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&self.path, self.rows.join("\n") + "\n")?;
        Ok(self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_collects_samples_and_stats() {
        let mut count = 0;
        let s = bench("noop", 1_000_000, BenchOpts::quick(), || {
            count += 1;
            std::hint::black_box(count);
        });
        assert!(s.samples >= 2);
        assert!(s.mean_s >= 0.0);
        assert!(s.min_s <= s.mean_s + 1e-12);
        assert!(s.mean_mb_s() > 0.0);
        assert!(s.row().contains("noop"));
    }

    #[test]
    fn throughput_accounts_bytes() {
        let s = bench("sleepy", 10_000_000, BenchOpts::quick(), || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        // 10 MB in >= 1ms -> <= 10 GB/s, >= 1 GB/s plausible band
        assert!(s.mean_mb_s() < 11_000.0);
    }

    #[test]
    fn csv_writer_roundtrip() {
        let p = std::env::temp_dir().join("vecsz_csv_test/out.csv");
        let mut w = CsvWriter::new(&p, "a,b");
        w.row(&["1".into(), "2".into()]);
        let path = w.finish().unwrap();
        let txt = std::fs::read_to_string(path).unwrap();
        assert_eq!(txt, "a,b\n1,2\n");
    }
}

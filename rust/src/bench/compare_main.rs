//! `bench-compare` — CI gate diffing fresh `BENCH_*.json` output against
//! committed baselines.
//!
//!     bench-compare --baseline ../ci/bench-baselines --fresh . [--tolerance 25]
//!                   [--require decode-kernel,decode_stage,serve-compress]
//!
//! Every `BENCH_*.json` in the fresh directory is compared against the
//! same-named file in the baseline directory (missing baseline files are
//! reported and skipped — a brand-new bench must be able to land first).
//! Exit code 1 when any matched row lost more than `--tolerance` percent
//! of its baseline throughput, or when a `--require` prefix (matched
//! against the fresh `op/format@threads` row keys) has no fresh row at
//! all — the lenient unmatched-rows rule would otherwise let a bench that
//! stopped emitting its rows pass forever.

use std::process::ExitCode;

use vecsz::bench::compare::{compare_files, missing_required};
use vecsz::cli::Args;
use vecsz::util::json;

fn run() -> Result<bool, vecsz::error::VszError> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&argv)?;
    let baseline_dir = a.str_or("baseline", "../ci/bench-baselines").to_string();
    let fresh_dir = a.str_or("fresh", ".").to_string();
    let tolerance = a.f64_or("tolerance", 25.0)?;
    let required: Vec<String> = a
        .get("require")
        .map(|s| s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect())
        .unwrap_or_default();
    a.reject_unknown()?;

    let mut fresh_files: Vec<String> = std::fs::read_dir(&fresh_dir)?
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    fresh_files.sort();
    if fresh_files.is_empty() {
        println!("bench-compare: no BENCH_*.json in {fresh_dir} — nothing to gate");
        return Ok(true);
    }

    let mut ok = true;
    for name in &fresh_files {
        let base = format!("{baseline_dir}/{name}");
        let fresh = format!("{fresh_dir}/{name}");
        if !std::path::Path::new(&base).exists() {
            println!("{name}: no committed baseline ({base}) — skipped");
            continue;
        }
        let report = compare_files(&base, &fresh, tolerance)?;
        println!("{name}: {} matched rows (gate: -{tolerance}%)", report.rows.len());
        if let Some((b, f)) = &report.isa_mismatch {
            println!(
                "  WARNING: ISA mismatch (baseline {b}, fresh {f}) — numbers are \
                 incomparable across hardware; reporting rows but skipping the gate"
            );
        }
        for r in &report.rows {
            let flag = if r.regressed { "  REGRESSION" } else { "" };
            println!(
                "  {:<28} {:>10.1} -> {:>10.1} MB/s  {:>+7.1}%{flag}",
                r.key, r.base_mb_s, r.fresh_mb_s, r.delta_pct
            );
        }
        for u in &report.unmatched {
            println!("  {u}: unmatched (ignored)");
        }
        if report.regressions().count() > 0 {
            ok = false;
        }
    }

    if !required.is_empty() {
        let mut docs = Vec::with_capacity(fresh_files.len());
        for name in &fresh_files {
            docs.push(json::parse(&std::fs::read_to_string(format!("{fresh_dir}/{name}"))?)?);
        }
        let missing = missing_required(&docs, &required)?;
        for m in &missing {
            println!("required rows '{m}*': no fresh bench row matches — MISSING");
            ok = false;
        }
        if missing.is_empty() {
            println!(
                "required rows present: {}",
                required.iter().map(String::as_str).collect::<Vec<_>>().join(", ")
            );
        }
    }
    Ok(ok)
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => {
            eprintln!("bench-compare: throughput regression beyond tolerance");
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("bench-compare: {e}");
            ExitCode::FAILURE
        }
    }
}

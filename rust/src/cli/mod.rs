//! Command-line argument parsing (substrate — clap is not in the vendored
//! set).
//!
//! Grammar: `vecsz <subcommand> [--flag value] [--switch] [positional...]`.
//! Flags may also be written `--flag=value`. Typed getters validate and
//! produce `VszError::Config` with a helpful message.

use std::collections::BTreeMap;

use crate::error::{Result, VszError};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Flags consumed by getters — unknown-flag detection.
    seen: std::cell::RefCell<Vec<String>>,
}

/// Known boolean switches (no value).
const SWITCHES: &[&str] = &[
    "help",
    "quick",
    "full",
    "verbose",
    "no-lossless",
    "csv",
    "stream",
    "tune-chunks",
    "verify-steps",
    "status",
    "resume",
    "repair",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if SWITCHES.contains(&stripped) {
                    a.switches.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| VszError::config(format!("--{stripped} needs a value")))?;
                    a.flags.insert(stripped.to_string(), v.clone());
                }
            } else if a.subcommand.is_empty() {
                a.subcommand = tok.clone();
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().push(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| VszError::config(format!("--{key}: '{v}' is not an integer"))),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| VszError::config(format!("--{key}: '{v}' is not a number")))
            }
        }
    }

    /// List of comma-separated usizes.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| VszError::config(format!("--{key}: bad entry '{p}'")))
                })
                .collect(),
        }
    }

    /// Error if flags were supplied that no getter asked about.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                return Err(VszError::config(format!("unknown flag --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(|x| x.to_string()).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn subcommand_flags_positionals() {
        let a = parse("compress input.f32 --eb 1e-4 --dims 512x512 --quick out.vsz");
        assert_eq!(a.subcommand, "compress");
        assert_eq!(a.positional, vec!["input.f32", "out.vsz"]);
        assert_eq!(a.get("eb"), Some("1e-4"));
        assert_eq!(a.get("dims"), Some("512x512"));
        assert!(a.has("quick"));
        assert!(!a.has("full"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --threads=8 --backend=vec16");
        assert_eq!(a.usize_or("threads", 1).unwrap(), 8);
        assert_eq!(a.str_or("backend", "psz"), "vec16");
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --threads abc");
        assert!(a.usize_or("threads", 1).is_err());
        let b = parse("x --eb zz");
        assert!(b.f64_or("eb", 1.0).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        let v: Vec<String> = vec!["c".into(), "--eb".into()];
        assert!(Args::parse(&v).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = parse("x --sizes 8,16,32");
        assert_eq!(a.usize_list_or("sizes", &[]).unwrap(), vec![8, 16, 32]);
        assert_eq!(parse("x").usize_list_or("sizes", &[64]).unwrap(), vec![64]);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("x --known 1 --mystery 2");
        let _ = a.usize_or("known", 0);
        assert!(a.reject_unknown().is_err());
        let b = parse("x --known 1");
        let _ = b.usize_or("known", 0);
        assert!(b.reject_unknown().is_ok());
    }
}

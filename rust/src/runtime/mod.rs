//! PJRT runtime — loads the AOT-compiled XLA artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and executes them from the Rust hot
//! path. Python never runs at request time.
//!
//! [`PjrtBackend`] adapts one compiled dual-quant executable to the
//! [`PqBackend`] trait so the coordinator/benches can swap it in wherever a
//! native backend fits. Input batches of any size are chunked into the
//! executable's fixed superbatch; the tail chunk is zero-padded and the
//! surplus outputs discarded.
//!
//! # Feature gating
//!
//! Execution requires the vendored `xla` crate, which is not available in
//! every build environment. The crate therefore compiles the real
//! implementation only under `--features pjrt`; the default build gets a
//! stub with the same API whose constructors return
//! [`VszError::Runtime`]. Manifest parsing ([`Manifest`]/[`ArtifactMeta`])
//! is pure Rust and always available, so `vecsz info` and the integration
//! tests' artifact discovery work in either configuration.

use std::path::{Path, PathBuf};

use crate::error::{Result, VszError};
use crate::util::json::{self};

/// One artifact as described by `manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub impl_kind: String, // "jnp" | "pallas"
    pub ndim: usize,
    pub block_size: usize,
    pub lanes: usize,
    pub superbatch: usize,
    pub radius: u16,
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            VszError::runtime(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let j = json::parse(&text)?;
        let radius = j.req("radius")?.as_usize().unwrap_or(512) as u16;
        let mut artifacts = Vec::new();
        for a in j.req("artifacts")?.as_array().unwrap_or(&[]) {
            artifacts.push(ArtifactMeta {
                name: a.req("name")?.as_str().unwrap_or_default().to_string(),
                file: a.req("file")?.as_str().unwrap_or_default().to_string(),
                impl_kind: a.req("impl")?.as_str().unwrap_or_default().to_string(),
                ndim: a.req("ndim")?.as_usize().unwrap_or(0),
                block_size: a.req("block_size")?.as_usize().unwrap_or(0),
                lanes: a.req("lanes")?.as_usize().unwrap_or(0),
                superbatch: a.req("superbatch")?.as_usize().unwrap_or(0),
                radius,
            });
        }
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    /// Find an artifact by (ndim, block size, lanes, impl).
    pub fn find(&self, ndim: usize, bs: usize, lanes: usize, impl_kind: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| {
            a.ndim == ndim && a.block_size == bs && a.lanes == lanes && a.impl_kind == impl_kind
        })
    }

    /// All (block_size, lanes) configs available for `ndim` with impl "jnp"
    /// (the autotuner's PJRT search space).
    pub fn configs(&self, ndim: usize) -> Vec<(usize, usize)> {
        self.artifacts
            .iter()
            .filter(|a| a.ndim == ndim && a.impl_kind == "jnp")
            .map(|a| (a.block_size, a.lanes))
            .collect()
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::sync::Mutex;

    use super::{ArtifactMeta, Manifest};
    use crate::error::{Result, VszError};
    use crate::padding::{PadGranularity, PadScalars};
    use crate::quant::{check_batch, CodesKind, DqConfig, PqBackend};
    use std::path::Path;

    /// A compiled, ready-to-execute dual-quant artifact.
    pub struct PjrtExecutable {
        meta: ArtifactMeta,
        exe: xla::PjRtLoadedExecutable,
    }

    /// PJRT client + executable cache.
    pub struct PjrtRuntime {
        pub manifest: Manifest,
        client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        /// Create a CPU PJRT client and load the manifest.
        pub fn new(artifact_dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| VszError::runtime(format!("pjrt cpu client: {e:?}")))?;
            Ok(Self { manifest, client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile one artifact (HLO text -> loaded executable).
        pub fn load(&self, meta: &ArtifactMeta) -> Result<PjrtExecutable> {
            let path = self.manifest.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| VszError::runtime(format!("parse {}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| VszError::runtime(format!("compile {}: {e:?}", meta.name)))?;
            Ok(PjrtExecutable { meta: meta.clone(), exe })
        }
    }

    impl PjrtExecutable {
        pub fn meta(&self) -> &ArtifactMeta {
            &self.meta
        }

        /// Execute one superbatch. `blocks` must be exactly
        /// `superbatch * bs^ndim` long, `pads` `superbatch` long.
        pub fn run_superbatch(
            &self,
            blocks: &[f32],
            pads: &[f32],
            eb: f64,
            radius: u16,
        ) -> Result<(Vec<i32>, Vec<f32>)> {
            let m = &self.meta;
            let elems = m.block_size.pow(m.ndim as u32);
            if blocks.len() != m.superbatch * elems || pads.len() != m.superbatch {
                return Err(VszError::runtime("superbatch size mismatch"));
            }
            let mut dims: Vec<i64> = vec![m.superbatch as i64];
            dims.extend(std::iter::repeat(m.block_size as i64).take(m.ndim));
            let xerr = |e: xla::Error| VszError::runtime(format!("pjrt exec: {e:?}"));
            let blocks_lit = xla::Literal::vec1(blocks).reshape(&dims).map_err(xerr)?;
            let pads_lit =
                xla::Literal::vec1(pads).reshape(&[m.superbatch as i64, 1]).map_err(xerr)?;
            let ebs = [2.0 * eb as f32, (0.5 / eb) as f32, radius as f32];
            let ebs_lit = xla::Literal::vec1(&ebs).reshape(&[1, 3]).map_err(xerr)?;

            let result = self
                .exe
                .execute::<xla::Literal>(&[blocks_lit, pads_lit, ebs_lit])
                .map_err(xerr)?[0][0]
                .to_literal_sync()
                .map_err(xerr)?;
            // aot.py lowers with return_tuple=True: (codes i32, outv f32)
            let (codes_lit, outv_lit) = result.to_tuple2().map_err(xerr)?;
            let codes = codes_lit.to_vec::<i32>().map_err(xerr)?;
            let outv = outv_lit.to_vec::<f32>().map_err(xerr)?;
            Ok((codes, outv))
        }
    }

    /// [`PqBackend`] adapter: chunks arbitrary batches into superbatches.
    ///
    /// Only Global/Block padding granularities are supported (the artifacts
    /// take one scalar per block — see DESIGN.md); `Edge` requires the
    /// native backends.
    ///
    /// Thread-safety: the `xla` crate's executables hold `Rc` internals and
    /// are not `Send`. Every use (execute + eventual drop) is serialized
    /// behind the mutex below, and the single-device CPU client has no
    /// cross-thread affinity requirements, so the manual `Send + Sync` is
    /// sound in this confinement discipline.
    struct ExeCell(PjrtExecutable);
    // SAFETY: see above — all access to the inner executable goes through
    // `Mutex<ExeCell>`.
    unsafe impl Send for ExeCell {}

    pub struct PjrtBackend {
        meta: ArtifactMeta,
        exe: Mutex<ExeCell>,
    }

    impl PjrtBackend {
        pub fn new(runtime: &PjrtRuntime, ndim: usize, bs: usize, lanes: usize) -> Result<Self> {
            let meta = runtime
                .manifest
                .find(ndim, bs, lanes, "jnp")
                .or_else(|| runtime.manifest.find(ndim, bs, lanes, "pallas"))
                .ok_or_else(|| {
                    VszError::runtime(format!("no artifact for ndim={ndim} bs={bs} lanes={lanes}"))
                })?
                .clone();
            Self::from_meta(runtime, &meta)
        }

        pub fn from_meta(runtime: &PjrtRuntime, meta: &ArtifactMeta) -> Result<Self> {
            let exe = runtime.load(meta)?;
            Ok(Self { meta: meta.clone(), exe: Mutex::new(ExeCell(exe)) })
        }
    }

    impl PqBackend for PjrtBackend {
        fn name(&self) -> String {
            format!("pjrt:{}", self.meta.name)
        }

        fn kind(&self) -> CodesKind {
            CodesKind::DualQuant
        }

        fn lanes(&self) -> usize {
            self.meta.lanes
        }

        fn run(
            &self,
            cfg: &DqConfig,
            blocks: &[f32],
            block_base: usize,
            pads: &PadScalars,
            codes: &mut [u16],
            outv: &mut [f32],
        ) {
            assert_eq!(cfg.shape.ndim, self.meta.ndim, "artifact ndim mismatch");
            assert_eq!(cfg.shape.bs, self.meta.block_size, "artifact block size mismatch");
            assert!(
                pads.policy.granularity != PadGranularity::Edge,
                "PJRT backend does not support edge-granularity padding"
            );
            let elems = cfg.shape.elems();
            let nb = check_batch(cfg.shape, blocks, codes, outv);
            let sb = self.meta.superbatch;
            let guard = self.exe.lock().unwrap();

            let mut in_blocks = vec![0.0f32; sb * elems];
            let mut in_pads = vec![0.0f32; sb];
            let mut done = 0usize;
            while done < nb {
                let take = (nb - done).min(sb);
                in_blocks[..take * elems]
                    .copy_from_slice(&blocks[done * elems..(done + take) * elems]);
                in_blocks[take * elems..].fill(0.0);
                for k in 0..take {
                    in_pads[k] = pads.block_scalar(block_base + done + k);
                }
                in_pads[take..].fill(0.0);
                let (c, v) = guard
                    .0
                    .run_superbatch(&in_blocks, &in_pads, cfg.eb, cfg.radius)
                    .expect("pjrt superbatch execution failed");
                for (dst, src) in codes[done * elems..(done + take) * elems]
                    .iter_mut()
                    .zip(c[..take * elems].iter())
                {
                    *dst = *src as u16;
                }
                outv[done * elems..(done + take) * elems].copy_from_slice(&v[..take * elems]);
                done += take;
            }
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{PjrtBackend, PjrtExecutable, PjrtRuntime};

/// Stub runtime compiled when the `pjrt` feature is off: same API surface,
/// constructors fail with a clear [`VszError::Runtime`] so callers (CLI
/// `info`, integration tests, examples) degrade gracefully instead of
/// failing to link.
#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use super::{ArtifactMeta, Manifest};
    use crate::error::{Result, VszError};
    use crate::padding::PadScalars;
    use crate::quant::{CodesKind, DqConfig, PqBackend};

    const UNAVAILABLE: &str =
        "PJRT execution unavailable: vecsz was built without the 'pjrt' feature \
         (requires the vendored xla crate)";

    /// Stub of the PJRT client; [`PjrtRuntime::new`] always fails.
    pub struct PjrtRuntime {
        pub manifest: Manifest,
    }

    impl PjrtRuntime {
        pub fn new(artifact_dir: &Path) -> Result<Self> {
            // Parse the manifest first so a missing manifest keeps its
            // specific error message, then report the missing feature.
            let _ = Manifest::load(artifact_dir)?;
            Err(VszError::runtime(UNAVAILABLE))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }

    /// Stub backend; constructors always fail, so `run` is unreachable.
    pub struct PjrtBackend {
        _private: (),
    }

    impl PjrtBackend {
        pub fn new(
            _runtime: &PjrtRuntime,
            _ndim: usize,
            _bs: usize,
            _lanes: usize,
        ) -> Result<Self> {
            Err(VszError::runtime(UNAVAILABLE))
        }

        pub fn from_meta(_runtime: &PjrtRuntime, _meta: &ArtifactMeta) -> Result<Self> {
            Err(VszError::runtime(UNAVAILABLE))
        }
    }

    impl PqBackend for PjrtBackend {
        fn name(&self) -> String {
            "pjrt:stub".to_string()
        }

        fn kind(&self) -> CodesKind {
            CodesKind::DualQuant
        }

        fn lanes(&self) -> usize {
            1
        }

        fn run(
            &self,
            _cfg: &DqConfig,
            _blocks: &[f32],
            _block_base: usize,
            _pads: &PadScalars,
            _codes: &mut [u16],
            _outv: &mut [f32],
        ) {
            unreachable!("stub PjrtBackend cannot be constructed");
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtBackend, PjrtRuntime};

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn manifest_parse_roundtrip() {
        let doc = r#"{"version":1,"radius":512,"artifacts":[
            {"name":"dq_2d_b16_l8_jnp","file":"f.hlo.txt","impl":"jnp",
             "ndim":2,"block_size":16,"lanes":8,"superbatch":4096,
             "inputs":[],"outputs":[]}]}"#;
        let dir = std::env::temp_dir().join("vecsz_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find(2, 16, 8, "jnp").unwrap();
        assert_eq!(a.superbatch, 4096);
        assert!(m.find(2, 16, 16, "jnp").is_none());
        assert_eq!(m.configs(2), vec![(16, 8)]);
    }

    #[test]
    fn manifest_missing_dir_is_runtime_error() {
        let err = Manifest::load(Path::new("/nonexistent/path")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        // manifest exists (written by the test above) but execution must
        // fail with the feature-gate message, not a link error.
        let doc = r#"{"version":1,"radius":512,"artifacts":[]}"#;
        let dir = std::env::temp_dir().join("vecsz_stub_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
        let err = PjrtRuntime::new(&dir).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    // Execution tests live in rust/tests/pjrt_integration.rs (they need
    // built artifacts and are skipped when artifacts/ is absent).
    #[allow(dead_code)]
    fn _types_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ArtifactMeta>();
    }
}

//! `vsz serve` — a long-running compression service over framed TCP.
//!
//! The service puts the layer-3 scheduler ([`crate::coordinator::sched`])
//! behind a socket: one shared [`ThreadPool`] executes chunk jobs from
//! every in-flight request, so a big compress from one client and a small
//! one from another interleave at chunk granularity instead of queueing
//! whole requests behind each other.
//!
//! ## Wire protocol
//!
//! Every frame on the wire is `u32 LE length` + `length` payload bytes.
//!
//! A **request** is a single frame:
//!
//! ```text
//! u8 opcode | u32 LE hdr_len | hdr_len bytes JSON header | raw body
//! ```
//!
//! | opcode | op         | header keys                          | body          |
//! |--------|------------|--------------------------------------|---------------|
//! | 1      | compress   | `dims`, `eb` (+ `name`, `block`,     | raw f32 LE    |
//! |        |            | `backend`, `chunk_rows`)             | samples       |
//! | 2      | decompress | —                                    | vsz container |
//! | 3      | extract    | `rows: [lo, hi]`                     | v3 container  |
//! | 4      | stats      | —                                    | —             |
//! | 5      | shutdown   | —                                    | —             |
//!
//! A **response** is one or more frames, each `u8 kind` + payload:
//! `0 = data` (streamed result slices, may repeat), `1 = end` (terminal;
//! JSON per-request stats), `2 = error` (terminal; message), `3 = busy`
//! (terminal; admission control rejected the request — the payload is a
//! JSON object `{"busy": reason, "retry_after_ms": hint}` whose hint
//! scales with the current in-flight load, and clients floor their next
//! backoff sleep at it).
//!
//! ## Admission control
//!
//! The server bounds the bytes it holds in flight. Each data-path request
//! is charged its body **plus** the buffers it will materialize — the
//! parsed f32 copy for compress, the decoded output (read from the
//! container header dims, which can be many times the compressed body)
//! for decompress/extract. A request whose charge would push the running
//! total past [`ServeConfig::max_inflight_bytes`] is rejected with a
//! `busy` frame instead of queueing unboundedly — the client retries with
//! backoff. Connections beyond
//! [`ServeConfig::max_conns`] are likewise rejected with `busy` at accept
//! time. The connection stays usable after a `busy` or `error` response;
//! only the request is dropped.
//!
//! ## Deadlines and cancellation
//!
//! Every data-path request carries a [`CancelToken`] shared by all of its
//! chunk jobs. A per-request deadline ([`ServeConfig::request_timeout_ms`],
//! overridable per request with a `timeout_ms` header key) arms a watchdog
//! that flips the token when the deadline passes **or** the client
//! disconnects mid-request: queued chunk jobs are skipped by the executor,
//! running ones bail at their next cooperative check, and the reply is a
//! `busy` frame naming the deadline — the same retryable class as an
//! admission reject. The admission budget is released as soon as the
//! handler replies (RAII), so a timed-out request can never leak in-flight
//! bytes. Handler sockets additionally run under read/write timeouts: an
//! idle connection may wait forever for its next request, but once a frame
//! starts it must complete within [`IO_TIMEOUT`], and response writes to a
//! stuck peer are bounded the same way.
//!
//! ## Statistics
//!
//! Each data-path response's `end` frame carries that request's numbers;
//! a `stats` request returns the lifetime [`CompressionStats`] aggregate
//! (merged across every request the server has handled) plus uptime and
//! in-flight gauges. `vsz serve --status` is a thin client over it.

use std::io::{Cursor, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::compressor::{decompress, BackendChoice, Config, EbMode};
use crate::coordinator::exec::CancelToken;
use crate::coordinator::pool::ThreadPool;
use crate::coordinator::sched;
use crate::failpoint;
use crate::data::{io as dio, Field};
use crate::error::{Result, VszError};
use crate::format;
use crate::metrics::CompressionStats;
use crate::stream::dataset::{container_fingerprint, ChunkCache, Dataset, Region};
use crate::stream::StreamOptions;
use crate::util::json::{self, Json};

/// Request opcodes (first body byte of a request frame).
pub const OP_COMPRESS: u8 = 1;
pub const OP_DECOMPRESS: u8 = 2;
pub const OP_EXTRACT: u8 = 3;
pub const OP_STATS: u8 = 4;
pub const OP_SHUTDOWN: u8 = 5;

/// Response frame kinds (first byte of a response frame).
pub const KIND_DATA: u8 = 0;
pub const KIND_END: u8 = 1;
pub const KIND_ERROR: u8 = 2;
pub const KIND_BUSY: u8 = 3;

/// Upper bound on a single frame — rejects bogus length prefixes before
/// the allocation, not after.
const MAX_FRAME: usize = 1 << 30;

/// Result payloads are streamed back in slices of this size.
const DATA_SLICE: usize = 1 << 20;

/// Once a request frame has started arriving (or a response write has
/// started), it must complete within this bound; a peer that stalls
/// mid-frame gets its connection closed instead of pinning a handler
/// thread forever. Idle waits between requests are unbounded.
const IO_TIMEOUT: Duration = Duration::from_secs(300);

/// Socket poll granularity: the read timeout installed on handler
/// sockets, which also bounds how often the per-request watchdog checks
/// for client disconnect and deadline expiry.
const POLL_INTERVAL: Duration = Duration::from_millis(100);

/// Server tuning knobs (`vsz serve` flags map onto these 1:1).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Chunk-worker pool width shared by all requests.
    pub threads: usize,
    /// Admission cap: total bytes in flight, counting each request's body
    /// plus its expected decoded output (see the module-level admission
    /// notes).
    pub max_inflight_bytes: u64,
    /// Accept cap: concurrent client connections.
    pub max_conns: usize,
    /// Default compress chunk span (rows); 0 picks the container default.
    /// A request's `chunk_rows` header key overrides it.
    pub chunk_rows: usize,
    /// Per-request deadline in milliseconds; 0 disables the deadline. A
    /// request's `timeout_ms` header key overrides it. An expired deadline
    /// cancels the request's chunk jobs and replies `busy`.
    pub request_timeout_ms: u64,
    /// Decoded-chunk cache budget in bytes (`--cache-mb`): repeated
    /// extract/decompress requests against the same container bytes hit
    /// warm slabs instead of re-decoding. 0 disables the cache. Resident
    /// slabs outlive requests, so this budget is separate from (and on top
    /// of) the admission cap.
    pub cache_bytes: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            threads: 4,
            max_inflight_bytes: 256 << 20,
            max_conns: 32,
            chunk_rows: 0,
            request_timeout_ms: 0,
            cache_bytes: 64 << 20,
        }
    }
}

/// State shared between the accept loop and every connection handler.
struct Shared {
    cfg: ServeConfig,
    addr: SocketAddr,
    pool: Arc<ThreadPool>,
    /// Server-wide decoded-chunk cache, keyed by container fingerprint so
    /// requests carrying the same container bytes share warm slabs.
    cache: Arc<ChunkCache>,
    inflight: AtomicU64,
    active_conns: AtomicUsize,
    stats: Mutex<CompressionStats>,
    stop: AtomicBool,
    started: Instant,
}

/// Holds admitted bytes against the in-flight gauge; releases on drop so
/// an error path can never leak admission budget.
struct Admission<'a> {
    gauge: &'a AtomicU64,
    bytes: u64,
}

impl Drop for Admission<'_> {
    fn drop(&mut self) {
        self.gauge.fetch_sub(self.bytes, Ordering::SeqCst);
    }
}

fn admit(shared: &Shared, bytes: u64) -> Option<Admission<'_>> {
    let prev = shared.inflight.fetch_add(bytes, Ordering::SeqCst);
    if prev.saturating_add(bytes) > shared.cfg.max_inflight_bytes {
        shared.inflight.fetch_sub(bytes, Ordering::SeqCst);
        None
    } else {
        Some(Admission { gauge: &shared.inflight, bytes })
    }
}

/// JSON payload of a `busy` frame: the reason plus a `retry_after_ms`
/// backoff hint scaled by how loaded the admission gauge is right now —
/// a server pinned at its cap pushes clients further out than one that
/// rejected a single oversized request.
fn busy_payload(shared: &Shared, reason: &str) -> String {
    let cap = shared.cfg.max_inflight_bytes.max(1);
    let load = (shared.inflight.load(Ordering::SeqCst) as f64 / cap as f64).min(1.0);
    let hint = (50.0 + 450.0 * load).round() as u64;
    format!("{{\"busy\":\"{}\",\"retry_after_ms\":{hint}}}", json::escape(reason))
}

/// Lock the lifetime stats, recovering from poisoning: the aggregate is
/// plain counters (always internally consistent), and one panicked handler
/// must not take every other connection's stats path down with it.
fn stats_lock(shared: &Shared) -> MutexGuard<'_, CompressionStats> {
    shared.stats.lock().unwrap_or_else(|p| p.into_inner())
}

/// Deadline + liveness context of one in-flight data-path request.
struct RequestCtx {
    cancel: CancelToken,
    deadline: Option<Instant>,
    timeout_ms: u64,
}

impl RequestCtx {
    fn new(timeout_ms: u64) -> Self {
        let deadline =
            (timeout_ms > 0).then(|| Instant::now() + Duration::from_millis(timeout_ms));
        Self { cancel: CancelToken::new(), deadline, timeout_ms }
    }

    fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// Per-request watchdog: flips the request's [`CancelToken`] when the
/// deadline passes or the client's socket reaches EOF mid-request. The
/// handler signals completion through the condvar pair; the thread is
/// detached (never joined) so finishing a request costs no watchdog
/// latency — it observes the done flag within one poll interval and exits.
struct Watchdog {
    done: Arc<(Mutex<bool>, Condvar)>,
}

impl Watchdog {
    fn spawn(stream: &TcpStream, ctx: &RequestCtx) -> Watchdog {
        let done = Arc::new((Mutex::new(false), Condvar::new()));
        let cancel = ctx.cancel.clone();
        let deadline = ctx.deadline;
        // the clone shares the fd; the handler does not read while the
        // request is in flight, so peeking from here races nothing
        let peer = stream.try_clone().ok();
        let signal = Arc::clone(&done);
        thread::spawn(move || {
            let (m, cv) = &*signal;
            let mut fin = m.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                if *fin {
                    return;
                }
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    cancel.cancel();
                    return;
                }
                // a zero-byte peek is EOF: the client went away, so the
                // work it was waiting for should stop. The socket's read
                // timeout bounds this to one poll interval.
                if let Some(s) = &peer {
                    let mut b = [0u8; 1];
                    drop(fin); // don't hold the lock across a blocking peek
                    let gone = matches!(s.peek(&mut b), Ok(0));
                    fin = m.lock().unwrap_or_else(|p| p.into_inner());
                    if gone && !*fin {
                        cancel.cancel();
                        return;
                    }
                    if *fin {
                        return;
                    }
                }
                let (g, _) = cv
                    .wait_timeout(fin, POLL_INTERVAL)
                    .unwrap_or_else(|p| p.into_inner());
                fin = g;
            }
        });
        Watchdog { done }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        let (m, cv) = &*self.done;
        *m.lock().unwrap_or_else(|p| p.into_inner()) = true;
        cv.notify_all();
    }
}

/// Bytes a data-path request holds in flight: its body plus the largest
/// buffer the request will materialize — the parsed f32 copy for compress,
/// the decoded output (derived from the container header dims, which can be
/// many times the compressed body) for decompress/extract. This is what the
/// admission cap charges, so it bounds real memory, not just wire bytes.
fn inflight_cost(op: u8, hdr: &Json, body: &[u8]) -> Result<u64> {
    let body_len = body.len() as u64;
    let extra = match op {
        OP_COMPRESS => body_len,
        OP_DECOMPRESS => dims_bytes(&format::peek_dims(body)?),
        OP_EXTRACT => {
            let dims = format::peek_dims(body)?;
            let (lo, hi) = parse_rows(hdr)?;
            let row_bytes =
                (dims.shape[1] as u64).saturating_mul(dims.shape[2] as u64).saturating_mul(4);
            (hi.saturating_sub(lo) as u64).saturating_mul(row_bytes)
        }
        _ => 0,
    };
    Ok(body_len.saturating_add(extra))
}

/// Decoded size of a full field in bytes (saturating: header axes are
/// individually bounded but their product may not fit).
fn dims_bytes(dims: &crate::blocks::Dims) -> u64 {
    dims.shape.iter().fold(4u64, |acc, &s| acc.saturating_mul(s as u64))
}

/// The `rows: [lo, hi]` header key of an extract request.
fn parse_rows(hdr: &Json) -> Result<(usize, usize)> {
    let rows = hdr
        .req("rows")?
        .as_array()
        .ok_or_else(|| VszError::format("extract: 'rows' must be [lo, hi]"))?;
    match rows {
        [lo, hi] => Ok((
            lo.as_usize().ok_or_else(|| VszError::format("extract: bad row lo"))?,
            hi.as_usize().ok_or_else(|| VszError::format("extract: bad row hi"))?,
        )),
        _ => Err(VszError::format("extract: 'rows' must be [lo, hi]")),
    }
}

/// The `vsz serve` listener. `bind` then `run`; `run` returns after a
/// `shutdown` request has been served and every connection has drained.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    pub fn bind(addr: &str, cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let pool = Arc::new(ThreadPool::new(cfg.threads.max(1)));
        let shared = Arc::new(Shared {
            cfg,
            addr,
            pool,
            cache: Arc::new(ChunkCache::new(cfg.cache_bytes)),
            inflight: AtomicU64::new(0),
            active_conns: AtomicUsize::new(0),
            stats: Mutex::new(CompressionStats::new()),
            stop: AtomicBool::new(false),
            started: Instant::now(),
        });
        Ok(Server { listener, shared })
    }

    /// The bound address (resolves the port when bound to `:0` in tests).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept loop: one handler thread per connection, all sharing the
    /// chunk pool. Returns once a `shutdown` request is served (the
    /// handler sets the stop flag, then pokes the listener awake).
    pub fn run(self) -> Result<()> {
        let mut handlers: Vec<JoinHandle<()>> = Vec::new();
        for conn in self.listener.incoming() {
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            let mut stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            if self.shared.active_conns.load(Ordering::SeqCst) >= self.shared.cfg.max_conns {
                let payload = busy_payload(&self.shared, "connection limit reached");
                let _ = write_kind_frame(&mut stream, KIND_BUSY, payload.as_bytes());
                continue;
            }
            // poll-interval read timeout (idle waits loop on it; mid-frame
            // stalls are bounded by IO_TIMEOUT in read_request_frame) and a
            // hard write timeout against stuck peers
            let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
            let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
            self.shared.active_conns.fetch_add(1, Ordering::SeqCst);
            let shared = Arc::clone(&self.shared);
            handlers.push(thread::spawn(move || {
                let peer = stream.peer_addr().ok();
                if let Err(e) = handle_conn(&shared, stream) {
                    eprintln!("vsz serve: connection {peer:?}: {e}");
                }
                shared.active_conns.fetch_sub(1, Ordering::SeqCst);
            }));
            handlers.retain(|h| !h.is_finished());
        }
        for h in handlers {
            let _ = h.join();
        }
        Ok(())
    }
}

/// One persistent connection: requests are served in order until the
/// client closes its end.
fn handle_conn(shared: &Shared, mut stream: TcpStream) -> Result<()> {
    loop {
        let req = match read_request_frame(shared, &mut stream)? {
            Some(b) => b,
            None => return Ok(()),
        };
        if req.len() < 5 {
            write_kind_frame(&mut stream, KIND_ERROR, b"request frame shorter than its header")?;
            continue;
        }
        let op = req[0];
        let hdr_len = u32::from_le_bytes([req[1], req[2], req[3], req[4]]) as usize;
        if 5 + hdr_len > req.len() {
            write_kind_frame(&mut stream, KIND_ERROR, b"header length exceeds request frame")?;
            continue;
        }
        let hdr = if hdr_len == 0 {
            Json::Obj(Vec::new())
        } else {
            let text = match std::str::from_utf8(&req[5..5 + hdr_len]) {
                Ok(t) => t,
                Err(_) => {
                    write_kind_frame(&mut stream, KIND_ERROR, b"request header is not UTF-8")?;
                    continue;
                }
            };
            match json::parse(text) {
                Ok(j) => j,
                Err(e) => {
                    let msg = format!("bad header: {e}");
                    write_kind_frame(&mut stream, KIND_ERROR, msg.as_bytes())?;
                    continue;
                }
            }
        };
        let body = &req[5 + hdr_len..];
        match op {
            OP_STATS => {
                let j = status_json(shared);
                write_kind_frame(&mut stream, KIND_END, j.as_bytes())?;
            }
            OP_SHUTDOWN => {
                shared.stop.store(true, Ordering::SeqCst);
                write_kind_frame(&mut stream, KIND_END, b"{\"ok\":true}")?;
                stream.flush()?;
                // unblock the accept loop so it observes the stop flag
                let _ = TcpStream::connect(shared.addr);
            }
            OP_COMPRESS | OP_DECOMPRESS | OP_EXTRACT => {
                let cost = match inflight_cost(op, &hdr, body) {
                    Ok(c) => c,
                    Err(e) => {
                        stats_lock(shared).record_error();
                        write_kind_frame(&mut stream, KIND_ERROR, e.to_string().as_bytes())?;
                        continue;
                    }
                };
                let guard = match admit(shared, cost) {
                    Some(g) => g,
                    None => {
                        let msg = format!(
                            "request needs {cost} in-flight bytes (body + decoded output), \
                             exceeding the {}-byte cap",
                            shared.cfg.max_inflight_bytes
                        );
                        let payload = busy_payload(shared, &msg);
                        write_kind_frame(&mut stream, KIND_BUSY, payload.as_bytes())?;
                        continue;
                    }
                };
                let timeout_ms = hdr
                    .get("timeout_ms")
                    .and_then(Json::as_usize)
                    .map(|v| v as u64)
                    .unwrap_or(shared.cfg.request_timeout_ms);
                let ctx = RequestCtx::new(timeout_ms);
                let watchdog = Watchdog::spawn(&stream, &ctx);
                let outcome = process(shared, op, &hdr, body, &ctx);
                drop(watchdog);
                match outcome {
                    Ok((data, end_json)) => {
                        for slice in data.chunks(DATA_SLICE) {
                            write_kind_frame(&mut stream, KIND_DATA, slice)?;
                        }
                        write_kind_frame(&mut stream, KIND_END, end_json.as_bytes())?;
                    }
                    Err(e) if ctx.cancel.is_cancelled() && ctx.expired() => {
                        // deadline-cancelled work replies busy — the same
                        // retryable class as an admission reject. The guard
                        // drop below returns the budget immediately.
                        stats_lock(shared).record_error();
                        let msg = format!(
                            "request deadline exceeded ({} ms); {e}",
                            ctx.timeout_ms
                        );
                        let payload = busy_payload(shared, &msg);
                        write_kind_frame(&mut stream, KIND_BUSY, payload.as_bytes())?;
                    }
                    Err(e) => {
                        stats_lock(shared).record_error();
                        write_kind_frame(&mut stream, KIND_ERROR, e.to_string().as_bytes())?;
                    }
                }
                drop(guard);
            }
            other => {
                let msg = format!("unknown opcode {other}");
                write_kind_frame(&mut stream, KIND_ERROR, msg.as_bytes())?;
            }
        }
    }
}

/// Execute one data-path request; returns the result payload and the
/// per-request stats JSON for the `end` frame. `ctx` carries the request's
/// cancel token (shared with every chunk job it spawns) and deadline.
fn process(
    shared: &Shared,
    op: u8,
    hdr: &Json,
    body: &[u8],
    ctx: &RequestCtx,
) -> Result<(Vec<u8>, String)> {
    let t = Instant::now();
    if ctx.cancel.is_cancelled() || ctx.expired() {
        return Err(VszError::runtime("request cancelled before work started"));
    }
    match op {
        OP_COMPRESS => {
            let dims_s = hdr
                .req("dims")?
                .as_str()
                .ok_or_else(|| VszError::format("compress: 'dims' must be a string like 512x512"))?;
            let dims = dio::parse_dims(dims_s)?;
            let eb = hdr
                .req("eb")?
                .as_f64()
                .ok_or_else(|| VszError::format("compress: 'eb' must be a number"))?;
            if body.len() != dims.len() * 4 {
                return Err(VszError::format(format!(
                    "compress: body is {} bytes, dims {dims_s} needs {}",
                    body.len(),
                    dims.len() * 4
                )));
            }
            let name = hdr.get("name").and_then(Json::as_str).unwrap_or("field").to_string();
            let data: Vec<f32> = body
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let mut cfg = Config { eb: EbMode::Abs(eb), ..Config::default() };
            if let Some(b) = hdr.get("block").and_then(Json::as_usize) {
                cfg.block_size = b;
            }
            if let Some(s) = hdr.get("backend").and_then(Json::as_str) {
                cfg.backend = BackendChoice::parse(s)
                    .ok_or_else(|| VszError::config(format!("compress: bad backend '{s}'")))?;
            }
            let span =
                hdr.get("chunk_rows").and_then(Json::as_usize).unwrap_or(shared.cfg.chunk_rows);
            let field = Field::new(name, dims, data);
            let (bytes, stats) = sched::compress_field_chunked_with(
                &shared.pool,
                field,
                &cfg,
                span,
                StreamOptions::default(),
                Some(ctx.cancel.clone()),
            )?;
            let secs = t.elapsed().as_secs_f64();
            stats_lock(shared).record_compress(stats.raw_bytes, stats.compressed_bytes, secs);
            let end = format!(
                "{{\"op\":\"compress\",\"raw_bytes\":{},\"compressed_bytes\":{},\
                 \"n_chunks\":{},\"ratio\":{:.4},\"seconds\":{:.6}}}",
                stats.raw_bytes,
                stats.compressed_bytes,
                stats.n_chunks,
                stats.ratio(),
                secs
            );
            Ok((bytes, end))
        }
        OP_DECOMPRESS => {
            // v3 containers decode through the server-wide Dataset cache
            // (bit-identical to `decompress`: same per-chunk decode, slabs
            // concatenated in field order); older containers carry no
            // index, so they take the legacy full-decode path.
            let data = if body.starts_with(format::MAGIC3) {
                open_dataset(shared, body)?.read(Region::All)?
            } else {
                decompress(body, shared.cfg.threads.max(1))?.data
            };
            if ctx.cancel.is_cancelled() {
                return Err(VszError::runtime("request cancelled during decode"));
            }
            let mut out = Vec::with_capacity(data.len() * 4);
            for x in &data {
                out.extend_from_slice(&x.to_le_bytes());
            }
            let secs = t.elapsed().as_secs_f64();
            stats_lock(shared).record_decompress(body.len(), out.len(), secs);
            let end = format!(
                "{{\"op\":\"decompress\",\"compressed_bytes\":{},\"raw_bytes\":{},\
                 \"seconds\":{:.6}}}",
                body.len(),
                out.len(),
                secs
            );
            Ok((out, end))
        }
        OP_EXTRACT => {
            let (lo, hi) = parse_rows(hdr)?;
            let data = open_dataset(shared, body)?.read(Region::Rows(lo..hi))?;
            if ctx.cancel.is_cancelled() {
                return Err(VszError::runtime("request cancelled during extract"));
            }
            let mut out = Vec::with_capacity(data.len() * 4);
            for x in &data {
                out.extend_from_slice(&x.to_le_bytes());
            }
            let secs = t.elapsed().as_secs_f64();
            stats_lock(shared).record_extract(body.len(), out.len(), secs);
            let end = format!(
                "{{\"op\":\"extract\",\"rows\":[{lo},{hi}],\"raw_bytes\":{},\
                 \"seconds\":{:.6}}}",
                out.len(),
                secs
            );
            Ok((out, end))
        }
        _ => unreachable!("process() is only called for data-path opcodes"),
    }
}

/// A per-request [`Dataset`] handle over the request's container bytes,
/// wired to the server-wide chunk cache and worker pool. The fingerprint
/// key makes repeated requests against the same container share slabs.
fn open_dataset<'a>(shared: &Shared, body: &'a [u8]) -> Result<Dataset<Cursor<&'a [u8]>>> {
    Dataset::open_shared(
        Cursor::new(body),
        shared.cfg.threads.max(1),
        Arc::clone(&shared.cache),
        container_fingerprint(body),
        Some(Arc::clone(&shared.pool)),
    )
}

/// The `stats` response: lifetime aggregate + gauges.
fn status_json(shared: &Shared) -> String {
    let stats = stats_lock(shared).to_json();
    let cache = shared.cache.stats().snapshot().to_json();
    format!(
        "{{\"uptime_s\":{:.3},\"active_conns\":{},\"inflight_bytes\":{},\
         \"pool_threads\":{},\"request_timeout_ms\":{},\
         \"cache_budget_bytes\":{},\"cache\":{cache},\"stats\":{stats}}}",
        shared.started.elapsed().as_secs_f64(),
        shared.active_conns.load(Ordering::SeqCst),
        shared.inflight.load(Ordering::SeqCst),
        shared.cfg.threads.max(1),
        shared.cfg.request_timeout_ms,
        shared.cache.budget(),
    )
}

// ---------------------------------------------------------------------------
// framing

fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

/// One `kind` response frame (length prefix covers the kind byte).
fn write_kind_frame<W: Write>(w: &mut W, kind: u8, payload: &[u8]) -> Result<()> {
    if failpoint::armed() {
        // route the assembled frame through the `serve_frame_write` site so
        // fault tests can tear or fail server responses deterministically
        let mut buf = Vec::with_capacity(5 + payload.len());
        buf.extend_from_slice(&((payload.len() + 1) as u32).to_le_bytes());
        buf.push(kind);
        buf.extend_from_slice(payload);
        return failpoint::write_through("serve_frame_write", w, &buf);
    }
    w.write_all(&((payload.len() + 1) as u32).to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    Ok(())
}

/// True for the error kinds a socket read/write timeout produces.
fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Server-side frame read over a socket carrying a [`POLL_INTERVAL`] read
/// timeout. Waiting for the *start* of a request is unbounded (an idle
/// connection is fine, though a set stop flag ends it); once the first
/// byte has arrived the whole frame must complete within [`IO_TIMEOUT`].
/// `None` on clean EOF or shutdown-while-idle.
fn read_request_frame(shared: &Shared, stream: &mut TcpStream) -> Result<Option<Vec<u8>>> {
    failpoint::hit("serve_frame_read")?;
    let mut len = [0u8; 4];
    let mut got = 0usize;
    let mut started: Option<Instant> = None;
    while got < 4 {
        match stream.read(&mut len[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(VszError::format("frame: truncated length prefix"));
            }
            Ok(n) => {
                started.get_or_insert_with(Instant::now);
                got += n;
            }
            Err(e) if would_block(&e) => match started {
                None => {
                    if shared.stop.load(Ordering::SeqCst) {
                        return Ok(None);
                    }
                }
                Some(t0) if t0.elapsed() > IO_TIMEOUT => {
                    return Err(VszError::runtime("frame: stalled mid-length-prefix"));
                }
                Some(_) => {}
            },
            Err(e) => return Err(e.into()),
        }
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(VszError::format(format!("frame: {n} bytes exceeds the 1 GiB frame cap")));
    }
    let t0 = started.unwrap_or_else(Instant::now);
    let mut buf = vec![0u8; n];
    let mut off = 0usize;
    while off < n {
        match stream.read(&mut buf[off..]) {
            Ok(0) => return Err(VszError::format("frame: truncated payload")),
            Ok(k) => off += k,
            Err(e) if would_block(&e) => {
                if t0.elapsed() > IO_TIMEOUT {
                    return Err(VszError::runtime("frame: stalled mid-payload"));
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(buf))
}

/// Read one frame; `None` on a clean EOF before the length prefix (the
/// peer closed between frames), an error on a mid-frame truncation.
fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let n = r.read(&mut len[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            return Err(VszError::format("frame: truncated length prefix"));
        }
        got += n;
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(VszError::format(format!("frame: {n} bytes exceeds the 1 GiB frame cap")));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(Some(buf))
}

// ---------------------------------------------------------------------------
// client

/// Thin blocking client for the framed protocol; used by the integration
/// tests, the serve bench and `vsz serve --status`.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)? })
    }

    /// One request/response exchange; accumulates data frames until the
    /// terminal frame and returns `(payload, end-frame JSON)`.
    fn request(&mut self, op: u8, header: &str, body: &[u8]) -> Result<(Vec<u8>, String)> {
        let mut payload = Vec::with_capacity(5 + header.len() + body.len());
        payload.push(op);
        payload.extend_from_slice(&(header.len() as u32).to_le_bytes());
        payload.extend_from_slice(header.as_bytes());
        payload.extend_from_slice(body);
        write_frame(&mut self.stream, &payload)?;
        self.stream.flush()?;
        let mut data = Vec::new();
        loop {
            let frame = read_frame(&mut self.stream)?
                .ok_or_else(|| VszError::runtime("server closed the connection mid-response"))?;
            let (kind, rest) = frame
                .split_first()
                .ok_or_else(|| VszError::format("empty response frame"))?;
            match *kind {
                KIND_DATA => data.extend_from_slice(rest),
                KIND_END => return Ok((data, String::from_utf8_lossy(rest).into_owned())),
                KIND_ERROR => {
                    return Err(VszError::runtime(format!(
                        "server error: {}",
                        String::from_utf8_lossy(rest)
                    )))
                }
                KIND_BUSY => {
                    return Err(VszError::runtime(format!(
                        "server busy: {}",
                        String::from_utf8_lossy(rest)
                    )))
                }
                other => {
                    return Err(VszError::format(format!("unknown response frame kind {other}")))
                }
            }
        }
    }

    /// Compress `samples` (row-major, dims like `"512x512"`) under an
    /// absolute error bound; returns the container bytes and the
    /// per-request stats JSON.
    pub fn compress(
        &mut self,
        name: &str,
        dims: &str,
        eb: f64,
        chunk_rows: usize,
        samples: &[f32],
    ) -> Result<(Vec<u8>, String)> {
        let mut body = Vec::with_capacity(samples.len() * 4);
        for x in samples {
            body.extend_from_slice(&x.to_le_bytes());
        }
        let hdr = format!(
            "{{\"name\":\"{name}\",\"dims\":\"{dims}\",\"eb\":{eb},\"chunk_rows\":{chunk_rows}}}"
        );
        self.request(OP_COMPRESS, &hdr, &body)
    }

    /// Decompress a container back to its samples.
    pub fn decompress(&mut self, container: &[u8]) -> Result<(Vec<f32>, String)> {
        let (bytes, end) = self.request(OP_DECOMPRESS, "{}", container)?;
        Ok((bytes_to_f32(&bytes)?, end))
    }

    /// Random-access extract of rows `lo..hi` from an indexed (v3)
    /// container.
    pub fn extract(
        &mut self,
        container: &[u8],
        lo: usize,
        hi: usize,
    ) -> Result<(Vec<f32>, String)> {
        let hdr = format!("{{\"rows\":[{lo},{hi}]}}");
        let (bytes, end) = self.request(OP_EXTRACT, &hdr, container)?;
        Ok((bytes_to_f32(&bytes)?, end))
    }

    /// Lifetime server statistics as a JSON string.
    pub fn stats(&mut self) -> Result<String> {
        Ok(self.request(OP_STATS, "{}", &[])?.1)
    }

    /// Ask the server to stop accepting and drain.
    pub fn shutdown(&mut self) -> Result<()> {
        self.request(OP_SHUTDOWN, "{}", &[]).map(|_| ())
    }

    /// Run `f` against this client, retrying transient `busy`/timeout
    /// rejections (see [`is_retryable`]) under `policy`'s capped
    /// exponential backoff + jitter. Hard errors and exhausted retries
    /// propagate the last error unchanged. The connection stays usable
    /// across `busy` rejections, so retries reuse it.
    pub fn with_retry<T>(
        &mut self,
        policy: &RetryPolicy,
        mut f: impl FnMut(&mut Client) -> Result<T>,
    ) -> Result<T> {
        // cheap decorrelation seed; exactness is irrelevant, only spread
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64 ^ d.as_secs())
            .unwrap_or(0x9E3779B97F4A7C15);
        let mut rng = crate::util::prng::Pcg32::seeded(seed);
        let mut attempt = 0u32;
        loop {
            match f(self) {
                Ok(v) => return Ok(v),
                Err(e) if is_retryable(&e) && attempt < policy.max_retries => {
                    let mut delay = policy.delay(attempt, rng.next_f32() as f64);
                    // a server-sent retry_after_ms is a floor, not a
                    // replacement: the server knows its own load better
                    // than our blind exponential schedule does
                    if let Some(ms) = busy_retry_after_ms(&e) {
                        delay = delay.max(Duration::from_millis(ms));
                    }
                    thread::sleep(delay);
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
}

fn bytes_to_f32(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return Err(VszError::format("response body is not a whole number of f32s"));
    }
    Ok(bytes.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
}

/// True when `e` is an admission-control rejection (retry with backoff)
/// rather than a hard failure. Deadline-cancelled requests reply on the
/// same `busy` channel, so they are also recognized here.
pub fn is_busy(e: &VszError) -> bool {
    matches!(e, VszError::Runtime(m) if m.starts_with("server busy"))
}

/// The `retry_after_ms` backoff hint carried by a structured `busy`
/// rejection, if any. Pre-hint servers send plain-text busy reasons;
/// those (and every non-busy error) return `None`, so callers fall back
/// to their own schedule.
pub fn busy_retry_after_ms(e: &VszError) -> Option<u64> {
    let VszError::Runtime(m) = e else { return None };
    let body = m.strip_prefix("server busy: ")?;
    let j = json::parse(body).ok()?;
    j.get("retry_after_ms")?.as_usize().map(|v| v as u64)
}

/// True when `e` is a socket-level timeout (the peer stalled, or a client
/// read/write timeout fired locally).
pub fn is_timeout(e: &VszError) -> bool {
    matches!(e, VszError::Io(io) if would_block(io))
}

/// True for the transient error class [`Client::with_retry`] retries:
/// admission/deadline `busy` rejections and socket timeouts.
pub fn is_retryable(e: &VszError) -> bool {
    is_busy(e) || is_timeout(e)
}

/// Bounded retry with capped exponential backoff + jitter for transient
/// `busy`/timeout rejections.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = a single attempt).
    pub max_retries: u32,
    /// First backoff delay; doubles per retry.
    pub base_delay: Duration,
    /// Backoff ceiling.
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_retries: 5,
            base_delay: Duration::from_millis(25),
            max_delay: Duration::from_secs(2),
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (0-based): `base * 2^attempt`,
    /// capped, with up to +50% multiplicative jitter so a herd of
    /// rejected clients does not retry in lockstep.
    fn delay(&self, attempt: u32, jitter: f64) -> Duration {
        let exp = self.base_delay.saturating_mul(1u32 << attempt.min(16));
        let capped = exp.min(self.max_delay);
        capped.mul_f64(1.0 + 0.5 * jitter.clamp(0.0, 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_kind_frame(&mut buf, KIND_END, b"{}").unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), [&[KIND_END][..], b"{}"].concat());
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF between frames");
    }

    #[test]
    fn truncated_length_prefix_is_an_error() {
        let mut r = Cursor::new(vec![5u8, 0]);
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn admission_gauge_rejects_and_releases() {
        let shared = Shared {
            cfg: ServeConfig { max_inflight_bytes: 100, ..ServeConfig::default() },
            addr: "127.0.0.1:0".parse().unwrap(),
            pool: Arc::new(ThreadPool::new(1)),
            cache: Arc::new(ChunkCache::new(0)),
            inflight: AtomicU64::new(0),
            active_conns: AtomicUsize::new(0),
            stats: Mutex::new(CompressionStats::new()),
            stop: AtomicBool::new(false),
            started: Instant::now(),
        };
        let a = admit(&shared, 60).expect("fits");
        assert!(admit(&shared, 60).is_none(), "would exceed the cap");
        assert_eq!(shared.inflight.load(Ordering::SeqCst), 60, "reject must not leak budget");
        drop(a);
        assert_eq!(shared.inflight.load(Ordering::SeqCst), 0);
        let b = admit(&shared, 100).expect("exact fit admits");
        drop(b);
    }

    #[test]
    fn busy_errors_are_recognizable() {
        assert!(is_busy(&VszError::runtime("server busy: cap")));
        assert!(!is_busy(&VszError::runtime("server error: boom")));
    }

    #[test]
    fn retryable_classification_covers_busy_and_timeouts() {
        assert!(is_retryable(&VszError::runtime("server busy: cap")));
        let t = std::io::Error::new(std::io::ErrorKind::WouldBlock, "stall");
        assert!(is_retryable(&VszError::Io(t)));
        let t = std::io::Error::new(std::io::ErrorKind::TimedOut, "stall");
        assert!(is_timeout(&VszError::Io(t)));
        assert!(!is_retryable(&VszError::runtime("server error: boom")));
        assert!(!is_retryable(&VszError::format("bad frame")));
    }

    #[test]
    fn retry_policy_backoff_is_capped_and_jittered() {
        let p = RetryPolicy::default();
        assert_eq!(p.delay(0, 0.0), Duration::from_millis(25));
        assert_eq!(p.delay(3, 0.0), Duration::from_millis(200));
        assert_eq!(p.delay(30, 0.0), Duration::from_secs(2), "exponent must cap, not overflow");
        assert_eq!(p.delay(1, 1.0), Duration::from_millis(75));
        assert_eq!(p.delay(2, 7.5), Duration::from_millis(150), "jitter factor clamps to [0,1]");
    }

    fn test_shared(cap: u64) -> Shared {
        Shared {
            cfg: ServeConfig { max_inflight_bytes: cap, ..ServeConfig::default() },
            addr: "127.0.0.1:0".parse().unwrap(),
            pool: Arc::new(ThreadPool::new(1)),
            cache: Arc::new(ChunkCache::new(0)),
            inflight: AtomicU64::new(0),
            active_conns: AtomicUsize::new(0),
            stats: Mutex::new(CompressionStats::new()),
            stop: AtomicBool::new(false),
            started: Instant::now(),
        }
    }

    #[test]
    fn busy_payload_scales_hint_with_load_and_escapes_reason() {
        let shared = test_shared(100);
        let idle = json::parse(&busy_payload(&shared, "cap\nhit")).unwrap();
        assert_eq!(idle.get("retry_after_ms").unwrap().as_usize(), Some(50));
        assert_eq!(idle.get("busy").unwrap().as_str(), Some("cap\nhit"));
        shared.inflight.store(100, Ordering::SeqCst);
        let full = json::parse(&busy_payload(&shared, "cap")).unwrap();
        assert_eq!(full.get("retry_after_ms").unwrap().as_usize(), Some(500));
        // load saturates at the cap — an oversized reject can't push the
        // hint past the full-load value
        shared.inflight.store(1_000_000, Ordering::SeqCst);
        let over = json::parse(&busy_payload(&shared, "cap")).unwrap();
        assert_eq!(over.get("retry_after_ms").unwrap().as_usize(), Some(500));
    }

    #[test]
    fn busy_hint_parses_from_structured_replies_only() {
        let hinted =
            VszError::runtime("server busy: {\"busy\":\"cap\",\"retry_after_ms\":120}");
        assert!(is_busy(&hinted), "structured replies stay in the busy class");
        assert_eq!(busy_retry_after_ms(&hinted), Some(120));
        // pre-hint plain-text reasons and non-busy errors carry no hint
        assert_eq!(busy_retry_after_ms(&VszError::runtime("server busy: cap")), None);
        assert_eq!(busy_retry_after_ms(&VszError::runtime("server error: boom")), None);
        assert_eq!(busy_retry_after_ms(&VszError::format("bad frame")), None);
    }

    #[test]
    fn with_retry_floors_backoff_at_the_server_hint() {
        // loopback listener only exists so a Client can be constructed;
        // the closures never touch the socket
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut c = Client::connect(&listener.local_addr().unwrap().to_string()).unwrap();
        let policy = RetryPolicy {
            max_retries: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(4),
        };
        let t = Instant::now();
        let mut calls = 0u32;
        let err = c
            .with_retry(&policy, |_| -> Result<()> {
                calls += 1;
                Err(VszError::runtime(
                    "server busy: {\"busy\":\"cap\",\"retry_after_ms\":80}",
                ))
            })
            .unwrap_err();
        assert!(is_busy(&err));
        assert_eq!(calls, 3, "initial attempt + max_retries");
        let hinted = t.elapsed();
        assert!(
            hinted >= Duration::from_millis(160),
            "two sleeps floored at the 80 ms hint, got {hinted:?}"
        );
        // the same policy against a hint-less busy reply sleeps only the
        // policy schedule (≤ ~9 ms with full jitter) — far under the floor
        let t = Instant::now();
        let _ = c
            .with_retry(&policy, |_| -> Result<()> {
                Err(VszError::runtime("server busy: cap"))
            })
            .unwrap_err();
        let legacy = t.elapsed();
        assert!(
            legacy < Duration::from_millis(120),
            "hint-less backoff must not inherit the floor, got {legacy:?}"
        );
    }

    #[test]
    fn request_ctx_deadline_expiry() {
        let ctx = RequestCtx::new(0);
        assert!(ctx.deadline.is_none() && !ctx.expired(), "0 disables the deadline");
        let ctx = RequestCtx::new(1);
        std::thread::sleep(Duration::from_millis(5));
        assert!(ctx.expired());
        assert!(!ctx.cancel.is_cancelled(), "expiry alone does not flip the token");
    }
}

//! Block-padding policies (§IV of the paper).
//!
//! Values without preceding neighbours (block borders) are predicted from
//! the padding scalar. The original SZ/cuSZ use zero padding; vecSZ's
//! contribution is choosing a *statistical* padding value (min/max/avg) at
//! one of three granularities (global / per-block / per-edge), trading
//! scalar-storage overhead against border predictability.

use crate::blocks::{Dims, gather_block};

/// Which statistic supplies the padding scalar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PadValue {
    /// Constant 0 — the cuSZ baseline.
    Zero,
    Min,
    Max,
    Avg,
}

/// At what granularity scalars are computed & stored (§IV-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PadGranularity {
    /// One scalar for the whole field (1 extra value stored).
    Global,
    /// One scalar per block (`nblocks` extra values).
    Block,
    /// One scalar per block border axis (`nblocks * ndim` extra values).
    Edge,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PaddingPolicy {
    pub value: PadValue,
    pub granularity: PadGranularity,
}

impl PaddingPolicy {
    pub const ZERO: PaddingPolicy =
        PaddingPolicy { value: PadValue::Zero, granularity: PadGranularity::Global };

    pub fn new(value: PadValue, granularity: PadGranularity) -> Self {
        Self { value, granularity }
    }

    /// Parse "zero", "avg-global", "min-block", "max-edge", ...
    pub fn parse(s: &str) -> Option<Self> {
        if s == "zero" {
            return Some(Self::ZERO);
        }
        let (v, g) = s.split_once('-')?;
        let value = match v {
            "zero" => PadValue::Zero,
            "min" => PadValue::Min,
            "max" => PadValue::Max,
            "avg" => PadValue::Avg,
            _ => return None,
        };
        let granularity = match g {
            "global" => PadGranularity::Global,
            "block" => PadGranularity::Block,
            "edge" => PadGranularity::Edge,
            _ => return None,
        };
        Some(Self { value, granularity })
    }

    /// The policy as stored in containers: zero padding normalizes to
    /// Global granularity (one scalar), matching what [`compute_scalars`]
    /// produces — the decompressor indexes scalars by the stored policy, so
    /// the two must agree.
    pub fn normalized(&self) -> Self {
        if self.value == PadValue::Zero {
            Self::ZERO
        } else {
            *self
        }
    }

    pub fn name(&self) -> String {
        let v = match self.value {
            PadValue::Zero => "zero",
            PadValue::Min => "min",
            PadValue::Max => "max",
            PadValue::Avg => "avg",
        };
        let g = match self.granularity {
            PadGranularity::Global => "global",
            PadGranularity::Block => "block",
            PadGranularity::Edge => "edge",
        };
        if self.value == PadValue::Zero {
            "zero".to_string()
        } else {
            format!("{v}-{g}")
        }
    }
}

/// Computed padding scalars for one field; stored in the container so the
/// decompressor reproduces predictions exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct PadScalars {
    pub policy: PaddingPolicy,
    /// Global: 1 scalar. Block: nblocks. Edge: nblocks * ndim (axis-major
    /// within block: `scalars[b * ndim + axis]`).
    pub scalars: Vec<f32>,
    pub ndim: usize,
}

impl PadScalars {
    /// Scalar used to gather-fill and (for Global/Block) all halo planes of
    /// block `b`.
    #[inline]
    pub fn block_scalar(&self, b: usize) -> f32 {
        match self.policy.granularity {
            PadGranularity::Global => self.scalars[0],
            PadGranularity::Block => self.scalars[b],
            // edge granularity: representative = axis-0 scalar
            PadGranularity::Edge => self.scalars[b * self.ndim],
        }
    }

    /// Scalar for the halo plane orthogonal to `axis` of block `b`.
    #[inline]
    pub fn edge_scalar(&self, b: usize, axis: usize) -> f32 {
        match self.policy.granularity {
            PadGranularity::Global => self.scalars[0],
            PadGranularity::Block => self.scalars[b],
            PadGranularity::Edge => self.scalars[b * self.ndim + axis],
        }
    }

    /// Storage overhead in raw f32 values (Table in §IV-B).
    pub fn storage_values(&self) -> usize {
        self.scalars.len()
    }
}

fn stat(value: PadValue, xs: &[f32]) -> f32 {
    match value {
        PadValue::Zero => 0.0,
        PadValue::Min => xs.iter().copied().fold(f32::INFINITY, f32::min),
        PadValue::Max => xs.iter().copied().fold(f32::NEG_INFINITY, f32::max),
        PadValue::Avg => {
            // f64 accumulator: stable for large fields
            (xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len().max(1) as f64) as f32
        }
    }
}

/// Statistic over the border hyperplane of a gathered block orthogonal to
/// `axis` (the elements the halo plane predicts).
fn edge_stat(value: PadValue, block: &[f32], bs: usize, ndim: usize, axis: usize) -> f32 {
    let mut vals: Vec<f32> = Vec::with_capacity(bs * bs);
    match ndim {
        1 => vals.push(block[0]),
        2 => match axis {
            0 => vals.extend_from_slice(&block[..bs]), // first row
            _ => vals.extend((0..bs).map(|i| block[i * bs])), // first col
        },
        3 => match axis {
            0 => vals.extend_from_slice(&block[..bs * bs]), // first plane
            1 => vals.extend((0..bs).flat_map(|k| (0..bs).map(move |j| (k * bs) * bs + j)).map(|i| block[i])),
            _ => vals.extend((0..bs).flat_map(|k| (0..bs).map(move |i| (k * bs + i) * bs)).map(|i| block[i])),
        },
        _ => unreachable!(),
    }
    stat(value, &vals)
}

/// Compute padding scalars for `field` under `policy`.
pub fn compute_scalars(field: &[f32], dims: &Dims, bs: usize, policy: PaddingPolicy) -> PadScalars {
    let ndim = dims.ndim;
    let scalars = match (policy.value, policy.granularity) {
        (PadValue::Zero, _) => vec![0.0],
        (v, PadGranularity::Global) => vec![stat(v, field)],
        (v, PadGranularity::Block) => {
            let nb = dims.num_blocks(bs);
            let mut out = Vec::with_capacity(nb);
            let mut block = vec![0.0f32; bs.pow(ndim as u32)];
            for b in 0..nb {
                // fill value irrelevant for stats over valid region only:
                // gather with NAN then filter
                gather_block(field, dims, bs, b, f32::NAN, &mut block);
                let valid: Vec<f32> = block.iter().copied().filter(|x| !x.is_nan()).collect();
                out.push(stat(v, &valid));
            }
            out
        }
        (v, PadGranularity::Edge) => {
            let nb = dims.num_blocks(bs);
            let mut out = Vec::with_capacity(nb * ndim);
            let mut block = vec![0.0f32; bs.pow(ndim as u32)];
            for b in 0..nb {
                gather_block(field, dims, bs, b, f32::NAN, &mut block);
                // NaNs (out-of-field) replaced by block mean of valid region
                let valid: Vec<f32> = block.iter().copied().filter(|x| !x.is_nan()).collect();
                let fallback = stat(PadValue::Avg, &valid);
                let clean: Vec<f32> =
                    block.iter().map(|&x| if x.is_nan() { fallback } else { x }).collect();
                for axis in 0..ndim {
                    out.push(edge_stat(v, &clean, bs, ndim, axis));
                }
            }
            out
        }
    };
    PadScalars { policy: policy.normalized(), scalars, ndim }
}

/// All policies of the paper's padding study (§IV/§V-I grid).
pub fn study_policies() -> Vec<PaddingPolicy> {
    let mut v = vec![PaddingPolicy::ZERO];
    for value in [PadValue::Min, PadValue::Max, PadValue::Avg] {
        for gran in [PadGranularity::Global, PadGranularity::Block, PadGranularity::Edge] {
            v.push(PaddingPolicy::new(value, gran));
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn field_2d() -> (Vec<f32>, Dims) {
        // 4x4 ramp offset by 50 (non-zero-centred, like CESM in Fig 2)
        let f: Vec<f32> = (0..16).map(|x| 50.0 + x as f32).collect();
        (f, Dims::d2(4, 4))
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for p in study_policies() {
            assert_eq!(PaddingPolicy::parse(&p.name()), Some(p));
        }
        assert_eq!(PaddingPolicy::parse("zero"), Some(PaddingPolicy::ZERO));
        assert_eq!(PaddingPolicy::parse("bogus"), None);
        assert_eq!(PaddingPolicy::parse("avg-bogus"), None);
    }

    #[test]
    fn global_scalars() {
        let (f, dims) = field_2d();
        let s = compute_scalars(&f, &dims, 2, PaddingPolicy::new(PadValue::Avg, PadGranularity::Global));
        assert_eq!(s.scalars.len(), 1);
        assert!((s.scalars[0] - 57.5).abs() < 1e-4);
        let s = compute_scalars(&f, &dims, 2, PaddingPolicy::new(PadValue::Min, PadGranularity::Global));
        assert_eq!(s.scalars[0], 50.0);
        let s = compute_scalars(&f, &dims, 2, PaddingPolicy::new(PadValue::Max, PadGranularity::Global));
        assert_eq!(s.scalars[0], 65.0);
    }

    #[test]
    fn block_scalars_ignore_out_of_field() {
        // 3x3 field, bs=2: corner block has 1 valid element = 8+50
        let f: Vec<f32> = (0..9).map(|x| 50.0 + x as f32).collect();
        let dims = Dims::d2(3, 3);
        let s = compute_scalars(&f, &dims, 2, PaddingPolicy::new(PadValue::Avg, PadGranularity::Block));
        assert_eq!(s.scalars.len(), 4);
        assert_eq!(s.block_scalar(3), 58.0);
    }

    #[test]
    fn edge_scalars_shape_and_values() {
        let (f, dims) = field_2d();
        let s = compute_scalars(&f, &dims, 2, PaddingPolicy::new(PadValue::Avg, PadGranularity::Edge));
        assert_eq!(s.scalars.len(), 4 * 2);
        // block 0 = [[50,51],[54,55]]: axis0 edge (first row) avg = 50.5,
        // axis1 edge (first col) avg = 52
        assert!((s.edge_scalar(0, 0) - 50.5).abs() < 1e-5);
        assert!((s.edge_scalar(0, 1) - 52.0).abs() < 1e-5);
    }

    #[test]
    fn zero_policy_single_scalar() {
        let (f, dims) = field_2d();
        let s = compute_scalars(&f, &dims, 2, PaddingPolicy::new(PadValue::Zero, PadGranularity::Edge));
        assert_eq!(s.scalars, vec![0.0]);
        assert_eq!(s.block_scalar(3), 0.0);
        assert_eq!(s.edge_scalar(2, 1), 0.0);
    }

    #[test]
    fn storage_overhead_ordering() {
        // paper §IV-B: global < block < edge overhead
        let (f, dims) = field_2d();
        let g = compute_scalars(&f, &dims, 2, PaddingPolicy::new(PadValue::Avg, PadGranularity::Global));
        let b = compute_scalars(&f, &dims, 2, PaddingPolicy::new(PadValue::Avg, PadGranularity::Block));
        let e = compute_scalars(&f, &dims, 2, PaddingPolicy::new(PadValue::Avg, PadGranularity::Edge));
        assert!(g.storage_values() < b.storage_values());
        assert!(b.storage_values() < e.storage_values());
    }

    #[test]
    fn study_grid_size() {
        // zero + 3 values x 3 granularities
        assert_eq!(study_policies().len(), 10);
    }
}

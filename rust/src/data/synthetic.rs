//! Synthetic SDRBench-like suites (Table II substitution).
//!
//! We do not have the proprietary SDRBench downloads in this environment,
//! so each suite is generated to match the *character that drives
//! prediction/quantization behaviour* of its namesake: dimensionality,
//! value range, large-scale smoothness vs small-scale roughness, zero
//! fraction and outlier structure (see DESIGN.md §Substitutions):
//!
//! * **HACC** (1D particles): positions = sorted cluster centres + jitter
//!   (piecewise-smooth as a stream); velocities = Gaussian mixtures.
//! * **CESM-ATM** (2D climate): cloud fraction in [0,1] with flat zero
//!   decks + fronts; TS-like field offset ~270 K (the non-zero-centred
//!   field of Fig 2 that motivates alternative padding).
//! * **Hurricane** (3D climate): vortex wind field + smooth thermodynamic
//!   fields.
//! * **NYX** (3D cosmology): log-normal baryon density (heavy tailed!),
//!   smooth temperature, filamentary velocity.
//! * **QMCPACK** (3D quantum): oscillatory orbitals under a Gaussian
//!   envelope.

use super::{noise::fbm, Dataset, Field, Scale};
use crate::blocks::Dims;
use crate::util::prng::Pcg32;

fn scaled(scale: Scale, small: [usize; 3], full: [usize; 3], ndim: usize) -> Dims {
    let s = match scale {
        Scale::Small => small,
        Scale::Full => full,
    };
    Dims { shape: s, ndim }
}

/// HACC-like 1D particle suite: 6 fields (xx, yy, zz, vx, vy, vz).
pub fn hacc(scale: Scale, seed: u64) -> Dataset {
    let n = match scale {
        Scale::Small => 1 << 21,      // 2 Mi particles, 8 MB/field
        Scale::Full => 280_953_867,   // Table II
    };
    let dims = Dims::d1(n);
    let box_size = 256.0f32;
    let n_clusters = (n / 4096).max(8);

    let mut fields = Vec::new();
    for (fi, name) in ["xx", "yy", "zz"].iter().enumerate() {
        let mut r = Pcg32::seeded(seed.wrapping_add(fi as u64));
        // cluster centres; particles appear cluster-by-cluster (as HACC's
        // rank-ordered output does), giving a piecewise-clustered stream.
        let centres: Vec<f32> = (0..n_clusters).map(|_| r.next_f32() * box_size).collect();
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            let c = centres[(i * n_clusters) / n];
            let jitter = r.next_normal() * 2.5;
            data.push((c + jitter).rem_euclid(box_size));
        }
        fields.push(Field::new(*name, dims, data));
    }
    for (fi, name) in ["vx", "vy", "vz"].iter().enumerate() {
        let mut r = Pcg32::seeded(seed.wrapping_add(100 + fi as u64));
        // Gaussian mixture: bulk flow per cluster + thermal spread
        let flows: Vec<f32> = (0..n_clusters).map(|_| r.next_normal() * 300.0).collect();
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            let f = flows[(i * n_clusters) / n];
            data.push(f + r.next_normal() * 120.0);
        }
        fields.push(Field::new(*name, dims, data));
    }
    Dataset { name: "hacc".into(), fields, default_eb: 1e-4 }
}

/// CESM-ATM-like 2D climate suite: 3 fields.
pub fn cesm(scale: Scale, seed: u64) -> Dataset {
    let dims = scaled(scale, [900, 1800, 1], [1800, 3600, 1], 2);
    let (nr, nc) = (dims.shape[0], dims.shape[1]);
    let mut fields = Vec::new();

    // CLDHGH: cloud fraction in [0, 1]; decks (plateaus at 0/1) + fronts.
    {
        let mut data = Vec::with_capacity(nr * nc);
        for i in 0..nr {
            for j in 0..nc {
                let p = [j as f32 / nc as f32 * 24.0, i as f32 / nr as f32 * 12.0, 0.0];
                let v = fbm(seed ^ 0xC1D, p, 5, 0.55) * 1.4 + 0.3;
                data.push(v.clamp(0.0, 1.0));
            }
        }
        fields.push(Field::new("CLDHGH", dims, data));
    }
    // TS: surface temperature, 230–310 K — the offset field of Fig 2.
    {
        let mut data = Vec::with_capacity(nr * nc);
        for i in 0..nr {
            for j in 0..nc {
                let lat = (i as f32 / nr as f32 - 0.5) * std::f32::consts::PI;
                let base = 287.0 - 55.0 * lat.sin().powi(2);
                let p = [j as f32 / nc as f32 * 16.0, i as f32 / nr as f32 * 8.0, 0.0];
                data.push(base + 8.0 * fbm(seed ^ 0x75, p, 4, 0.5));
            }
        }
        fields.push(Field::new("TS", dims, data));
    }
    // FSNTOA: net solar flux, 0–420 with sharp cloud shadows.
    {
        let mut data = Vec::with_capacity(nr * nc);
        for i in 0..nr {
            for j in 0..nc {
                let lat = (i as f32 / nr as f32 - 0.5) * std::f32::consts::PI;
                let insol = 340.0 * lat.cos().max(0.0);
                let p = [j as f32 / nc as f32 * 24.0, i as f32 / nr as f32 * 12.0, 0.0];
                let cloud = (fbm(seed ^ 0xF50, p, 5, 0.55) * 1.4 + 0.3).clamp(0.0, 1.0);
                data.push(insol * (1.0 - 0.7 * cloud));
            }
        }
        fields.push(Field::new("FSNTOA", dims, data));
    }
    Dataset { name: "cesm".into(), fields, default_eb: 1e-5 }
}

/// Hurricane-Isabel-like 3D suite: wind speed (vortex), temperature,
/// pressure.
pub fn hurricane(scale: Scale, seed: u64) -> Dataset {
    let dims = scaled(scale, [25, 250, 250], [100, 500, 500], 3);
    let (np, nr, nc) = (dims.shape[0], dims.shape[1], dims.shape[2]);
    let mut fields = Vec::new();
    let eye = (nr as f32 * 0.5, nc as f32 * 0.55);

    // Uf: tangential wind of a vortex + turbulence.
    {
        let mut data = Vec::with_capacity(np * nr * nc);
        for k in 0..np {
            let height = k as f32 / np as f32;
            for i in 0..nr {
                for j in 0..nc {
                    let dy = i as f32 - eye.0;
                    let dx = j as f32 - eye.1;
                    let r = (dx * dx + dy * dy).sqrt() + 4.0;
                    // Rankine-like vortex profile
                    let vmax = 65.0 * (1.0 - 0.6 * height);
                    let rm = 22.0;
                    let vt = if r < rm { vmax * r / rm } else { vmax * (rm / r).powf(0.6) };
                    let swirl = -dy / r * vt;
                    let p = [j as f32 / 24.0, i as f32 / 24.0, k as f32 / 6.0];
                    data.push(swirl + 6.0 * fbm(seed ^ 0x0F, p, 4, 0.5));
                }
            }
        }
        fields.push(Field::new("Uf", dims, data));
    }
    // TCf: temperature, decreasing with height, warm core.
    {
        let mut data = Vec::with_capacity(np * nr * nc);
        for k in 0..np {
            let lapse = 25.0 - 70.0 * (k as f32 / np as f32);
            for i in 0..nr {
                for j in 0..nc {
                    let dy = i as f32 - eye.0;
                    let dx = j as f32 - eye.1;
                    let r2 = dx * dx + dy * dy;
                    let core = 6.0 * (-r2 / 800.0).exp();
                    let p = [j as f32 / 32.0, i as f32 / 32.0, k as f32 / 8.0];
                    data.push(lapse + core + 1.5 * fbm(seed ^ 0x7C, p, 4, 0.5));
                }
            }
        }
        fields.push(Field::new("TCf", dims, data));
    }
    // Pf: pressure perturbation — very smooth, low at the eye.
    {
        let mut data = Vec::with_capacity(np * nr * nc);
        for k in 0..np {
            for i in 0..nr {
                for j in 0..nc {
                    let dy = i as f32 - eye.0;
                    let dx = j as f32 - eye.1;
                    let r2 = dx * dx + dy * dy;
                    let dip = -4500.0 * (-r2 / 3000.0).exp() * (1.0 - k as f32 / np as f32);
                    let p = [j as f32 / 64.0, i as f32 / 64.0, k as f32 / 12.0];
                    data.push(dip + 300.0 * fbm(seed ^ 0x9F, p, 3, 0.5));
                }
            }
        }
        fields.push(Field::new("Pf", dims, data));
    }
    Dataset { name: "hurricane".into(), fields, default_eb: 1e-4 }
}

/// NYX-like 3D cosmology suite.
pub fn nyx(scale: Scale, seed: u64) -> Dataset {
    let dims = scaled(scale, [96, 96, 96], [512, 512, 512], 3);
    let (np, nr, nc) = (dims.shape[0], dims.shape[1], dims.shape[2]);
    let mut fields = Vec::new();

    // baryon_density: exp of a smooth Gaussian field -> log-normal with
    // heavy tails (the hardest SDRBench field for SZ).
    {
        let mut data = Vec::with_capacity(np * nr * nc);
        for k in 0..np {
            for i in 0..nr {
                for j in 0..nc {
                    let p = [j as f32 / 12.0, i as f32 / 12.0, k as f32 / 12.0];
                    let g = fbm(seed ^ 0xBA, p, 5, 0.6);
                    data.push((3.2 * g).exp() * 1.2e8);
                }
            }
        }
        fields.push(Field::new("baryon_density", dims, data));
    }
    // temperature: smooth, correlated with density.
    {
        let mut data = Vec::with_capacity(np * nr * nc);
        for k in 0..np {
            for i in 0..nr {
                for j in 0..nc {
                    let p = [j as f32 / 12.0, i as f32 / 12.0, k as f32 / 12.0];
                    let g = fbm(seed ^ 0xBA, p, 4, 0.55);
                    data.push(1.0e4 * (1.0 + 1.5 * g).max(0.05));
                }
            }
        }
        fields.push(Field::new("temperature", dims, data));
    }
    // velocity_x: large-scale flows.
    {
        let mut data = Vec::with_capacity(np * nr * nc);
        for k in 0..np {
            for i in 0..nr {
                for j in 0..nc {
                    let p = [j as f32 / 20.0, i as f32 / 20.0, k as f32 / 20.0];
                    data.push(3.0e7 * fbm(seed ^ 0x7E, p, 4, 0.5));
                }
            }
        }
        fields.push(Field::new("velocity_x", dims, data));
    }
    Dataset { name: "nyx".into(), fields, default_eb: 1e-4 }
}

/// QMCPACK-like 3D suite: oscillatory einspline orbitals.
pub fn qmcpack(scale: Scale, seed: u64) -> Dataset {
    // full-scale note: the real layout is 288x115x69x69 (4D, Table II); we
    // fold the two trailing spatial axes (69*69 = 4761) to stay 3D, which
    // preserves the per-orbital oscillatory structure the predictor sees.
    let dims = scaled(scale, [64, 69, 69], [288, 115, 4761], 3);
    let (np, nr, nc) = (dims.shape[0], dims.shape[1], dims.shape[2]);
    let mut fields = Vec::new();
    for (fi, name) in ["einspline_real", "einspline_imag"].iter().enumerate() {
        let phase0 = if fi == 0 { 0.0 } else { std::f32::consts::FRAC_PI_2 };
        let mut data = Vec::with_capacity(np * nr * nc);
        for k in 0..np {
            for i in 0..nr {
                for j in 0..nc {
                    let (x, y, z) =
                        (j as f32 / nc as f32, i as f32 / nr as f32, k as f32 / np as f32);
                    // plane-wave-like oscillation under a soft envelope
                    let osc = (14.0 * x + 9.0 * y + 6.0 * z + phase0).sin()
                        * (11.0 * y - 4.0 * x).cos();
                    let env = (-((x - 0.5).powi(2) + (y - 0.5).powi(2) + (z - 0.5).powi(2)) * 4.0)
                        .exp();
                    let p = [x * 30.0, y * 30.0, z * 30.0];
                    data.push(osc * env + 0.02 * fbm(seed ^ 0x0AC, p, 3, 0.5));
                }
            }
        }
        fields.push(Field::new(*name, dims, data));
    }
    Dataset { name: "qmcpack".into(), fields, default_eb: 1e-4 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_have_expected_shapes_small() {
        let h = hacc(Scale::Small, 1);
        assert_eq!(h.fields.len(), 6);
        assert_eq!(h.fields[0].dims.ndim, 1);
        let c = cesm(Scale::Small, 1);
        assert_eq!(c.fields.len(), 3);
        assert_eq!(c.fields[0].dims.ndim, 2);
        for d in [hurricane(Scale::Small, 1), nyx(Scale::Small, 1), qmcpack(Scale::Small, 1)] {
            assert_eq!(d.ndim(), 3, "{}", d.name);
            assert!(!d.fields.is_empty());
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = cesm(Scale::Small, 7);
        let b = cesm(Scale::Small, 7);
        assert_eq!(a.fields[0].data[..100], b.fields[0].data[..100]);
        let c = cesm(Scale::Small, 8);
        assert_ne!(a.fields[0].data[..100], c.fields[0].data[..100]);
    }

    #[test]
    fn cesm_cloud_fraction_in_unit_interval() {
        let d = cesm(Scale::Small, 3);
        let cld = &d.fields[0];
        assert!(cld.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // decks: a meaningful share of exact 0/1 plateaus
        let flat = cld.data.iter().filter(|&&v| v == 0.0 || v == 1.0).count();
        assert!(flat > cld.data.len() / 50, "flat fraction {}", flat);
    }

    #[test]
    fn cesm_ts_is_offset_like_fig2() {
        let d = cesm(Scale::Small, 3);
        let ts = &d.fields[1];
        let mean = ts.data.iter().map(|&x| x as f64).sum::<f64>() / ts.data.len() as f64;
        assert!(mean > 200.0, "TS mean {mean} should be far from zero");
    }

    #[test]
    fn nyx_density_heavy_tailed() {
        let d = nyx(Scale::Small, 5);
        let rho = &d.fields[0];
        let mean = rho.data.iter().map(|&x| x as f64).sum::<f64>() / rho.data.len() as f64;
        let max = rho.data.iter().copied().fold(0.0f32, f32::max) as f64;
        assert!(max / mean > 10.0, "log-normal tail expected: max/mean {}", max / mean);
        assert!(rho.data.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn hacc_positions_within_box() {
        let d = hacc(Scale::Small, 2);
        for f in &d.fields[..3] {
            assert!(f.data.iter().all(|&x| (0.0..=256.0).contains(&x)), "{}", f.name);
        }
    }
}

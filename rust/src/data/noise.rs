//! Lattice value-noise (fractal/multi-octave) in 1/2/3 dimensions —
//! substrate for the synthetic dataset generators.
//!
//! Deterministic: gradients come from hashing lattice coordinates with
//! SplitMix64, so a (seed, coordinate) pair always yields the same value.
//! Octave stacking gives the multi-scale smoothness of real scientific
//! fields (climate/cosmology data are smooth at large scales with
//! small-scale detail — exactly what Lorenzo prediction sees in SDRBench).

use crate::util::prng::mix64;

#[inline]
fn lattice(seed: u64, c: [i64; 3]) -> f32 {
    let h = mix64(
        seed ^ (c[0] as u64).wrapping_mul(0x8DA6B343)
            ^ (c[1] as u64).wrapping_mul(0xD8163841)
            ^ (c[2] as u64).wrapping_mul(0xCB1AB31F),
    );
    // map to [-1, 1)
    ((h >> 40) as f32) * (1.0 / (1u64 << 23) as f32) - 1.0
}

#[inline]
fn smoothstep(t: f32) -> f32 {
    t * t * (3.0 - 2.0 * t)
}

/// Single-octave trilinear value noise at continuous point `p` (unused
/// dimensions pass 0.0).
pub fn value_noise(seed: u64, p: [f32; 3]) -> f32 {
    let cell = [p[0].floor(), p[1].floor(), p[2].floor()];
    let f = [
        smoothstep(p[0] - cell[0]),
        smoothstep(p[1] - cell[1]),
        smoothstep(p[2] - cell[2]),
    ];
    let c = [cell[0] as i64, cell[1] as i64, cell[2] as i64];
    let mut acc = 0.0f32;
    for corner in 0..8u32 {
        let o = [(corner & 1) as i64, ((corner >> 1) & 1) as i64, ((corner >> 2) & 1) as i64];
        let w = (0..3).map(|a| if o[a] == 1 { f[a] } else { 1.0 - f[a] }).product::<f32>();
        acc += w * lattice(seed, [c[0] + o[0], c[1] + o[1], c[2] + o[2]]);
    }
    acc
}

/// Fractal (fBm) noise: `octaves` stacked value-noise layers, each at
/// double frequency and `gain` amplitude of the previous.
pub fn fbm(seed: u64, p: [f32; 3], octaves: u32, gain: f32) -> f32 {
    let mut amp = 1.0f32;
    let mut freq = 1.0f32;
    let mut acc = 0.0f32;
    let mut norm = 0.0f32;
    for o in 0..octaves {
        acc += amp * value_noise(seed.wrapping_add(o as u64 * 0x9E37), [p[0] * freq, p[1] * freq, p[2] * freq]);
        norm += amp;
        amp *= gain;
        freq *= 2.0;
    }
    acc / norm.max(f32::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = value_noise(7, [1.3, 2.7, 0.0]);
        let b = value_noise(7, [1.3, 2.7, 0.0]);
        assert_eq!(a, b);
        assert_ne!(a, value_noise(8, [1.3, 2.7, 0.0]));
    }

    #[test]
    fn bounded_output() {
        for i in 0..1000 {
            let p = [i as f32 * 0.173, i as f32 * 0.311, i as f32 * 0.057];
            let v = fbm(3, p, 5, 0.5);
            assert!(v.abs() <= 1.5, "fbm out of expected envelope: {v}");
        }
    }

    #[test]
    fn continuity_small_steps_small_changes() {
        // value noise must be continuous: eps steps move the value by O(eps)
        let mut prev = value_noise(11, [0.0, 0.5, 0.25]);
        for i in 1..=1000 {
            let x = i as f32 * 1e-3;
            let cur = value_noise(11, [x, 0.5, 0.25]);
            assert!((cur - prev).abs() < 0.05, "jump at {x}: {prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn lattice_agrees_at_integer_points() {
        // at integer coordinates the interpolation collapses to the lattice value
        let v = value_noise(5, [3.0, 4.0, 5.0]);
        let l = lattice(5, [3, 4, 5]);
        assert!((v - l).abs() < 1e-6);
    }
}

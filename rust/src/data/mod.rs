//! Datasets: field representation, raw-binary I/O and the synthetic
//! SDRBench-like suites (Table II substitution — see DESIGN.md).

pub mod io;
pub mod noise;
pub mod synthetic;

use crate::blocks::Dims;

/// One scalar field of a dataset (the unit SZ compresses).
#[derive(Clone, Debug)]
pub struct Field {
    pub name: String,
    pub dims: Dims,
    pub data: Vec<f32>,
}

impl Field {
    pub fn new(name: impl Into<String>, dims: Dims, data: Vec<f32>) -> Self {
        let f = Self { name: name.into(), dims, data };
        assert_eq!(f.dims.len(), f.data.len(), "dims/data mismatch for {}", f.name);
        f
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn size_mb(&self) -> f64 {
        self.size_bytes() as f64 / 1e6
    }
}

/// A named dataset = fields + the paper's error bound for it (§V-B: 1e-5
/// for CESM-ATM, 1e-4 for the rest).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub fields: Vec<Field>,
    pub default_eb: f64,
}

impl Dataset {
    pub fn total_bytes(&self) -> usize {
        self.fields.iter().map(|f| f.size_bytes()).sum()
    }

    pub fn ndim(&self) -> usize {
        self.fields.first().map(|f| f.dims.ndim).unwrap_or(0)
    }
}

/// The five suites of Table II.
pub const SUITE_NAMES: [&str; 5] = ["hacc", "cesm", "hurricane", "nyx", "qmcpack"];

/// Scale of a generated suite. `Small` targets the testbed (a few MB per
/// field); `Full` reproduces the paper's Table II dimensions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Small,
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "small" => Some(Scale::Small),
            "full" => Some(Scale::Full),
            _ => None,
        }
    }
}

/// Generate a suite by name.
pub fn suite(name: &str, scale: Scale, seed: u64) -> Option<Dataset> {
    match name {
        "hacc" => Some(synthetic::hacc(scale, seed)),
        "cesm" => Some(synthetic::cesm(scale, seed)),
        "hurricane" => Some(synthetic::hurricane(scale, seed)),
        "nyx" => Some(synthetic::nyx(scale, seed)),
        "qmcpack" => Some(synthetic::qmcpack(scale, seed)),
        _ => None,
    }
}

/// All suites (the Fig 3/5/8 workload set).
pub fn all_suites(scale: Scale, seed: u64) -> Vec<Dataset> {
    SUITE_NAMES.iter().map(|n| suite(n, scale, seed).unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_invariants() {
        let f = Field::new("x", Dims::d2(4, 8), vec![0.0; 32]);
        assert_eq!(f.size_bytes(), 128);
    }

    #[test]
    #[should_panic(expected = "dims/data mismatch")]
    fn field_length_checked() {
        Field::new("bad", Dims::d1(10), vec![0.0; 5]);
    }

    #[test]
    fn suite_lookup() {
        assert!(suite("cesm", Scale::Small, 1).is_some());
        assert!(suite("nope", Scale::Small, 1).is_none());
    }
}

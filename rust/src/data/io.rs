//! Raw-binary field I/O (SDRBench's `.f32`/`.dat` convention: bare
//! little-endian f32 streams, dimensions supplied out of band).

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use crate::blocks::Dims;
use crate::data::Field;
use crate::error::{Result, VszError};
use crate::util::{bytes_to_f32, f32_as_bytes};

/// Write a field's payload as bare little-endian f32.
pub fn write_f32_file(path: &Path, data: &[f32]) -> Result<()> {
    let mut f = fs::File::create(path)?;
    f.write_all(f32_as_bytes(data))?;
    Ok(())
}

/// Read a bare f32 file; length must match `dims`.
pub fn read_f32_file(path: &Path, dims: Dims, name: &str) -> Result<Field> {
    let mut f = fs::File::open(path)?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if bytes.len() != dims.len() * 4 {
        return Err(VszError::format(format!(
            "{}: file has {} bytes, dims {:?} need {}",
            path.display(),
            bytes.len(),
            &dims.shape[..dims.ndim],
            dims.len() * 4
        )));
    }
    Ok(Field::new(name, dims, bytes_to_f32(&bytes)))
}

/// Parse "NxMxK" / "NxM" / "N" into [`Dims`].
pub fn parse_dims(s: &str) -> Result<Dims> {
    let parts: Vec<&str> = s.split('x').collect();
    let mut vals = Vec::with_capacity(parts.len());
    for p in &parts {
        vals.push(
            p.parse::<usize>()
                .map_err(|_| VszError::config(format!("bad dimension '{p}' in '{s}'")))?,
        );
    }
    match vals.len() {
        1 => Ok(Dims::d1(vals[0])),
        2 => Ok(Dims::d2(vals[0], vals[1])),
        3 => Ok(Dims::d3(vals[0], vals[1], vals[2])),
        n => Err(VszError::config(format!("{n} dimensions unsupported (1-3)"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_file() {
        let dir = std::env::temp_dir().join("vecsz_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.f32");
        let data = vec![1.0f32, -2.5, 3.25, 0.0];
        write_f32_file(&p, &data).unwrap();
        let f = read_f32_file(&p, Dims::d2(2, 2), "t").unwrap();
        assert_eq!(f.data, data);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn length_mismatch_rejected() {
        let dir = std::env::temp_dir().join("vecsz_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("short.f32");
        write_f32_file(&p, &[1.0, 2.0]).unwrap();
        assert!(read_f32_file(&p, Dims::d1(3), "s").is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn dims_parsing() {
        assert_eq!(parse_dims("100").unwrap(), Dims::d1(100));
        assert_eq!(parse_dims("4x5").unwrap(), Dims::d2(4, 5));
        assert_eq!(parse_dims("2x3x4").unwrap(), Dims::d3(2, 3, 4));
        assert!(parse_dims("2x3x4x5").is_err());
        assert!(parse_dims("abc").is_err());
    }
}

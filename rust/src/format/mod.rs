//! `.vsz` container format.
//!
//! Layout (all little-endian):
//! ```text
//! magic "VSZ1" | u16 version | u8 ndim | u8 codes_kind | u64 dims[3]
//! f64 eb | u16 radius | u32 block_size
//! u8 pad_value | u8 pad_granularity
//! u8 n_sections, then per section:
//!   u8 tag | uvarint raw_len | uvarint enc_len | u32 crc32(payload) | bytes
//! ```
//! Section payloads are already entropy-coded by their producers (Huffman
//! for codes, lossless for outlier streams); the container adds integrity
//! and framing only.

use crate::bitio::{put_uvarint, Cursor};
use crate::blocks::Dims;
use crate::error::{Result, VszError};
use crate::padding::{PadGranularity, PadValue, PaddingPolicy};
use crate::quant::CodesKind;
use crate::util::crc32;

pub const MAGIC: &[u8; 4] = b"VSZ1";
pub const VERSION: u16 = 1;

/// Section tags.
pub mod tag {
    /// Huffman-coded quant codes.
    pub const CODES: u8 = 1;
    /// Outlier positions (delta varints, lossless-compressed).
    pub const OUTLIER_POS: u8 = 2;
    /// Outlier values (f32 LE bytes, lossless-compressed).
    pub const OUTLIER_VAL: u8 = 3;
    /// Padding scalars (f32 LE bytes, lossless-compressed).
    pub const PAD_SCALARS: u8 = 4;
}

/// Parsed container header.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Header {
    pub dims: Dims,
    pub codes_kind: CodesKind,
    pub eb: f64,
    pub radius: u16,
    pub block_size: u32,
    pub padding: PaddingPolicy,
}

/// One framed section.
#[derive(Clone, Debug)]
pub struct Section {
    pub tag: u8,
    pub raw_len: u64,
    pub payload: Vec<u8>,
}

fn kind_to_u8(k: CodesKind) -> u8 {
    match k {
        CodesKind::DualQuant => 0,
        CodesKind::Sz14 => 1,
    }
}

fn kind_from_u8(v: u8) -> Result<CodesKind> {
    match v {
        0 => Ok(CodesKind::DualQuant),
        1 => Ok(CodesKind::Sz14),
        _ => Err(VszError::format(format!("unknown codes kind {v}"))),
    }
}

fn pad_value_to_u8(v: PadValue) -> u8 {
    match v {
        PadValue::Zero => 0,
        PadValue::Min => 1,
        PadValue::Max => 2,
        PadValue::Avg => 3,
    }
}

fn pad_value_from_u8(v: u8) -> Result<PadValue> {
    Ok(match v {
        0 => PadValue::Zero,
        1 => PadValue::Min,
        2 => PadValue::Max,
        3 => PadValue::Avg,
        _ => return Err(VszError::format(format!("unknown pad value {v}"))),
    })
}

fn pad_gran_to_u8(g: PadGranularity) -> u8 {
    match g {
        PadGranularity::Global => 0,
        PadGranularity::Block => 1,
        PadGranularity::Edge => 2,
    }
}

fn pad_gran_from_u8(v: u8) -> Result<PadGranularity> {
    Ok(match v {
        0 => PadGranularity::Global,
        1 => PadGranularity::Block,
        2 => PadGranularity::Edge,
        _ => return Err(VszError::format(format!("unknown pad granularity {v}"))),
    })
}

/// Serialize a container.
pub fn write_container(header: &Header, sections: &[Section]) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + sections.iter().map(|s| s.payload.len() + 16).sum::<usize>());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(header.dims.ndim as u8);
    out.push(kind_to_u8(header.codes_kind));
    for d in header.dims.shape {
        out.extend_from_slice(&(d as u64).to_le_bytes());
    }
    out.extend_from_slice(&header.eb.to_bits().to_le_bytes());
    out.extend_from_slice(&header.radius.to_le_bytes());
    out.extend_from_slice(&header.block_size.to_le_bytes());
    out.push(pad_value_to_u8(header.padding.value));
    out.push(pad_gran_to_u8(header.padding.granularity));
    out.push(sections.len() as u8);
    for s in sections {
        out.push(s.tag);
        put_uvarint(&mut out, s.raw_len);
        put_uvarint(&mut out, s.payload.len() as u64);
        out.extend_from_slice(&crc32(&s.payload).to_le_bytes());
        out.extend_from_slice(&s.payload);
    }
    out
}

/// Parse and integrity-check a container.
pub fn read_container(data: &[u8]) -> Result<(Header, Vec<Section>)> {
    let mut c = Cursor::new(data);
    let magic = c.take(4).ok_or_else(|| VszError::format("truncated magic"))?;
    if magic != MAGIC {
        return Err(VszError::format("bad magic (not a .vsz container)"));
    }
    let version = c.u16().ok_or_else(|| VszError::format("truncated version"))?;
    if version != VERSION {
        return Err(VszError::format(format!("unsupported version {version}")));
    }
    let ndim = c.u8().ok_or_else(|| VszError::format("truncated ndim"))? as usize;
    if !(1..=3).contains(&ndim) {
        return Err(VszError::format(format!("bad ndim {ndim}")));
    }
    let codes_kind = kind_from_u8(c.u8().ok_or_else(|| VszError::format("truncated kind"))?)?;
    let mut shape = [1usize; 3];
    for s in shape.iter_mut() {
        *s = c.u64().ok_or_else(|| VszError::format("truncated dims"))? as usize;
    }
    let dims = Dims { shape, ndim };
    let eb = c.f64().ok_or_else(|| VszError::format("truncated eb"))?;
    if !(eb.is_finite() && eb > 0.0) {
        return Err(VszError::format("invalid error bound"));
    }
    let radius = c.u16().ok_or_else(|| VszError::format("truncated radius"))?;
    let block_size = c.u32().ok_or_else(|| VszError::format("truncated block size"))?;
    let pv = pad_value_from_u8(c.u8().ok_or_else(|| VszError::format("truncated pad value"))?)?;
    let pg = pad_gran_from_u8(c.u8().ok_or_else(|| VszError::format("truncated pad gran"))?)?;
    let n_sections = c.u8().ok_or_else(|| VszError::format("truncated section count"))? as usize;
    let mut sections = Vec::with_capacity(n_sections);
    for _ in 0..n_sections {
        let tag = c.u8().ok_or_else(|| VszError::format("truncated section tag"))?;
        let raw_len = c.uvarint().ok_or_else(|| VszError::format("truncated raw_len"))?;
        let enc_len = c.uvarint().ok_or_else(|| VszError::format("truncated enc_len"))? as usize;
        let crc = c.u32().ok_or_else(|| VszError::format("truncated crc"))?;
        let payload = c
            .take(enc_len)
            .ok_or_else(|| VszError::format("truncated section payload"))?
            .to_vec();
        if crc32(&payload) != crc {
            return Err(VszError::Integrity(format!("section {tag}: crc mismatch")));
        }
        sections.push(Section { tag, raw_len, payload });
    }
    let header = Header {
        dims,
        codes_kind,
        eb,
        radius,
        block_size,
        padding: PaddingPolicy::new(pv, pg),
    };
    Ok((header, sections))
}

/// Find a section by tag.
pub fn find_section<'a>(sections: &'a [Section], t: u8) -> Result<&'a Section> {
    sections
        .iter()
        .find(|s| s.tag == t)
        .ok_or_else(|| VszError::format(format!("missing section {t}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_header() -> Header {
        Header {
            dims: Dims::d2(180, 360),
            codes_kind: CodesKind::DualQuant,
            eb: 1e-4,
            radius: 512,
            block_size: 16,
            padding: PaddingPolicy::new(PadValue::Avg, PadGranularity::Global),
        }
    }

    #[test]
    fn roundtrip_header_and_sections() {
        let h = sample_header();
        let secs = vec![
            Section { tag: tag::CODES, raw_len: 1000, payload: vec![1, 2, 3, 4] },
            Section { tag: tag::OUTLIER_POS, raw_len: 5, payload: vec![9] },
            Section { tag: tag::PAD_SCALARS, raw_len: 4, payload: vec![0, 0, 128, 63] },
        ];
        let blob = write_container(&h, &secs);
        let (h2, secs2) = read_container(&blob).unwrap();
        assert_eq!(h, h2);
        assert_eq!(secs2.len(), 3);
        assert_eq!(secs2[0].payload, vec![1, 2, 3, 4]);
        assert_eq!(secs2[0].raw_len, 1000);
        assert_eq!(find_section(&secs2, tag::OUTLIER_POS).unwrap().payload, vec![9]);
        assert!(find_section(&secs2, tag::OUTLIER_VAL).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut blob = write_container(&sample_header(), &[]);
        blob[0] = b'X';
        assert!(matches!(read_container(&blob), Err(VszError::Format(_))));
    }

    #[test]
    fn rejects_corrupt_payload() {
        let secs =
            vec![Section { tag: tag::CODES, raw_len: 8, payload: vec![1, 2, 3, 4, 5, 6] }];
        let mut blob = write_container(&sample_header(), &secs);
        let n = blob.len();
        blob[n - 1] ^= 0xFF;
        assert!(matches!(read_container(&blob), Err(VszError::Integrity(_))));
    }

    #[test]
    fn rejects_truncation_anywhere() {
        let secs = vec![Section { tag: tag::CODES, raw_len: 8, payload: vec![7; 32] }];
        let blob = write_container(&sample_header(), &secs);
        for cut in [3usize, 5, 8, 20, blob.len() - 1] {
            assert!(read_container(&blob[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn rejects_nonsense_eb_and_ndim() {
        let mut h = sample_header();
        h.eb = -1.0;
        let blob = write_container(&h, &[]);
        assert!(read_container(&blob).is_err());
        let mut blob2 = write_container(&sample_header(), &[]);
        blob2[6] = 7; // ndim byte
        assert!(read_container(&blob2).is_err());
    }

    #[test]
    fn sz14_kind_roundtrips() {
        let mut h = sample_header();
        h.codes_kind = CodesKind::Sz14;
        let (h2, _) = read_container(&write_container(&h, &[])).unwrap();
        assert_eq!(h2.codes_kind, CodesKind::Sz14);
    }
}
